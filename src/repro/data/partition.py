"""Federated data partitioning: IID and Dirichlet non-IID.

The paper samples 100 examples per learner with replacement (pure stress
test); production FL experiments additionally need realistic non-IID silos,
so we provide the standard Dirichlet(α) label-skew partitioner used across
the FL literature (lower α → more skew).
"""

from __future__ import annotations

import numpy as np

__all__ = ["iid_partition", "dirichlet_partition"]


def iid_partition(
    n_examples: int, n_learners: int, seed: int = 0,
    per_learner: int | None = None, with_replacement: bool = False,
) -> list[np.ndarray]:
    """Uniform split (or fixed-size sample per learner, paper-style)."""
    rng = np.random.default_rng(seed)
    if per_learner is not None:
        return [
            rng.choice(n_examples, size=per_learner, replace=with_replacement)
            for _ in range(n_learners)
        ]
    perm = rng.permutation(n_examples)
    return [np.sort(chunk) for chunk in np.array_split(perm, n_learners)]


def dirichlet_partition(
    labels: np.ndarray, n_learners: int, alpha: float = 0.5, seed: int = 0,
    min_per_learner: int = 1,
) -> list[np.ndarray]:
    """Label-skew partition: per class, split indices by Dirichlet(α) shares."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: list[list[np.ndarray]] = [[] for _ in range(n_learners)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        shares = rng.dirichlet([alpha] * n_learners)
        cuts = (np.cumsum(shares)[:-1] * len(idx)).astype(int)
        for i, part in enumerate(np.split(idx, cuts)):
            shards[i].append(part)
    out = [np.sort(np.concatenate(s)) if s else np.array([], np.int64) for s in shards]
    # guarantee non-empty silos
    pool = np.concatenate(out) if any(len(o) for o in out) else np.arange(len(labels))
    for i, o in enumerate(out):
        if len(o) < min_per_learner:
            extra = rng.choice(pool, size=min_per_learner - len(o), replace=True)
            out[i] = np.sort(np.concatenate([o, extra]))
    return out
