from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.synthetic import (
    HousingData,
    make_housing_data,
    make_lm_data,
    LMDataIterator,
)

__all__ = [
    "dirichlet_partition",
    "iid_partition",
    "HousingData",
    "make_housing_data",
    "make_lm_data",
    "LMDataIterator",
]
