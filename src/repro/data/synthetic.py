"""Synthetic datasets: the paper's housing regression + LM token streams.

``make_housing_data`` regenerates a HousingMLP-style regression task (13
features, scalar target with a fixed nonlinear ground truth + noise) — the
paper uses the Boston housing set purely as a stress-test carrier, so a
statistically matched synthetic stands in (offline container, no downloads).

``make_lm_data``/``LMDataIterator`` provide deterministic token streams for
the transformer architectures (Zipf-distributed ids so the loss actually
decreases under training).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["HousingData", "make_housing_data", "make_lm_data", "LMDataIterator"]


@dataclasses.dataclass
class HousingData:
    x: np.ndarray  # (N, 13) float32
    y: np.ndarray  # (N, 1) float32


def make_housing_data(n: int = 506, seed: int = 0, noise: float = 0.1) -> HousingData:
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 13)).astype(np.float32)
    w1 = rng.normal(size=(13,)).astype(np.float32)
    w2 = rng.normal(size=(13,)).astype(np.float32)
    y = x @ w1 + 0.5 * np.tanh(x @ w2) + noise * rng.normal(size=(n,)).astype(np.float32)
    return HousingData(x=x, y=y[:, None].astype(np.float32))


def make_lm_data(
    n_sequences: int, seq_len: int, vocab_size: int, seed: int = 0
) -> np.ndarray:
    """(N, seq_len+1) int32 token ids, Zipf-ish marginal + local structure."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab_size, size=(n_sequences, seq_len + 1), p=probs)
    # inject local bigram structure so next-token prediction is learnable
    for t in range(1, seq_len + 1):
        copy_mask = rng.random(n_sequences) < 0.3
        toks[copy_mask, t] = (toks[copy_mask, t - 1] + 1) % vocab_size
    return toks.astype(np.int32)


class LMDataIterator:
    """Batched (tokens, labels) iterator over a private token shard."""

    def __init__(self, tokens: np.ndarray, seed: int = 0):
        self._toks = tokens
        self._rng = np.random.default_rng(seed)

    def __call__(self, batch_size: int) -> dict:
        idx = self._rng.integers(0, self._toks.shape[0], size=batch_size)
        seqs = self._toks[idx]
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}

    @property
    def n_examples(self) -> int:
        return int(self._toks.shape[0])
