"""Federation checkpointing: packed model + controller state → .npz.

The checkpoint IS the wire format: the packed numeric buffer plus the
manifest (names/shapes/dtypes/offsets) — the same representation the
controller aggregates and ships.  Server-optimizer state and round counters
ride along so an interrupted federation resumes exactly.
"""

from __future__ import annotations

import json
import os
import pickle
import re
from typing import Any

import jax
import numpy as np

from repro.core import packing

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_FNAME = re.compile(r"ckpt_(\d+)\.npz$")


def save_checkpoint(
    directory: str,
    step: int,
    params: Any,
    extra_arrays: dict[str, np.ndarray] | None = None,
    metadata: dict | None = None,
) -> str:
    os.makedirs(directory, exist_ok=True)
    buf = np.asarray(jax.device_get(packing.pack_numeric(params)))
    manifest = packing.build_manifest(params)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    payload = {"buffer": buf}
    for k, v in (extra_arrays or {}).items():
        payload[f"extra__{k}"] = np.asarray(jax.device_get(v))
    np.savez(
        path,
        manifest=np.frombuffer(pickle.dumps(manifest), dtype=np.uint8),
        meta=np.frombuffer(
            json.dumps({"step": step, **(metadata or {})}).encode(), dtype=np.uint8
        ),
        **payload,
    )
    return path


def restore_checkpoint(directory: str, step: int | None = None):
    """Returns (params, extra_arrays, metadata)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as z:
        manifest = pickle.loads(z["manifest"].tobytes())
        meta = json.loads(z["meta"].tobytes().decode())
        params = packing.unpack_numeric(z["buffer"], manifest)
        extras = {
            k[len("extra__"):]: z[k] for k in z.files if k.startswith("extra__")
        }
    return params, extras, meta


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := _FNAME.match(f))
    ]
    return max(steps) if steps else None
