"""Decode cache construction, aligned with the scan segments.

The cache pytree mirrors ``plan_segments(cfg)``: a list over segments, each a
tuple over unit positions, each a dict holding that layer kind's state stacked
over the segment's ``repeats``:

* attention (``attn``/``shared_attn``/``xattn``): ``{"attn": {"k","v"}}`` of
  shape ``(repeats, B, L, KVH, hd)`` — ``L = sliding_window`` for ``swa``
  layers (ring buffer), ``max_len`` otherwise;
* MLA: ``{"attn": {"ckv","kpe"}}`` — the compressed latent cache,
  ``(repeats, B, L, kv_lora_rank)`` / ``(repeats, B, L, rope_dim)``;
* Mamba2: ``{"mamba": {"conv","ssm"}}`` — constant-size state, independent of
  ``max_len`` (the whole point of running ``long_500k`` on SSM/hybrid archs).

``abstract_cache`` returns ShapeDtypeStructs for the dry-run (no allocation).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import (
    ATTN, MAMBA, SHARED_ATTN, SWA, XATTN, LayerSpec, ModelConfig, plan_segments,
)

__all__ = ["init_cache", "abstract_cache", "cache_bytes"]


def _entry_struct(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                  dtype) -> dict:
    if spec.kind == MAMBA:
        di, N = cfg.d_inner, cfg.ssm_state
        H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
        return {
            "mamba": {
                "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, di + 2 * N), dtype),
                "ssm": jax.ShapeDtypeStruct((batch, H, Pd, N), jnp.float32),
            }
        }
    L = min(cfg.sliding_window, max_len) if spec.kind == SWA else max_len
    if cfg.attn_impl == "mla":
        return {
            "attn": {
                "ckv": jax.ShapeDtypeStruct((batch, L, cfg.kv_lora_rank), dtype),
                "kpe": jax.ShapeDtypeStruct((batch, L, cfg.qk_rope_head_dim), dtype),
            }
        }
    hd = cfg.resolved_head_dim
    return {
        "attn": {
            "k": jax.ShapeDtypeStruct((batch, L, cfg.n_kv_heads, hd), dtype),
            "v": jax.ShapeDtypeStruct((batch, L, cfg.n_kv_heads, hd), dtype),
        }
    }


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> list:
    """ShapeDtypeStruct cache pytree (dry-run input)."""
    caches = []
    for seg in plan_segments(cfg):
        unit = tuple(
            jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((seg.repeats, *s.shape), s.dtype),
                _entry_struct(cfg, spec, batch, max_len, dtype),
            )
            for spec in seg.unit
        )
        caches.append(unit)
    return caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> list:
    """Zero-initialized cache."""
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), abstract_cache(cfg, batch, max_len, dtype),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> int:
    import numpy as np

    total = 0
    for leaf in jax.tree_util.tree_leaves(
        abstract_cache(cfg, batch, max_len, dtype),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    ):
        total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total
