"""Sharding policy: how each architecture maps onto the production mesh.

The mesh is fixed — ``("data","model")`` single-pod, ``("pod","data","model")``
multi-pod — but the *rules* adapt per architecture (DESIGN.md §4):

* attention heads shard over ``model`` iff divisible by the axis size,
  otherwise the sequence dimension is sharded (context parallelism) for
  prefill/train and the KV cache sequence for decode;
* MoE experts shard over ``model`` (padded to divisibility);
* FSDP: parameter ``d_model``/``d_ff`` dims additionally shard over ``data``
  for the very large configs (weight-gathered training), controlled by
  ``fsdp_params``.

``constrain`` is a no-op when no policy is active, so model code runs
unchanged in single-device smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = ["ShardingPolicy", "make_policy", "constrain", "arena_specs"]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh | None
    data_axes: tuple[str, ...] = ("data",)  # ("pod","data") in multi-pod
    model_axis: str = "model"
    shard_q_heads: bool = True
    shard_kv_heads: bool = True
    shard_ssm_heads: bool = True
    fsdp_params: bool = False  # shard param d_model dim over data axes too
    # Megatron-style sequence parallelism: residual stream (and therefore the
    # layer-scan remat stash) sharded over `model` along S between blocks.
    seq_parallel: bool = True
    # serving layout: weights-stationary decode — MoE experts shard over
    # model x data (2D EP) instead of the training FSDP layout.
    serving: bool = False

    @property
    def active(self) -> bool:
        return self.mesh is not None

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.mesh else 1

    # -- frequently used specs ------------------------------------------------
    def batch_spec(self, ndim: int) -> P:
        """Activations: batch over data axes, rest replicated."""
        return P(self.data_axes, *([None] * (ndim - 1)))

    def fsdp_axes(self):
        return self.data_axes if self.fsdp_params else None


def make_policy(cfg: ModelConfig, mesh: Mesh | None, multi_pod: bool = False,
                fsdp: bool | None = None, seq_parallel: bool = True,
                serving: bool = False) -> ShardingPolicy:
    if mesh is None:
        return ShardingPolicy(mesh=None)
    msize = mesh.shape["model"]
    if fsdp is None:
        # FSDP for configs whose replicated params would not fit one chip's
        # HBM share: heuristic at >= 8B params.
        fsdp = cfg.param_count_estimate() >= 8e9
    return ShardingPolicy(
        mesh=mesh,
        data_axes=("pod", "data") if multi_pod else ("data",),
        model_axis="model",
        shard_q_heads=cfg.n_heads % msize == 0,
        shard_kv_heads=cfg.n_kv_heads % msize == 0 and cfg.n_kv_heads >= msize,
        shard_ssm_heads=(cfg.ssm_heads % msize == 0) if cfg.ssm_state else False,
        fsdp_params=bool(fsdp),
        seq_parallel=seq_parallel,
        serving=serving,
    )


def constrain(x: jax.Array, policy: ShardingPolicy | None, *spec) -> jax.Array:
    """``with_sharding_constraint`` that degrades to identity without a mesh."""
    if policy is None or not policy.active:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(policy.mesh, P(*spec))
    )


def arena_specs(
    mesh: Mesh, axes: str | tuple[str, ...] | None = None
) -> tuple[NamedSharding, NamedSharding, NamedSharding]:
    """Shardings for a column-sharded aggregation arena on ``mesh``.

    The arena layout of ``core/store.ArenaStore(mesh=...)``: the persistent
    ``(n_max, P)`` buffer is split along ``P`` over ``axes`` (default: the
    mesh's ``"data"`` axis if present, else every axis) and *replicated-free*
    along rows — each device owns a ``(n_max, P/n_shards)`` shard and no row
    ever lives on two devices twice.  Returns
    ``(buffer_sharding, row_sharding, replicated)``:

    * ``buffer_sharding`` — ``P(None, axes)`` for the ``(n_max, P)`` arena;
    * ``row_sharding`` — ``P(axes)`` for a single packed ``(P,)`` upload or
      the ``(P,)`` aggregate;
    * ``replicated`` — ``P()`` for the tiny ``(n_max,)`` metadata vectors
      (weights / versions / mask).
    """
    from repro.core.aggregation import arena_axes

    axes = arena_axes(mesh, axes)
    return (
        NamedSharding(mesh, P(None, axes)),
        NamedSharding(mesh, P(axes)),
        NamedSharding(mesh, P()),
    )


def seq_constrain(x: jax.Array, policy: ShardingPolicy | None) -> jax.Array:
    """Residual-stream constraint: (B, S, D) -> batch over data, S over model.

    Applied at layer boundaries so the scan carry (= the remat stash, one
    (B,S,D) per layer) is model_size x smaller.  Skipped when S does not
    divide the axis (whisper's 1500-frame encoder) or S == 1 (decode).
    """
    if policy is None or not policy.active or not policy.seq_parallel:
        return x
    if x.ndim != 3 or x.shape[1] == 1 or x.shape[1] % policy.model_size:
        return x
    return constrain(x, policy, policy.data_axes, policy.model_axis, None)
