from repro.models.config import ModelConfig, plan_segments
from repro.models import layers, transformer, kvcache, mlp, sharding

__all__ = ["ModelConfig", "plan_segments", "layers", "transformer", "kvcache", "mlp", "sharding"]
