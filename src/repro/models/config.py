"""Model configuration covering all assigned architecture families.

One dataclass describes dense (GQA / MLA / sliding-window), MoE
(shared + routed top-k), SSM (Mamba2/SSD), hybrid (Mamba2 + shared attention),
encoder-decoder (Whisper), and stub-frontend (VLM/audio) architectures.

The layer stack is described by a *pattern* of layer kinds that is cycled over
``n_layers`` and then compiled into homogeneous scan *segments*
(``plan_segments``) so that deep models lower as ``lax.scan`` over stacked
parameters instead of thousand-op unrolled HLO.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp

__all__ = ["ModelConfig", "LayerSpec", "Segment", "plan_segments", "padded_vocab"]

# layer kinds
ATTN = "attn"  # full (global) self-attention + MLP/MoE
SWA = "swa"  # sliding-window self-attention + MLP
MAMBA = "mamba"  # Mamba2 (SSD) mixer + (optional) MLP
SHARED_ATTN = "shared_attn"  # zamba2-style tied full-attention block
XATTN = "xattn"  # decoder layer with self-attn + cross-attn (whisper)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str
    moe: bool = False  # routed-expert MLP instead of dense MLP


@dataclasses.dataclass(frozen=True)
class Segment:
    """``repeats`` scan steps, each applying ``unit`` layer specs in order."""

    unit: tuple[LayerSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.unit) * self.repeats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int  # logical vocabulary
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    attn_impl: str = "gqa"  # gqa | mla
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 4096
    layer_pattern: tuple[str, ...] = (ATTN,)
    rope_theta: float = 10000.0

    # MoE
    n_experts: int = 0  # routed experts (possibly padded, see expert_pad_to)
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0  # 0 -> moe_d_ff * n_shared_experts
    first_k_dense: int = 0  # leading dense layers before MoE starts (deepseek)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    expert_pad_to: int = 1  # pad n_experts up to a multiple of this

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500

    # modality frontend stub (vlm/audio): precomputed embeddings of dim
    # ``frontend_dim`` projected into d_model and prepended to the sequence.
    frontend: str | None = None  # "vision_stub" | "audio_stub"
    frontend_dim: int = 0
    num_prefix_tokens: int = 0

    # deepseek multi-token prediction: extra predict depth (0 = off)
    mtp_depth: int = 0

    # attention execution (substrate, not paper-semantics):
    # chunked = flash-style online-softmax over KV blocks (no S^2 HBM traffic).
    # attn_naive=True forces the einsum path (baseline arm of §Perf cycle 1).
    attn_naive: bool = False
    attn_k_chunk: int = 1024
    attn_chunk_min_len: int = 2048  # use naive below this KV length

    # block details
    mlp_gated: bool = True  # SiLU-gated (llama-style) vs plain GELU (whisper)
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    pos_embedding: str = "rope"  # rope | sinusoidal (whisper)

    # numerics / lowering
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    vocab_pad_to: int = 256
    remat: bool = True
    scan_layers: bool = True  # False: unroll (used by dry-run cost differencing)
    tie_embeddings: bool = False

    # citation of the source model card / paper for this config
    source: str = ""

    # ---------------------------------------------------------------- helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab_size(self) -> int:
        return padded_vocab(self.vocab_size, self.vocab_pad_to)

    @property
    def padded_n_experts(self) -> int:
        if self.n_experts == 0:
            return 0
        m = self.expert_pad_to
        return ((self.n_experts + m - 1) // m) * m

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_specs(self) -> list[LayerSpec]:
        """Expand the cycled pattern into one spec per layer."""
        specs = []
        for i in range(self.n_layers):
            kind = self.layer_pattern[i % len(self.layer_pattern)]
            moe = (
                self.n_experts > 0
                and kind in (ATTN, SWA)
                and i >= self.first_k_dense
            )
            specs.append(LayerSpec(kind=kind, moe=moe))
        return specs

    def param_count_estimate(self) -> int:
        """Closed-form parameter estimate (used for roofline MODEL_FLOPS)."""
        D, F, Vp = self.d_model, self.d_ff, self.padded_vocab_size
        hd = self.resolved_head_dim
        total = Vp * D  # embed
        if not self.tie_embeddings:
            total += D * Vp
        for spec in self.layer_specs():
            if spec.kind in (ATTN, SWA, SHARED_ATTN, XATTN):
                if self.attn_impl == "mla":
                    r_q = self.q_lora_rank or D
                    total += D * r_q + r_q * self.n_heads * (
                        self.qk_nope_head_dim + self.qk_rope_head_dim
                    )
                    total += D * (self.kv_lora_rank + self.qk_rope_head_dim)
                    total += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_head_dim + self.v_head_dim
                    )
                    total += self.n_heads * self.v_head_dim * D
                else:
                    total += D * self.n_heads * hd  # wq
                    total += 2 * D * self.n_kv_heads * hd  # wk, wv
                    total += self.n_heads * hd * D  # wo
                if spec.kind == XATTN:  # cross-attention second block
                    total += 2 * (D * self.n_heads * hd) + 2 * (D * self.n_kv_heads * hd)
            if spec.kind == MAMBA:
                di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                total += D * (2 * di + 2 * N + H)  # in_proj(z,x,B,C,dt)
                total += di * D  # out_proj
                total += self.conv_width * (di + 2 * N)
            # mlp / moe
            if spec.kind in (ATTN, SWA, SHARED_ATTN, XATTN):
                if spec.moe:
                    E = self.padded_n_experts
                    total += E * 3 * D * self.moe_d_ff
                    total += D * E  # router
                    sf = self.shared_d_ff or self.moe_d_ff * max(self.n_shared_experts, 1)
                    if self.n_shared_experts:
                        total += 3 * D * sf
                else:
                    total += 3 * D * F  # gated mlp
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                total += 4 * D * self.n_heads * hd + 3 * D * F
        return int(total)


def padded_vocab(vocab: int, multiple: int) -> int:
    return int(math.ceil(vocab / multiple) * multiple)


def plan_segments(cfg: ModelConfig) -> list[Segment]:
    """Compile the per-layer spec list into maximal scan segments.

    Strategy: find the repeating unit (the full cycled pattern) and emit
    ``Segment(unit, repeats)`` for as many whole cycles as fit, then a
    remainder segment with ``repeats=1``.  Homogeneous patterns collapse to a
    single one-layer unit scanned ``n_layers`` times (minus any
    ``first_k_dense`` prefix, which becomes its own leading segment).
    """
    specs = cfg.layer_specs()
    segments: list[Segment] = []
    i = 0
    # leading dense prefix (deepseek first_k_dense) — own unrolled segment
    if cfg.first_k_dense > 0:
        segments.append(Segment(unit=tuple(specs[: cfg.first_k_dense]), repeats=1))
        i = cfg.first_k_dense
    rest = specs[i:]
    if not rest:
        return segments
    unit_len = len(cfg.layer_pattern)
    if all(s == rest[0] for s in rest):
        # fully homogeneous — one spec scanned len(rest) times
        segments.append(Segment(unit=(rest[0],), repeats=len(rest)))
        return segments
    repeats = len(rest) // unit_len
    if repeats > 0:
        segments.append(Segment(unit=tuple(rest[:unit_len]), repeats=repeats))
    rem = rest[repeats * unit_len :]
    if rem:
        segments.append(Segment(unit=tuple(rem), repeats=1))
    return segments
