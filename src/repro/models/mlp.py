"""The paper's HousingMLP: a 100-hidden-layer regression MLP.

Used by the benchmark harness to reproduce Figs. 5-7 / Table 2 at the exact
model sizes the paper stress-tests (100k / 1M / 10M parameters).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.housing_mlp import MLPConfig

__all__ = ["init_params", "apply", "mse_loss"]


def init_params(key, cfg: MLPConfig):
    ks = jax.random.split(key, cfg.n_hidden_layers + 1)
    params = {"layers": []}
    d_in = cfg.n_features
    for i in range(cfg.n_hidden_layers):
        params["layers"].append(
            {
                "w": jax.random.normal(ks[i], (d_in, cfg.width)) * (1.0 / jnp.sqrt(d_in)),
                "b": jnp.zeros((cfg.width,)),
            }
        )
        d_in = cfg.width
    params["out"] = {
        "w": jax.random.normal(ks[-1], (d_in, cfg.n_outputs)) * (1.0 / jnp.sqrt(d_in)),
        "b": jnp.zeros((cfg.n_outputs,)),
    }
    return params


def apply(params, x: jax.Array) -> jax.Array:
    for layer in params["layers"]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    return x @ params["out"]["w"] + params["out"]["b"]


def mse_loss(params, batch) -> jax.Array:
    x, y = batch
    pred = apply(params, x)
    return jnp.mean((pred - y) ** 2)
