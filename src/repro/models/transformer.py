"""Model composition: segments → scan, decoder-only / enc-dec / hybrid.

The layer stack from ``plan_segments(cfg)`` lowers as ``lax.scan`` over
stacked parameters (one scan per homogeneous segment), which keeps the HLO
small for 80-layer configs while preserving faithful layer ordering for
heterogeneous patterns (gemma3 5:1 sliding:global, zamba2 mamba+shared-attn).

Public entry points:

* ``init_params(key, cfg)`` / ``abstract_params(cfg)``
* ``forward(params, tokens, cfg, ...)``     — train/prefill logits
* ``decode_step(params, tokens, cache, ...)`` — one-token serve step
* ``lm_loss(params, batch, cfg, ...)``      — causal LM objective (+MoE aux,
  +MTP when configured)
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import (
    ATTN, MAMBA, SHARED_ATTN, SWA, XATTN,
    LayerSpec, ModelConfig, Segment, plan_segments,
)
from repro.models.sharding import ShardingPolicy, constrain, seq_constrain

# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, cross: bool = False):
    """One transformer layer's params for the given spec."""
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {}
    if spec.kind == MAMBA:
        p["norm1"] = layers.init_norm(cfg)
        p["mamba"] = layers.init_mamba(ks[0], cfg)
        return p
    if spec.kind == SHARED_ATTN:
        # placeholder: weights live in params["shared_block"]; per-instance
        # linear adapter keeps layers distinguishable (zamba2 uses LoRA here).
        p["adapter_scale"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
        return p
    # attention family
    p["norm1"] = layers.init_norm(cfg)
    if cfg.attn_impl == "mla":
        p["attn"] = layers.init_mla(ks[0], cfg)
    else:
        p["attn"] = layers.init_attention(ks[0], cfg)
    if spec.kind == XATTN:
        p["norm_x"] = layers.init_norm(cfg)
        p["xattn"] = layers.init_attention(ks[1], cfg, cross=True)
    p["norm2"] = layers.init_norm(cfg)
    if spec.moe:
        p["moe"] = layers.init_moe(ks[2], cfg)
    elif cfg.d_ff > 0:
        p["mlp"] = layers.init_mlp(ks[2], cfg)
    return p


def _init_shared_block(key, cfg: ModelConfig):
    """Zamba2's tied full-attention transformer block."""
    ks = jax.random.split(key, 2)
    return {
        "norm1": layers.init_norm(cfg),
        "attn": layers.init_attention(ks[0], cfg),
        "norm2": layers.init_norm(cfg),
        "mlp": layers.init_mlp(ks[1], cfg),
    }


def _stack_init(key, cfg: ModelConfig, seg: Segment):
    """Stacked (repeats-leading) params for one scan segment."""

    def one(k):
        ks = jax.random.split(k, len(seg.unit))
        return tuple(_init_layer(ks[i], cfg, s) for i, s in enumerate(seg.unit))

    if seg.repeats == 1:
        return jax.tree_util.tree_map(lambda x: x[None], one(key))
    keys = jax.random.split(key, seg.repeats)
    return jax.vmap(one)(keys)


def init_params(key, cfg: ModelConfig):
    segs = plan_segments(cfg)
    n = 8 + len(segs)
    ks = jax.random.split(key, n)
    Vp, D = cfg.padded_vocab_size, cfg.d_model
    params: dict[str, Any] = {
        "embed": layers._dense_init(ks[0], (Vp, D), cfg.param_dtype, scale=0.02),
        "final_norm": layers.init_norm(cfg),
        "segments": [_stack_init(ks[8 + i], cfg, seg) for i, seg in enumerate(segs)],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers._dense_init(ks[1], (D, Vp), cfg.param_dtype)
    if any(s.kind == SHARED_ATTN for s in cfg.layer_specs()):
        params["shared_block"] = _init_shared_block(ks[2], cfg)
    if cfg.frontend is not None:
        params["frontend_proj"] = layers._dense_init(
            ks[3], (cfg.frontend_dim, D), cfg.param_dtype
        )
    if cfg.is_encoder_decoder:
        enc_seg = Segment(unit=(LayerSpec(kind=ATTN),), repeats=cfg.n_encoder_layers)
        params["encoder"] = {
            "segments": [_stack_init(ks[4], cfg, enc_seg)],
            "final_norm": layers.init_norm(cfg),
        }
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "proj": layers._dense_init(ks[5], (2 * D, D), cfg.param_dtype),
            "norm_h": layers.init_norm(cfg),
            "norm_e": layers.init_norm(cfg),
            "layer": jax.tree_util.tree_map(
                lambda x: x[None], _init_layer(ks[6], cfg, LayerSpec(kind=ATTN))
            ),
            "final_norm": layers.init_norm(cfg),
        }
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run input)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------


def _apply_layer(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions,
    policy,
    shared_block=None,
    memory=None,  # encoder output for cross-attention
    cache=None,
    decode_pos=None,
):
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    if spec.kind == SHARED_ATTN:
        sb = shared_block
        h = layers.apply_norm(sb["norm1"], x, cfg)
        a, c_attn = layers.apply_attention(
            sb["attn"], h, cfg, positions=positions, mode="causal", policy=policy,
            kv_cache=None if cache is None else cache.get("attn"),
            decode_pos=decode_pos,
        )
        x = x + a * p["adapter_scale"].astype(x.dtype)
        h = layers.apply_norm(sb["norm2"], x, cfg)
        x = x + layers.apply_mlp(sb["mlp"], h, cfg, policy)
        if cache is not None:
            new_cache = {"attn": c_attn}
        return x, new_cache, aux

    if spec.kind == MAMBA:
        h = layers.apply_norm(p["norm1"], x, cfg)
        y, c_m = layers.apply_mamba(
            p["mamba"], h, cfg, policy=policy,
            cache=None if cache is None else cache.get("mamba"),
            decode_pos=decode_pos,
        )
        x = x + y
        if cache is not None:
            new_cache = {"mamba": c_m}
        return x, new_cache, aux

    # attention family (attn / swa / xattn)
    mode = "sliding" if spec.kind == SWA else "causal"
    h = layers.apply_norm(p["norm1"], x, cfg)
    if cfg.attn_impl == "mla":
        a, c_attn = layers.apply_mla(
            p["attn"], h, cfg, positions=positions, mode=mode, policy=policy,
            kv_cache=None if cache is None else cache.get("attn"),
            decode_pos=decode_pos,
        )
    else:
        a, c_attn = layers.apply_attention(
            p["attn"], h, cfg, positions=positions, mode=mode, policy=policy,
            kv_cache=None if cache is None else cache.get("attn"),
            decode_pos=decode_pos,
        )
    x = x + a

    if spec.kind == XATTN:
        h = layers.apply_norm(p["norm_x"], x, cfg)
        a, _ = layers.apply_attention(
            p["xattn"], h, cfg, positions=positions, mode="full", policy=policy,
            x_cross=memory,
            kv_cache=None if cache is None else cache.get("attn"),
            decode_pos=decode_pos,
        )
        x = x + a

    h = layers.apply_norm(p["norm2"], x, cfg)
    if "moe" in p:
        y, aux = layers.apply_moe(p["moe"], h, cfg, policy)
        x = x + y
    elif "mlp" in p:
        x = x + layers.apply_mlp(p["mlp"], h, cfg, policy)

    if cache is not None:
        new_cache = {"attn": c_attn} if c_attn is not None else {}
    return x, new_cache, aux


def _encoder_mode(spec_kind: str) -> str:
    return "full"


def _run_segments(
    params_segments,
    x: jax.Array,
    cfg: ModelConfig,
    segs: list[Segment],
    *,
    positions,
    policy,
    shared_block=None,
    memory=None,
    caches=None,  # list aligned with segs; each: tuple per unit pos of stacked dicts
    decode_pos=None,
    encoder: bool = False,
):
    """Apply all segments; returns (x, new_caches, aux_total)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None

    for si, seg in enumerate(segs):
        seg_params = params_segments[si]
        seg_cache = caches[si] if caches is not None else None

        def unit_body(x, p_unit, c_unit):
            x = seq_constrain(x, policy)
            new_c = []
            aux = jnp.zeros((), jnp.float32)
            for li, spec in enumerate(seg.unit):
                eff_spec = spec if not encoder else dataclasses.replace(spec, kind=ATTN)
                mode_spec = eff_spec
                x, nc, a = _apply_layer(
                    p_unit[li], x, cfg, mode_spec,
                    positions=positions, policy=policy,
                    shared_block=shared_block, memory=memory,
                    cache=None if c_unit is None else c_unit[li],
                    decode_pos=decode_pos,
                )
                if encoder:
                    pass
                aux = aux + a
                new_c.append(nc)
            return x, tuple(new_c), aux

        body = unit_body
        if cfg.remat:
            body = jax.checkpoint(unit_body)

        if seg.repeats == 1 or not cfg.scan_layers:
            # unrolled path: repeats==1 remainders, and the dry-run's
            # cost-differencing lowerings (cfg.scan_layers=False)
            step_caches = []
            aux = jnp.zeros((), jnp.float32)
            for r in range(seg.repeats):
                p_unit = jax.tree_util.tree_map(lambda a: a[r], seg_params)
                c_unit = (
                    None if seg_cache is None
                    else jax.tree_util.tree_map(lambda a: a[r], seg_cache)
                )
                x, nc, a = body(x, p_unit, c_unit)
                aux = aux + a
                step_caches.append(nc)
            if new_caches is not None:
                new_caches.append(
                    jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *step_caches)
                )
            aux_total = aux_total + aux
        else:

            def scan_step(carry, xs):
                x = carry
                if seg_cache is None:
                    p_unit = xs
                    c_unit = None
                else:
                    p_unit, c_unit = xs
                x, nc, aux = body(x, p_unit, c_unit)
                return x, (nc, aux) if seg_cache is not None else aux

            xs = seg_params if seg_cache is None else (seg_params, seg_cache)
            x, ys = jax.lax.scan(scan_step, x, xs)
            if seg_cache is not None:
                nc, auxs = ys
                new_caches.append(nc)
            else:
                auxs = ys
            aux_total = aux_total + jnp.sum(auxs)

    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed(params, tokens: jax.Array, cfg: ModelConfig, positions) -> jax.Array:
    x = params["embed"].astype(cfg.dtype)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    if cfg.pos_embedding == "sinusoidal":
        x = x + layers.sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)
    return x


def _logits(params, x: jax.Array, cfg: ModelConfig, policy) -> jax.Array:
    x = layers.apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings or "lm_head" not in params:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    logits = x @ w
    if policy is not None and policy.active:
        logits = constrain(logits, policy, policy.data_axes, None, policy.model_axis)
    # mask padded vocabulary
    Vp, V = cfg.padded_vocab_size, cfg.vocab_size
    if Vp != V:
        mask = (jnp.arange(Vp) >= V) * jnp.asarray(-1e30, jnp.float32)
        logits = logits + mask.astype(logits.dtype)
    return logits


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------


def encode(params, frames: jax.Array, cfg: ModelConfig, policy=None) -> jax.Array:
    """Audio encoder over stubbed (precomputed) frame embeddings."""
    enc = params["encoder"]
    B, S, _ = frames.shape
    positions = jnp.arange(S)[None, :]
    x = frames.astype(cfg.dtype) @ params["frontend_proj"].astype(cfg.dtype)
    x = x + layers.sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)
    segs = [Segment(unit=(LayerSpec(kind=ATTN),), repeats=cfg.n_encoder_layers)]
    x, _, _ = _run_segments(
        enc["segments"], x, cfg, segs,
        positions=positions, policy=policy, encoder=True,
    )
    return layers.apply_norm(enc["final_norm"], x, cfg)


# ---------------------------------------------------------------------------
# forward / decode / loss
# ---------------------------------------------------------------------------


def forward(
    params,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    policy: ShardingPolicy | None = None,
    prefix_embeds: jax.Array | None = None,  # VLM patches (B, n_pre, frontend_dim)
    memory: jax.Array | None = None,  # whisper encoder output
    frames: jax.Array | None = None,  # whisper raw frame embeddings
    caches=None,
    decode_pos=None,
    return_hidden: bool = False,
):
    """Token logits for train/prefill (caches=None) or decode (caches set).

    Returns (logits, new_caches, aux_loss) — plus hidden states if
    ``return_hidden`` (used by the MTP head to avoid a second forward).
    """
    B, S = tokens.shape
    if decode_pos is None:
        positions = jnp.arange(S)[None, :]
        n_pre = 0
        if prefix_embeds is not None:
            n_pre = prefix_embeds.shape[1]
            positions = jnp.arange(n_pre + S)[None, :]
    else:
        positions = decode_pos[None, None] + jnp.arange(S)[None, :]

    x = _embed(params, tokens, cfg, positions if prefix_embeds is None else positions[:, -S:])
    if prefix_embeds is not None:
        pre = prefix_embeds.astype(cfg.dtype) @ params["frontend_proj"].astype(cfg.dtype)
        x = jnp.concatenate([pre, x.astype(pre.dtype)], axis=1)

    if policy is not None and policy.active:
        x = constrain(x, policy, policy.data_axes, None, None)
        x = seq_constrain(x, policy)

    if cfg.is_encoder_decoder and memory is None:
        assert frames is not None, "enc-dec model needs frames or memory"
        memory = encode(params, frames, cfg, policy)

    shared_block = params.get("shared_block")
    segs = plan_segments(cfg)
    x, new_caches, aux = _run_segments(
        params["segments"], x, cfg, segs,
        positions=positions, policy=policy,
        shared_block=shared_block, memory=memory,
        caches=caches, decode_pos=decode_pos,
    )

    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1] :]
    logits = _logits(params, x, cfg, policy)
    if return_hidden:
        return logits, new_caches, aux, x
    return logits, new_caches, aux


def decode_step(
    params,
    tokens: jax.Array,  # (B, 1) current token
    caches,
    decode_pos: jax.Array,  # scalar int32
    cfg: ModelConfig,
    *,
    policy=None,
    memory=None,
):
    """One serve step: next-token logits + updated caches."""
    logits, new_caches, _ = forward(
        params, tokens, cfg, policy=policy, memory=memory,
        caches=caches, decode_pos=decode_pos,
    )
    return logits, new_caches


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def lm_loss(
    params,
    batch: dict,
    cfg: ModelConfig,
    *,
    policy: ShardingPolicy | None = None,
) -> jax.Array:
    """Causal LM loss (+ MoE aux + MTP when configured).

    batch: {"tokens": (B,S), "labels": (B,S)} plus optional
    {"prefix_embeds"} (vlm) / {"frames"} (audio enc-dec).
    """
    logits, _, aux, h = forward(
        params, batch["tokens"], cfg, policy=policy,
        prefix_embeds=batch.get("prefix_embeds"),
        frames=batch.get("frames"),
        return_hidden=True,
    )
    loss = _xent(logits, batch["labels"])
    if cfg.n_experts:
        loss = loss + cfg.router_aux_coef * aux

    if cfg.mtp_depth > 0:
        # deepseek MTP: predict t+2 from [h_t ; emb(label_t)] through one
        # extra layer; labels shifted once more.
        mtp = params["mtp"]
        emb_next = params["embed"].astype(cfg.dtype)[batch["labels"]]
        hcat = jnp.concatenate(
            [layers.apply_norm(mtp["norm_h"], h, cfg),
             layers.apply_norm(mtp["norm_e"], emb_next, cfg)], axis=-1
        )
        h2 = hcat @ mtp["proj"].astype(hcat.dtype)
        B, S = batch["tokens"].shape
        positions = jnp.arange(S)[None, :]
        p_unit = jax.tree_util.tree_map(lambda a: a[0], mtp["layer"])
        h2, _, _ = _apply_layer(
            p_unit, h2, cfg, LayerSpec(kind=ATTN),
            positions=positions, policy=policy,
        )
        h2 = layers.apply_norm(mtp["final_norm"], h2, cfg)
        logits2 = _logits(params, h2, cfg, policy)
        # shift: position t predicts label_{t+1}
        loss = loss + 0.3 * _xent(logits2[:, :-1], batch["labels"][:, 1:])
    return loss
