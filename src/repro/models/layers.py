"""Model building blocks: norms, RoPE, attention (GQA/MLA/sliding), MLP, MoE,
Mamba2 (SSD).  Pure functions over parameter dicts; every block has an
``init_*`` (parameter construction) and an apply function.

Decode paths take and return explicit cache entries (``models/kvcache.py``
defines their layout); train/prefill paths are cache-free.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.sharding import ShardingPolicy, constrain

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, param_dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(
        param_dtype
    )


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: int | None = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.param_dtype)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.param_dtype)
    return p


def apply_norm(p, x: jax.Array, cfg: ModelConfig, eps: float = 1e-6) -> jax.Array:
    # f32 statistics AND f32 apply.  §Perf cycle 6 tried a bf16 apply to
    # avoid f32 residual copies — REFUTED: measured HLO bytes rose 20-40%
    # on the train shapes (the f32 path fuses into adjacent f32 consumers;
    # the bf16 path forced extra round-trips).  Kept as the measured winner.
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def _rms_head_norm(scale, x, eps: float = 1e-6):
    """qk-norm: RMS over the head dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary / sinusoidal position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: jax.Array, d_model: int) -> jax.Array:
    """Whisper-style absolute sinusoidal embeddings, (..., S, D)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention (GQA, sliding window, qk-norm, optional bias)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), cfg.param_dtype),
        "wk": _dense_init(ks[1], (D, KVH * hd), cfg.param_dtype),
        "wv": _dense_init(ks[2], (D, KVH * hd), cfg.param_dtype),
        "wo": _dense_init(ks[3], (H * hd, D), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((KVH * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((KVH * hd,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    return p


def _project_qkv(p, xq: jax.Array, xkv: jax.Array, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    q = xq @ p["wq"].astype(xq.dtype)
    k = xkv @ p["wk"].astype(xkv.dtype)
    v = xkv @ p["wv"].astype(xkv.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(*q.shape[:-1], H, hd)
    k = k.reshape(*k.shape[:-1], KVH, hd)
    v = v.reshape(*v.shape[:-1], KVH, hd)
    if cfg.qk_norm:
        q = _rms_head_norm(p["q_norm"], q)
        k = _rms_head_norm(p["k_norm"], k)
    return q, k, v


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=-2)


def _attn_mask(q_len: int, k_len: int, q_offset, mode: str, window: int):
    """(q_len, k_len) additive mask.  q_offset: scalar (decode position)."""
    qi = q_offset + jnp.arange(q_len)[:, None]
    kj = jnp.arange(k_len)[None, :]
    if mode == "full":
        return jnp.zeros((q_len, k_len), jnp.float32)
    ok = kj <= qi
    if mode == "sliding":
        ok = ok & (kj > qi - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa_naive(q, k, v, mask, policy: ShardingPolicy | None, *, head_sharded: bool,
                scale: float):
    """softmax(q k^T / sqrt(d)) v with full S^2 score materialization.

    The einsum baseline: simple, but writes (B,H,Sq,Sk) f32 scores to HBM —
    §Perf cycle 1 measures this against the chunked path.
    """
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale + mask
    if policy is not None and policy.active:
        hspec = policy.model_axis if head_sharded else None
        sspec = None if head_sharded else policy.model_axis
        scores = constrain(scores, policy, policy.data_axes, hspec, sspec, None)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out


def _sdpa_chunked(q, k, v, policy, *, head_sharded: bool, scale: float,
                  mode: str, window: int, q_offset, chunk: int):
    """Flash-style attention: lax.scan over KV chunks with online softmax.

    No (Sq, Sk) score tensor ever reaches HBM — per step only
    (B, H, Sq, chunk).  Equivalent to the naive path to fp tolerance
    (tests/test_models.py::test_chunked_attention_matches_naive).
    """
    B, Sq, H, hd = q.shape
    hd_v = v.shape[-1]  # may differ from hd (MLA: qk=[nope;rope], v=v_head_dim)
    Sk = k.shape[1]
    nchunks = (Sk + chunk - 1) // chunk
    Sk_pad = nchunks * chunk
    if Sk_pad != Sk:
        k = jnp.pad(k, ((0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)))

    kc = jnp.moveaxis(k.reshape(B, nchunks, chunk, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nchunks, chunk, H, hd_v), 1, 0)

    qi = q_offset + jnp.arange(Sq)[:, None]  # (Sq, 1) absolute q positions

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, c_idx = xs
        kj = c_idx * chunk + jnp.arange(chunk)[None, :]  # (1, chunk) absolute
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb, preferred_element_type=jnp.float32)
        s = s * scale
        ok = kj < Sk  # mask padding
        if mode != "full":
            ok = ok & (kj <= qi)
        if mode == "sliding":
            ok = ok & (kj > qi - window)
        s = jnp.where(ok[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), vb)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd_v), jnp.float32)
    # checkpoint the chunk body: without it, scan stashes every chunk's f32
    # scores for backward — re-materializing the S^2 HBM traffic this path
    # exists to avoid (flash backward recomputes p per chunk instead).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (kc, vc, jnp.arange(nchunks))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, hd)


def _sdpa(q, k, v, mask, policy: ShardingPolicy | None, *, head_sharded: bool,
          cfg: ModelConfig | None = None, mode: str = "full", window: int = 0,
          q_offset=0):
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    use_chunked = (
        cfg is not None
        and not cfg.attn_naive
        and q.shape[1] > 1  # decode stays naive: (B,H,1,Sk) is small
        and k.shape[1] >= cfg.attn_chunk_min_len
    )
    if use_chunked:
        if policy is not None and policy.active:
            hs = policy.model_axis if head_sharded else None
            ss = None if head_sharded else policy.model_axis
            q = constrain(q, policy, policy.data_axes, ss, hs, None)
        return _sdpa_chunked(
            q, k, v, policy, head_sharded=head_sharded, scale=scale,
            mode=mode, window=window, q_offset=q_offset, chunk=cfg.attn_k_chunk,
        )
    return _sdpa_naive(q, k, v, mask, policy, head_sharded=head_sharded, scale=scale)


def _flash_decode(q, ck, cv, k_new, v_new, pos, *, mode: str, window: int,
                  n_rep: int, policy: ShardingPolicy):
    """shard_map flash-decoding over a sequence-sharded KV cache.

    §Perf cycle 5: the einsum decode path makes XLA all-gather the sharded
    cache both for the dynamic position update and for the softmax over the
    sharded length — tens of GiB of collectives per token.  Here each model
    shard updates its local cache slice in place and computes a partial
    (max, denom, weighted-V); the merge is one pmax + two psums of
    (B,H[,hd]) — kilobytes.

    q: (B,1,H,hd); ck/cv: (B,L,KVH,hd) sharded (data: B, model: L);
    k_new/v_new: (B,1,KVH,hd).  Returns (out (B,1,H,hd), ck, cv).
    """
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = policy.mesh
    m_ax, da = policy.model_axis, policy.data_axes
    B = q.shape[0]
    dsize = 1
    for a in da:
        dsize *= mesh.shape[a]
    bspec = da if (B % dsize == 0 and B >= dsize) else None
    L = ck.shape[1]
    ring = mode == "sliding" and L == window
    scale = 1.0 / math.sqrt(q.shape[-1])

    def body(q, ck, cv, k_new, v_new, pos):
        m = jax.lax.axis_index(m_ax)
        L_loc = ck.shape[1]
        # --- local in-place cache update -----------------------------------
        slot_g = jnp.mod(pos, L) if ring else pos
        local = slot_g - m * L_loc
        in_range = (local >= 0) & (local < L_loc)
        idx = jnp.clip(local, 0, L_loc - 1)
        cur_k = jax.lax.dynamic_slice(ck, (0, idx, 0, 0), k_new.shape)
        cur_v = jax.lax.dynamic_slice(cv, (0, idx, 0, 0), v_new.shape)
        ck = jax.lax.dynamic_update_slice(
            ck, jnp.where(in_range, k_new.astype(ck.dtype), cur_k), (0, idx, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cv, jnp.where(in_range, v_new.astype(cv.dtype), cur_v), (0, idx, 0, 0)
        )
        # --- local partial attention ---------------------------------------
        kj = m * L_loc + jnp.arange(L_loc)  # global slot ids of my shard
        if ring:
            rpos = _ring_positions(kj, pos, L)
            valid = (pos - rpos >= 0) & (pos - rpos < L) & (rpos >= 0)
        else:
            valid = kj <= pos
        kk = _repeat_kv(ck.astype(q.dtype), n_rep)
        vv = _repeat_kv(cv.astype(q.dtype), n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        mx_loc = jnp.max(s, axis=-1)  # (B,H,1)
        mx = jax.lax.pmax(mx_loc, m_ax)
        pexp = jnp.exp(s - mx[..., None])
        l = jax.lax.psum(jnp.sum(pexp, axis=-1), m_ax)  # (B,H,1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", pexp.astype(q.dtype), vv)
        pv = jax.lax.psum(pv.astype(jnp.float32), m_ax)  # (B,H,1,hd)
        out = (pv / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        return jnp.moveaxis(out, 1, 2), ck, cv  # (B,1,H,hd)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),  # q replicated over model
            P(bspec, m_ax, None, None),  # cache: L sharded
            P(bspec, m_ax, None, None),
            P(bspec, None, None, None),
            P(bspec, None, None, None),
            P(),
        ),
        out_specs=(
            P(bspec, None, None, None),
            P(bspec, m_ax, None, None),
            P(bspec, m_ax, None, None),
        ),
        check_vma=False,
    )(q, ck, cv, k_new, v_new, pos)


def apply_attention(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    mode: str,  # "causal" | "sliding" | "full"
    policy: ShardingPolicy | None = None,
    kv_cache: dict | None = None,  # decode: {"k","v"}
    decode_pos: jax.Array | None = None,  # scalar int32 absolute position
    x_cross: jax.Array | None = None,  # cross-attention memory (whisper)
) -> tuple[jax.Array, dict | None]:
    """Self- or cross-attention.  Returns (y, updated_cache)."""
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    n_rep = H // KVH
    B = x.shape[0]

    xkv = x_cross if x_cross is not None else x
    q, k, v = _project_qkv(p, x, xkv, cfg)

    if cfg.pos_embedding == "rope" and x_cross is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    head_sharded = policy.shard_q_heads if policy else False
    if policy is not None and policy.active:
        hs = policy.model_axis if head_sharded else None
        ss = None if head_sharded else policy.model_axis
        q = constrain(q, policy, policy.data_axes, ss, hs, None)

    new_cache = None
    if (
        kv_cache is not None
        and x_cross is None
        and policy is not None
        and policy.active
        and not policy.shard_kv_heads
        and kv_cache["k"].shape[1] % policy.model_size == 0
    ):
        # sequence-sharded cache -> shard_map flash-decoding (§Perf cycle 5)
        out, ck, cv = _flash_decode(
            q, kv_cache["k"], kv_cache["v"], k, v, decode_pos,
            mode=mode, window=cfg.sliding_window, n_rep=n_rep, policy=policy,
        )
        new_cache = {"k": ck, "v": cv}
        out = out.reshape(B, -1, H * hd)
        y = out @ p["wo"].astype(out.dtype)
        return y, new_cache

    if kv_cache is not None and x_cross is None:
        # decode: append this step's k/v at position `decode_pos`
        pos = decode_pos
        ck, cv = kv_cache["k"], kv_cache["v"]  # (B, L, KVH, hd)
        L = ck.shape[1]
        if mode == "sliding" and L == cfg.sliding_window:
            slot = jnp.mod(pos, L)  # ring buffer
        else:
            slot = pos
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k_full, v_full = ck.astype(x.dtype), cv.astype(x.dtype)
        # mask out unwritten/future slots
        kj = jnp.arange(L)
        if mode == "sliding" and L == cfg.sliding_window:
            # ring buffer: valid iff slot already written (age < window)
            rpos = _ring_positions(kj, pos, L)
            age = pos - rpos
            valid = (age >= 0) & (age < L) & (rpos >= 0)
            mask = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, :]
        else:
            valid = kj <= pos
            mask = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, :]
        out = _sdpa(
            q, _repeat_kv(k_full, n_rep), _repeat_kv(v_full, n_rep),
            mask, policy, head_sharded=head_sharded, cfg=cfg,
        )
    elif kv_cache is not None and x_cross is not None:
        # cross-attention during decode: static memory, no cache update
        mask = jnp.zeros((1, k.shape[1]), jnp.float32)
        out = _sdpa(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep), mask, policy,
                    head_sharded=head_sharded, cfg=cfg, mode="full")
        new_cache = kv_cache
    else:
        eff_mode = {"causal": "causal", "sliding": "sliding", "full": "full"}[mode]
        use_chunked = (not cfg.attn_naive and q.shape[1] > 1
                       and k.shape[1] >= cfg.attn_chunk_min_len)
        mask = None if use_chunked else _attn_mask(
            q.shape[1], k.shape[1], 0, eff_mode, cfg.sliding_window)
        out = _sdpa(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep), mask, policy,
                    head_sharded=head_sharded, cfg=cfg, mode=eff_mode,
                    window=cfg.sliding_window, q_offset=0)

    out = out.reshape(B, -1, H * hd)
    y = out @ p["wo"].astype(out.dtype)
    return y, new_cache


def _ring_positions(slots: jax.Array, pos: jax.Array, L) -> jax.Array:
    """Absolute position currently stored in each ring-buffer slot.

    The slot for absolute position t is t % L; slot j currently holds the
    largest t' <= pos with t' % L == j.
    """
    rem = jnp.mod(pos, L)
    base = pos - rem
    cand = base + slots
    return jnp.where(cand <= pos, cand, cand - L)


# ---------------------------------------------------------------------------
# MLA (deepseek multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": _dense_init(ks[0], (D, rq), cfg.param_dtype),
        "q_norm": jnp.ones((rq,), cfg.param_dtype),
        "wq_b": _dense_init(ks[1], (rq, H * (dn + dr)), cfg.param_dtype),
        "wkv_a": _dense_init(ks[2], (D, rkv + dr), cfg.param_dtype),
        "kv_norm": jnp.ones((rkv,), cfg.param_dtype),
        "wk_b": _dense_init(ks[3], (rkv, H * dn), cfg.param_dtype),
        "wv_b": _dense_init(ks[4], (rkv, H * dv), cfg.param_dtype),
        "wo": _dense_init(ks[5], (H * dv, D), cfg.param_dtype),
    }


def _mla_q(p, x, cfg: ModelConfig, positions):
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = _rms_head_norm(p["q_norm"], x @ p["wq_a"].astype(x.dtype))
    q = (cq @ p["wq_b"].astype(x.dtype)).reshape(*x.shape[:-1], H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p, x, cfg: ModelConfig, positions):
    rkv, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = x @ p["wkv_a"].astype(x.dtype)  # (B,S,rkv+dr)
    c_kv = _rms_head_norm(p["kv_norm"], kv[..., :rkv])
    k_pe = apply_rope(kv[..., None, rkv:], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_pe  # (B,S,rkv), (B,S,dr)


def apply_mla(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    mode: str,
    policy: ShardingPolicy | None = None,
    kv_cache: dict | None = None,  # {"ckv": (B,L,rkv), "kpe": (B,L,dr)}
    decode_pos: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    """Multi-head latent attention.  Decode uses the *absorbed* formulation:
    scores from the compressed latent directly, value read-out in latent space
    — the cache holds only (rkv + dr) floats per token."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope = _mla_q(p, x, cfg, positions)

    if kv_cache is None:
        # train/prefill: expand latents to per-head K/V; fold the shared
        # rope key into a concatenated head dim so the (chunked) SDPA core
        # handles MLA unchanged: q_eff=[q_nope;q_rope], k_eff=[k_nope;k_pe].
        c_kv, k_pe = _mla_kv_latent(p, x, cfg, positions)
        k_nope = (c_kv @ p["wk_b"].astype(x.dtype)).reshape(B, S, H, dn)
        v = (c_kv @ p["wv_b"].astype(x.dtype)).reshape(B, S, H, dv)
        q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_eff = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, dr))], axis=-1
        )
        # pad v to match the sdpa head dim contract? no: _sdpa allows hd_v != hd_qk
        use_chunked = (not cfg.attn_naive and S > 1 and S >= cfg.attn_chunk_min_len)
        mask = None if use_chunked else _attn_mask(S, S, 0, "causal", 0)
        # _sdpa scales by 1/sqrt(q_eff_dim) == 1/sqrt(dn+dr) = `scale` — correct.
        out = _sdpa(q_eff, k_eff, v, mask, policy,
                    head_sharded=policy.shard_q_heads if policy else False,
                    cfg=cfg, mode="causal", window=0, q_offset=0)
        new_cache = None
    elif (
        policy is not None and policy.active
        and kv_cache["ckv"].shape[1] % policy.model_size == 0
    ):
        # absorbed decode over a sequence-sharded latent cache: shard_map
        # flash merge (§Perf cycle 5), latent read-out psum'ed in rkv space.
        from repro.compat import shard_map
        from jax.sharding import PartitionSpec as PS

        mesh = policy.mesh
        m_ax, da = policy.model_axis, policy.data_axes
        dsize = 1
        for a in da:
            dsize *= mesh.shape[a]
        bspec = da if (B % dsize == 0 and B >= dsize) else None
        c_new, kpe_new = _mla_kv_latent(p, x, cfg, positions)
        wk_b = p["wk_b"].astype(x.dtype).reshape(rkv, H, dn)
        wv_b = p["wv_b"].astype(x.dtype).reshape(rkv, H, dv)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)
        L = kv_cache["ckv"].shape[1]

        def body(q_lat, q_rope, ckv, kpe, c_new, kpe_new, pos):
            m = jax.lax.axis_index(m_ax)
            L_loc = ckv.shape[1]
            local = pos - m * L_loc
            in_range = (local >= 0) & (local < L_loc)
            idx = jnp.clip(local, 0, L_loc - 1)
            cur_c = jax.lax.dynamic_slice(ckv, (0, idx, 0), c_new.shape)
            cur_p = jax.lax.dynamic_slice(kpe, (0, idx, 0), kpe_new.shape)
            ckv = jax.lax.dynamic_update_slice(
                ckv, jnp.where(in_range, c_new.astype(ckv.dtype), cur_c), (0, idx, 0))
            kpe = jax.lax.dynamic_update_slice(
                kpe, jnp.where(in_range, kpe_new.astype(kpe.dtype), cur_p), (0, idx, 0))
            kj = m * L_loc + jnp.arange(L_loc)
            valid = kj <= pos
            ckv_c = ckv.astype(q_lat.dtype)
            s = (
                jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv_c,
                           preferred_element_type=jnp.float32)
                + jnp.einsum("bqhd,bkd->bhqk", q_rope, kpe.astype(q_lat.dtype),
                             preferred_element_type=jnp.float32)
            ) * scale
            s = jnp.where(valid[None, None, None, :], s, -1e30)
            mx = jax.lax.pmax(jnp.max(s, axis=-1), m_ax)
            pexp = jnp.exp(s - mx[..., None])
            l = jax.lax.psum(jnp.sum(pexp, axis=-1), m_ax)
            o_lat = jnp.einsum("bhqk,bkr->bhqr", pexp.astype(q_lat.dtype), ckv_c)
            o_lat = jax.lax.psum(o_lat.astype(jnp.float32), m_ax)
            o_lat = (o_lat / jnp.maximum(l[..., None], 1e-30)).astype(q_lat.dtype)
            return jnp.moveaxis(o_lat, 1, 2), ckv, kpe  # (B,1,H,rkv)

        o_lat, ckv, kpe = shard_map(
            body, mesh=mesh,
            in_specs=(
                PS(bspec, None, None, None), PS(bspec, None, None, None),
                PS(bspec, m_ax, None), PS(bspec, m_ax, None),
                PS(bspec, None, None), PS(bspec, None, None), PS(),
            ),
            out_specs=(
                PS(bspec, None, None, None),
                PS(bspec, m_ax, None), PS(bspec, m_ax, None),
            ),
            check_vma=False,
        )(q_lat, q_rope, kv_cache["ckv"], kv_cache["kpe"], c_new, kpe_new,
          decode_pos)
        new_cache = {"ckv": ckv, "kpe": kpe}
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wv_b)
    else:
        # absorbed decode
        pos = decode_pos
        c_new, kpe_new = _mla_kv_latent(p, x, cfg, positions)
        ckv = jax.lax.dynamic_update_slice(
            kv_cache["ckv"], c_new.astype(kv_cache["ckv"].dtype), (0, pos, 0)
        )
        kpe = jax.lax.dynamic_update_slice(
            kv_cache["kpe"], kpe_new.astype(kv_cache["kpe"].dtype), (0, pos, 0)
        )
        new_cache = {"ckv": ckv, "kpe": kpe}
        L = ckv.shape[1]
        wk_b = p["wk_b"].astype(x.dtype).reshape(rkv, H, dn)
        wv_b = p["wv_b"].astype(x.dtype).reshape(rkv, H, dv)
        # absorb: q_lat = q_nope @ W_UK  -> (B,S,H,rkv)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)
        ckv_c = ckv.astype(x.dtype)
        scores = (
            jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv_c, preferred_element_type=jnp.float32)
            + jnp.einsum("bqhd,bkd->bhqk", q_rope, kpe.astype(x.dtype),
                         preferred_element_type=jnp.float32)
        ) * scale
        valid = jnp.arange(L) <= pos
        scores = scores + jnp.where(valid, 0.0, -1e30)[None, None, None, :]
        if policy is not None and policy.active:
            scores = constrain(scores, policy, policy.data_axes, policy.model_axis, None, None)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhqk,bkr->bqhr", probs, ckv_c)  # latent read-out
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wv_b)

    out = out.reshape(B, S, H * dv)
    y = out @ p["wo"].astype(out.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        return {
            "w_gate": _dense_init(ks[0], (D, F), cfg.param_dtype),
            "w_up": _dense_init(ks[1], (D, F), cfg.param_dtype),
            "w_down": _dense_init(ks[2], (F, D), cfg.param_dtype),
        }
    return {
        "w_up": _dense_init(ks[0], (D, F), cfg.param_dtype),
        "w_down": _dense_init(ks[1], (F, D), cfg.param_dtype),
        "b_up": jnp.zeros((F,), cfg.param_dtype),
        "b_down": jnp.zeros((D,), cfg.param_dtype),
    }


def apply_mlp(p, x: jax.Array, cfg: ModelConfig,
              policy: ShardingPolicy | None = None) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype))
    if policy is not None and policy.active:
        h = constrain(h, policy, policy.data_axes, None, policy.model_axis)
    y = h @ p["w_down"].astype(x.dtype)
    if "b_down" in p:
        y = y + p["b_down"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# MoE: shared experts + routed top-k with expert parallelism
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig):
    D, E, F = cfg.d_model, cfg.padded_n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (D, E), cfg.param_dtype, scale=0.02),
        "we_gate": _dense_init(ks[1], (E, D, F), cfg.param_dtype),
        "we_up": _dense_init(ks[2], (E, D, F), cfg.param_dtype),
        "we_down": _dense_init(ks[3], (E, F, D), cfg.param_dtype),
    }
    if cfg.n_shared_experts > 0:
        sf = cfg.shared_d_ff or cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = init_mlp(ks[4], cfg, d_ff=sf)
    return p


def _router_probs(p, x_flat: jax.Array, cfg: ModelConfig):
    """Router in f32.  Padded (dead) experts get -inf logits."""
    E, E_real = cfg.padded_n_experts, cfg.n_experts
    # f32 accumulation without materializing an f32 copy of (T, D)
    logits = jnp.einsum(
        "td,de->te", x_flat, p["router"].astype(x_flat.dtype),
        preferred_element_type=jnp.float32,
    )
    if E != E_real:
        pad_mask = jnp.arange(E) >= E_real
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)  # (T,k)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    return probs, gate_vals, expert_idx


def moe_aux_loss(probs: jax.Array, expert_idx: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balance loss: E * Σ_e f_e · P_e."""
    E = cfg.padded_n_experts
    T = probs.shape[0]
    counts = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    f = counts / (T * cfg.top_k)
    pmean = jnp.mean(probs, axis=0)
    return E * jnp.sum(f * pmean)


def apply_moe_dense(p, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Reference MoE: every expert computed densely for every token, combined
    with top-k gates.  O(T·E·D·F) — only for small/smoke configs and as the
    correctness oracle for the expert-parallel path."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    probs, gates, idx = _router_probs(p, xf, cfg)
    # (T, E, F) all-expert forward
    h = jnp.einsum("td,edf->tef", xf, p["we_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xf, p["we_up"].astype(x.dtype))
    eo = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["we_down"].astype(x.dtype))
    onehot = jax.nn.one_hot(idx, cfg.padded_n_experts, dtype=x.dtype)  # (T,k,E)
    comb = jnp.einsum("tk,tke->te", gates.astype(x.dtype), onehot)
    y = jnp.einsum("te,ted->td", comb, eo)
    aux = moe_aux_loss(probs, idx, cfg)
    y = y.reshape(B, S, D)
    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg)
    return y, aux


def apply_moe_ep(
    p, x: jax.Array, cfg: ModelConfig, policy: ShardingPolicy
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE under ``shard_map`` over the model axis.

    Baseline formulation (DESIGN.md §5): tokens replicated over ``model``;
    each shard owns E/model_size experts, dispatches only assignments routed
    to its local experts into a capacity-padded ``(E_loc, C, D)`` buffer, runs
    the batched expert matmuls, and contributes its partial combine via one
    ``psum``.  No all-to-all; communication is a single (T, D) reduce.
    The §Perf hillclimb replaces this with an all-to-all dispatch for the
    train shapes.
    """
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = policy.mesh
    msize = policy.model_size
    E = cfg.padded_n_experts
    assert E % msize == 0, (E, msize)
    E_loc = E // msize
    B, S, D = x.shape
    T = B * S
    # static capacity per expert (per data shard)
    data_size = 1
    for a in policy.data_axes:
        data_size *= mesh.shape[a]
    T_loc = max(T // data_size, 1)
    C = max(int(math.ceil(T_loc * cfg.top_k / E * cfg.capacity_factor)), cfg.top_k)

    fsdp = policy.fsdp_params
    da = policy.data_axes
    dsize = 1
    for a in da:
        dsize *= mesh.shape[a]

    # --- decode variant (§Perf cycle 7): weights-stationary 2D EP ----------
    # One token per sequence: gathering all B·1 tokens costs ~MBs while
    # gathering FSDP expert weights costs ~GBs per layer.  Shard experts over
    # model × data (E/256 per chip, never moved), replicate the tiny token
    # set, psum contributions over the whole mesh.
    if (S == 1 and policy.serving and fsdp
            and E % (msize * dsize) == 0 and B % dsize == 0):
        E_loc2 = E // (msize * dsize)

        def body_decode(router, wg, wu, wd, xb):
            m = jax.lax.axis_index(policy.model_axis)
            d = jax.lax.axis_index(da)
            xg = jax.lax.all_gather(xb, da, axis=0, tiled=True)  # (B,1,D)
            xf = xg.reshape(-1, D)
            probs, gates, idx = _router_probs({"router": router}, xf, cfg)
            aux = moe_aux_loss(probs, idx, cfg)
            e0 = (m * dsize + d) * E_loc2  # my expert block start
            # per-token gate for each of my local experts: (T, E_loc2)
            local_ids = e0 + jnp.arange(E_loc2)
            sel = (idx[:, :, None] == local_ids[None, None, :])
            gate_e = jnp.sum(jnp.where(sel, gates[:, :, None], 0.0), axis=1)
            h = jnp.einsum("td,edf->tef", xf, wg.astype(xf.dtype))
            u = jnp.einsum("td,edf->tef", xf, wu.astype(xf.dtype))
            yc = jnp.einsum(
                "tef,efd->td",
                jax.nn.silu(h) * u * gate_e.astype(h.dtype)[:, :, None],
                wd.astype(xf.dtype),
            )
            y = jax.lax.psum(yc, (policy.model_axis, *da))  # (T, D) full batch
            B_loc = xb.shape[0]
            y = jax.lax.dynamic_slice(y, (d * B_loc, 0), (B_loc, D))
            return y.reshape(xb.shape), jax.lax.pmean(aux, policy.model_axis)

        e_spec = P((policy.model_axis, *da))
        y, aux = shard_map(
            body_decode,
            mesh=mesh,
            in_specs=(P(), e_spec, e_spec, e_spec, P(da, None, None)),
            out_specs=(P(da, None, None), P()),
            check_vma=False,
        )(p["router"], p["we_gate"], p["we_up"], p["we_down"], x)
        if "shared" in p:
            y = y + apply_mlp(p["shared"], x, cfg, policy)
        return y, aux

    def body(router, we_gate, we_up, we_down, xb):
        # xb: (B_loc, S, D) — replicated over model, sharded over data.
        # Expert weights arrive FSDP-sharded (E_loc, D/|data|, F) and are
        # gathered just-in-time (ZeRO-3 style): persistent storage stays
        # fully sharded, only one layer's experts are ever materialized.
        if fsdp:
            we_gate = jax.lax.all_gather(we_gate, da, axis=1, tiled=True)
            we_up = jax.lax.all_gather(we_up, da, axis=1, tiled=True)
            we_down = jax.lax.all_gather(we_down, da, axis=2, tiled=True)
        m = jax.lax.axis_index(policy.model_axis)
        xf = xb.reshape(-1, D)
        t_loc = xf.shape[0]
        probs, gates, idx = _router_probs({"router": router}, xf, cfg)
        aux = moe_aux_loss(probs, idx, cfg)

        flat_e = idx.reshape(-1)  # (T*k,)
        flat_g = gates.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_loc), cfg.top_k)
        local_e = flat_e - m * E_loc
        is_local = (local_e >= 0) & (local_e < E_loc)

        # rank of each assignment within its (local) expert, via sort
        sort_key = jnp.where(is_local, local_e, E_loc)  # non-local last
        order = jnp.argsort(sort_key, stable=True)
        sorted_e = sort_key[order]
        # position within expert = index - start offset of that expert
        counts = jnp.bincount(sorted_e, length=E_loc + 1)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])[:-1]
        ranks_sorted = jnp.arange(sorted_e.shape[0]) - starts[jnp.clip(sorted_e, 0, E_loc)]
        ranks = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)

        keep = is_local & (ranks < C)
        slot = jnp.where(keep, local_e * C + ranks, E_loc * C)  # overflow slot

        # Work in SLOT space (E_loc*C ≈ T·k·cf/model_size entries), never in
        # assignment space (T·k entries): the (T·k, D) gathers would dominate
        # the step's memory (14 GiB/layer for deepseek-v3 train_4k).
        n_slots = E_loc * C
        tok_per_slot = jnp.full((n_slots + 1,), t_loc, jnp.int32).at[slot].set(
            flat_t.astype(jnp.int32)
        )[:n_slots]
        gate_per_slot = jnp.zeros((n_slots + 1,), jnp.float32).at[slot].set(
            jnp.where(keep, flat_g, 0.0)
        )[:n_slots]
        valid_slot = jnp.zeros((n_slots + 1,), bool).at[slot].set(keep)[:n_slots]

        xf_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
        buf = xf_pad[tok_per_slot] * valid_slot[:, None].astype(xf.dtype)
        buf = buf.reshape(E_loc, C, D)

        h = jnp.einsum("ecd,edf->ecf", buf, we_gate.astype(xb.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, we_up.astype(xb.dtype))
        eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, we_down.astype(xb.dtype))

        contrib = eo.reshape(n_slots, D) * gate_per_slot[:, None].astype(eo.dtype)
        y_part = jnp.zeros((t_loc + 1, D), xb.dtype).at[tok_per_slot].add(contrib)[:t_loc]
        y = jax.lax.psum(y_part, policy.model_axis)
        aux = jax.lax.pmean(aux, policy.model_axis)
        return y.reshape(xb.shape), aux

    m_ax = policy.model_axis
    if fsdp:
        spec_gu = P(m_ax, da, None)  # matches param_specs FSDP layout
        spec_d = P(m_ax, None, da)
    else:
        spec_gu = spec_d = P(m_ax)
    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), spec_gu, spec_gu, spec_d, P(da, None, None)),
        out_specs=(P(da, None, None), P()),
        check_vma=False,
    )(p["router"], p["we_gate"], p["we_up"], p["we_down"], x)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg, policy)
    return y, aux


def apply_moe(p, x, cfg: ModelConfig, policy: ShardingPolicy | None):
    if policy is not None and policy.active:
        return apply_moe_ep(p, x, cfg, policy)
    return apply_moe_dense(p, x, cfg)


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state space duality)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig):
    D = cfg.d_model
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj_out = 2 * di + 2 * N + H  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense_init(ks[0], (D, proj_out), cfg.param_dtype),
        "conv_w": _dense_init(ks[1], (cfg.conv_width, di + 2 * N), cfg.param_dtype, scale=0.2),
        "conv_b": jnp.zeros((di + 2 * N,), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(cfg.param_dtype),
        "D_skip": jnp.ones((H,), cfg.param_dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(cfg.param_dtype),
        "norm": jnp.ones((di,), cfg.param_dtype),
        "out_proj": _dense_init(ks[2], (di, D), cfg.param_dtype),
    }


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. xBC: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(W):
        out = out + pad[:, i : i + xBC.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xBC.dtype)


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs; dt: (B,S,H) (positive); A: (H,) (negative);
    Bm, Cm: (B,S,N) (single group).  Returns y: (B,S,H,P).
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:  # pad tail with zeros (dt=0 -> unit decay, B=0 -> no state writes)
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S_pad = S + pad
    else:
        S_pad = S
    nc = S_pad // Q

    xc = xh.reshape(Bsz, nc, Q, H, Pd)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    del xh, dt, Bm, Cm

    a = dtc * A  # (B,nc,Q,H) log-decay per step (negative)
    cum_a = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (diagonal block) ----
    # L[t,s] = exp(cum_a[t] - cum_a[s]) for t >= s (decay from s+1..t)
    rel = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bctn,bcsn->bcts", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    M = scores[..., None] * Lmat  # (B,nc,Q,Q,H)
    xdt = xc.astype(jnp.float32) * dtc[..., None].astype(jnp.float32)
    y_diag = jnp.einsum("bctsh,bcshp->bcthp", M, xdt)

    # ---- chunk states ----
    # state_c = Σ_s exp(cum_a[Q-1] - cum_a[s]) dt_s B_s ⊗ x_s  : (B,nc,H,P,N)
    decay_to_end = jnp.exp(cum_a[:, :, -1:, :] - cum_a)  # (B,nc,Q,H)
    st = jnp.einsum(
        "bcsh,bcsn,bcshp->bchpn",
        (decay_to_end * dtc).astype(jnp.float32),
        Bc.astype(jnp.float32),
        xc.astype(jnp.float32),
    )

    # ---- inter-chunk recurrence (sequential over nc chunks) ----
    chunk_decay = jnp.exp(cum_a[:, :, -1, :])  # (B,nc,H)

    def step(carry, inp):
        st_c, dec_c = inp  # (B,H,P,N), (B,H)
        new = carry * dec_c[:, :, None, None] + st_c
        return new, carry  # emit state *entering* this chunk

    init = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    _, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(st, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,P,N)

    # ---- inter-chunk contribution ----
    y_off = jnp.einsum(
        "bctn,bcth,bchpn->bcthp",
        Cc.astype(jnp.float32), jnp.exp(cum_a), prev_states,
    )

    y = (y_diag + y_off).reshape(Bsz, S_pad, H, Pd)
    return y[:, :S]


def apply_mamba(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    policy: ShardingPolicy | None = None,
    cache: dict | None = None,  # {"conv": (B,W-1,di+2N), "ssm": (B,H,P,N)}
    decode_pos: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    """Mamba2 mixer.  Train/prefill: chunked SSD.  Decode: O(1) recurrence."""
    B, S, D = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    Pd = cfg.ssm_head_dim

    proj = x @ p["in_proj"].astype(x.dtype)  # (B,S,2di+2N+H)
    z, xi, Bm, Cm, dt_raw = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    xBC = jnp.concatenate([xi, Bm, Cm], axis=-1)

    new_cache = None
    if cache is None:
        xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    else:
        # decode: use conv window cache (holds previous W-1 inputs)
        W = cfg.conv_width
        window = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)  # (B,W,ch)
        acc = jnp.zeros_like(xBC, dtype=jnp.float32)
        for i in range(W):
            acc = acc + window[:, i : i + 1, :].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
        xBC = jax.nn.silu(acc + p["conv_b"].astype(jnp.float32)).astype(xBC.dtype)
        new_conv = window[:, 1:, :]

    xi, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xi.reshape(B, S, H, Pd)
    if policy is not None and policy.active and policy.shard_ssm_heads:
        xh = constrain(xh, policy, policy.data_axes, None, policy.model_axis, None)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if cache is None:
        y = _ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    else:
        # one-step recurrence
        st = cache["ssm"].astype(jnp.float32)  # (B,H,P,N)
        a1 = jnp.exp(dt[:, 0, :] * A)  # (B,H)
        dBx = jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, 0, :], Bm[:, 0, :].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        st = st * a1[:, :, None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", st, Cm[:, 0, :].astype(jnp.float32))[:, None]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": st.astype(cache["ssm"].dtype)}

    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di)
    # gated RMS norm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(ms + 1e-6) * p["norm"].astype(jnp.float32)
    out = y.astype(x.dtype) @ p["out_proj"].astype(x.dtype)
    return out, new_cache
