"""Version-compat shims for the pinned JAX toolchain.

``jax.shard_map`` became a top-level export (with the ``check_vma`` kwarg)
only in newer JAX; on the 0.4.x toolchain this container bakes in it lives in
``jax.experimental.shard_map`` and the kwarg is called ``check_rep``.
Likewise ``jax.sharding.AxisType`` (explicit-sharding axis modes) does not
exist on 0.4.x, where every mesh axis is implicitly Auto.  Import
:func:`shard_map` / :func:`make_auto_mesh` from here so every call site works
on both.
"""

from __future__ import annotations

__all__ = ["shard_map", "make_auto_mesh"]

try:
    from jax import shard_map  # noqa: F401  (JAX >= 0.6: check_vma spelling)
except ImportError:  # pragma: no cover - depends on installed JAX
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, /, *args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map_experimental(f, *args, **kwargs)


def make_auto_mesh(shape, axes):
    """``jax.make_mesh`` with every axis in Auto mode, on any JAX version."""
    import jax

    try:
        from jax.sharding import AxisType
    except ImportError:  # pragma: no cover - depends on installed JAX
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
