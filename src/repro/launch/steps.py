"""Step-function builders: train_step / prefill_step / serve_step per config.

These are the functions the dry-run lowers and the examples execute.  All of
them are pure (params, state, batch) -> outputs so they jit/pjit directly.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import kvcache, transformer
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingPolicy
from repro.optim import Optimizer

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step"]


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    policy: ShardingPolicy | None = None):
    """(params, opt_state, batch) -> (params, opt_state, loss)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: transformer.lm_loss(p, batch, cfg, policy=policy)
        )(params)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ModelConfig, policy: ShardingPolicy | None = None):
    """(params, batch) -> (next_tokens, last_logit_stats).

    Serving-shaped prefill: runs the full forward and emits the next token
    for every sequence (greedy).  Cache materialization for the subsequent
    decode is exercised by the decode shapes; returning full 32k logits would
    be a multi-hundred-GB artifact, so the step reduces to next-token output
    exactly like a production prefill server.
    """

    def prefill_step(params, batch):
        logits, _, _ = transformer.forward(
            params, batch["tokens"], cfg, policy=policy,
            prefix_embeds=batch.get("prefix_embeds"),
            frames=batch.get("frames"),
        )
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok.astype(jnp.int32)

    return prefill_step


def make_serve_step(cfg: ModelConfig, policy: ShardingPolicy | None = None):
    """(params, caches, tokens (B,1), pos, [memory]) -> (next (B,1), caches)."""

    def serve_step(params, caches, tokens, pos, memory=None):
        logits, new_caches = transformer.decode_step(
            params, tokens, caches, pos, cfg, policy=policy, memory=memory
        )
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True)
        return next_tok.astype(jnp.int32), new_caches

    return serve_step
