"""Roofline term extraction from compiled dry-run artifacts.

Per (arch × shape × mesh):

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` reports the *per-device* program (the SPMD
module is already partitioned), so terms divide by per-chip peaks directly —
this matches the spec's ``global / (chips × peak)`` formulation.

Collective bytes are not in cost_analysis: we parse the compiled HLO and sum
per-op traffic estimates (output-shape bytes × a ring-algorithm multiplier ×
(g-1)/g for group size g).  This is an estimate of link traffic, good to the
multiplier's fidelity; the relative ordering across configs — which is what
the §Perf loop optimizes — is robust to it.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

import numpy as np

from repro.launch.mesh import HARDWARE

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms", "model_flops"]

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
# traffic multiplier per output byte for ring algorithms
_COLLECTIVES = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,  # per-device sends ~input/g ... counted on output
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    bytes_per_chip: dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_per_chip.values())


def _line_out_bytes(line: str, op: str) -> float:
    """Bytes of the op's output type; handles tuple outputs like
    ``%x = (f32[2000]{0}, f32[]) all-reduce(...)``."""
    rhs = line.split("=", 1)[1]
    # shapes before the op invocation are the output type; after it, operands
    m = re.search(rf"\b{op}(-start|-done)?\(", rhs)
    head = rhs[: m.start()] if m else (rhs.split("(", 1)[0] if "(" in rhs else rhs)
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    bytes_pc: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        for op, mult in _COLLECTIVES.items():
            # match op invocation, not metadata mentions
            if re.search(rf"= .*\b{op}(-start)?\(", ls) or re.search(
                rf"= {op}(-start)?\(", ls
            ):
                g = _group_size(ls, n_devices)
                if g <= 1:
                    continue
                out_b = _line_out_bytes(ls, op)
                counts[op] += 1
                bytes_pc[op] += out_b * mult * (g - 1) / g
                break
    return CollectiveStats(counts=counts, bytes_per_chip=bytes_pc)


def roofline_terms(
    flops_per_chip: float,
    bytes_per_chip: float,
    collective_bytes_per_chip: float,
    hw: dict | None = None,
) -> dict:
    hw = hw or HARDWARE
    compute_s = flops_per_chip / hw["peak_flops_bf16"]
    memory_s = bytes_per_chip / hw["hbm_bandwidth"]
    collective_s = collective_bytes_per_chip / hw["ici_link_bandwidth"]
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward passes."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * n_tokens
