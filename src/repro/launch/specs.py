"""Sharding specs and abstract inputs for every (arch × shape × mesh) combo.

``param_specs`` walks the abstract parameter pytree and assigns a
PartitionSpec per leaf from path-based rules (DESIGN.md §5):

* Megatron TP over ``model``: attention head projections (iff head counts
  divide the axis), MLP d_ff, MoE experts, vocab;
* FSDP over the data axes for large configs (``policy.fsdp_params``):
  the ``d_model`` sides of weight matrices additionally shard over
  ``("pod","data")`` so no chip holds a full replica;
* Mamba in_proj keeps its fused output dim replicated (the z/x/B/C/dt concat
  boundary does not align with a 16-way tiling — splitting the projection is
  a recorded §Perf hillclimb candidate).

``input_specs`` produces ShapeDtypeStructs *with shardings attached* for
train / prefill / decode steps — the dry-run lowers against these, so no
host memory is ever allocated for the full-scale shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES
from repro.models import kvcache, transformer
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingPolicy, make_policy

__all__ = ["param_specs", "opt_state_specs", "input_specs", "batch_specs", "cache_specs"]


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def _leaf_spec(path: str, leaf, cfg: ModelConfig, pol: ShardingPolicy) -> P:
    ndim = len(leaf.shape)
    m = pol.model_axis
    f = pol.data_axes if pol.fsdp_params else None
    stacked = "segments" in path or "'layer'" in path  # leading scan dim

    def pad(spec: tuple) -> P:
        """Left-pad with None for the stacked scan dimension."""
        if stacked:
            return P(None, *spec)
        return P(*spec)

    def dims(spec: tuple, want: int) -> P:
        assert len(spec) == want, (path, leaf.shape, spec)
        return pad(spec)

    name = path.rsplit("'", 2)[-2] if "'" in path else path

    base = ndim - (1 if stacked else 0)

    if name in ("embed",):
        return P(m, f)
    if name == "lm_head":
        return P(f, m)
    if name == "frontend_proj":
        return P(None, f)
    if name == "proj":  # mtp 2D->D projection
        return P(f, None)
    if name in ("wq",):
        if pol.serving and not pol.fsdp_params and not pol.shard_q_heads:
            return dims((m, None), 2)  # contraction-dim TP (psum'd matmul)
        return dims((f, m if pol.shard_q_heads else None), 2)
    if name in ("wk", "wv"):
        if pol.serving and not pol.fsdp_params and not pol.shard_kv_heads:
            return dims((m, None), 2)
        return dims((f, m if pol.shard_kv_heads else None), 2)
    if name == "wo":
        if pol.serving and not pol.fsdp_params and not pol.shard_q_heads:
            return dims((None, m), 2)
        return dims((m if pol.shard_q_heads else None, f), 2)
    if name in ("bq",):
        return dims((m if pol.shard_q_heads else None,), 1)
    if name in ("bk", "bv"):
        return dims((m if pol.shard_kv_heads else None,), 1)
    # MLA
    if name in ("wq_a", "wkv_a"):
        return dims((f, None), 2)
    if name in ("wq_b", "wk_b", "wv_b"):
        return dims((None, m), 2)  # head-major output dim; 128 heads % 16 == 0
    # MLP
    if name in ("w_gate", "w_up"):
        return dims((f, m), 2)
    if name == "w_down":
        return dims((m, f), 2)
    if name == "b_up":
        return dims((m,), 1)
    if name == "b_down":
        return dims((None,), 1)
    # MoE
    if name == "router":
        return dims((None, None), 2)
    if name in ("we_gate", "we_up", "we_down"):
        if pol.serving and pol.fsdp_params:
            # weights-stationary 2D EP decode layout (§Perf cycle 7)
            return dims(((m, *pol.data_axes), None, None), 3)
        if name == "we_down":
            return dims((m, None, f), 3)
        return dims((m, f, None), 3)
    # Mamba
    if name == "in_proj":
        return dims((f, None), 2)
    if name == "out_proj":
        return dims((None, f), 2)
    if name in ("conv_w", "conv_b", "A_log", "D_skip", "dt_bias"):
        return pad(tuple([None] * base))
    # norms / scales / everything small: replicated (keep scan dim unsharded)
    return pad(tuple([None] * base))


def param_specs(cfg: ModelConfig, pol: ShardingPolicy, abstract=None):
    """PartitionSpec pytree matching ``transformer.abstract_params(cfg)``."""
    if abstract is None:
        abstract = transformer.abstract_params(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract)
    specs = [
        _leaf_spec(jax.tree_util.keystr(path), leaf, cfg, pol) for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(optimizer_name: str, p_specs, abstract_params):
    """Optimizer-state specs derived from the param specs."""
    import jax.numpy as jnp

    if optimizer_name == "sgd":
        return ()
    if optimizer_name in ("adam", "adamw"):
        from repro.optim.optimizers import AdamState

        return AdamState(step=P(), m=p_specs, v=p_specs)
    if optimizer_name == "momentum":
        return p_specs
    if optimizer_name == "adafactor":
        from repro.optim.optimizers import AdafactorState

        def drop_last(spec, leaf):
            t = tuple(spec) if spec is not None else (None,) * len(leaf.shape)
            t = t + (None,) * (len(leaf.shape) - len(t))
            return P(*t[:-1]) if len(leaf.shape) >= 2 else P()

        def drop_second_last(spec, leaf):
            t = tuple(spec) if spec is not None else (None,) * len(leaf.shape)
            t = t + (None,) * (len(leaf.shape) - len(t))
            return P(*t[:-2], t[-1]) if len(leaf.shape) >= 2 else P()

        def full(spec, leaf):
            return P() if len(leaf.shape) >= 2 else (spec or P())

        tm = jax.tree_util.tree_map
        return AdafactorState(
            step=P(),
            vr=tm(drop_last, p_specs, abstract_params,
                  is_leaf=lambda x: isinstance(x, P)),
            vc=tm(drop_second_last, p_specs, abstract_params,
                  is_leaf=lambda x: isinstance(x, P)),
            v=tm(full, p_specs, abstract_params, is_leaf=lambda x: isinstance(x, P)),
        )
    raise ValueError(optimizer_name)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, pol: ShardingPolicy, shape_name: str) -> dict:
    """Abstract train/prefill batch with shardings."""
    info = INPUT_SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    da = pol.data_axes
    out: dict[str, Any] = {}
    n_text = S
    if cfg.frontend == "vision_stub":
        n_text = S - cfg.num_prefix_tokens
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_prefix_tokens, cfg.frontend_dim), jnp.bfloat16,
            sharding=_ns(pol, P(da, None, None)),
        )
    if cfg.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.frontend_dim), jnp.bfloat16,
            sharding=_ns(pol, P(da, None, None)),
        )
    out["tokens"] = jax.ShapeDtypeStruct(
        (B, n_text), jnp.int32, sharding=_ns(pol, P(da, None))
    )
    out["labels"] = jax.ShapeDtypeStruct(
        (B, n_text), jnp.int32, sharding=_ns(pol, P(da, None))
    )
    return out


def _ns(pol: ShardingPolicy, spec: P) -> NamedSharding:
    return NamedSharding(pol.mesh, spec)


def _cache_leaf_spec(path: str, leaf, cfg: ModelConfig, pol: ShardingPolicy,
                     batch: int) -> P:
    """Cache leaves: (repeats, B, ...) — B over data when divisible, then
    heads over model when divisible else sequence over model."""
    m, da = pol.model_axis, pol.data_axes
    dsize = 1
    for a in da:
        dsize *= pol.mesh.shape[a]
    bspec = da if batch % dsize == 0 and batch >= dsize else None

    name = path.rsplit("'", 2)[-2]
    if name in ("k", "v"):  # (rep, B, L, KVH, hd)
        if pol.shard_kv_heads:
            return P(None, bspec, None, m, None)
        return P(None, bspec, m, None, None)  # sequence-sharded cache
    if name in ("ckv", "kpe"):  # (rep, B, L, r)
        return P(None, bspec, m, None)
    if name == "conv":  # (rep, B, W-1, ch)
        return P(None, bspec, None, None)
    if name == "ssm":  # (rep, B, H, P, N)
        if pol.shard_ssm_heads:
            return P(None, bspec, m, None, None)
        return P(None, bspec, None, None, None)
    return P(*([None] * len(leaf.shape)))


def cache_specs(cfg: ModelConfig, pol: ShardingPolicy, batch: int, max_len: int):
    """(abstract_cache_with_shardings, spec_pytree)."""
    abstract = kvcache.abstract_cache(cfg, batch, max_len)
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract)
    specs, structs = [], []
    for path, leaf in flat:
        spec = _cache_leaf_spec(jax.tree_util.keystr(path), leaf, cfg, pol, batch)
        specs.append(spec)
        structs.append(
            jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=_ns(pol, spec))
        )
    return (
        jax.tree_util.tree_unflatten(treedef, structs),
        jax.tree_util.tree_unflatten(treedef, specs),
    )


# ---------------------------------------------------------------------------
# full dry-run input assembly
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, pol: ShardingPolicy, shape_name: str,
                optimizer_name: str = "adamw") -> dict:
    """Everything a step function needs, as sharded ShapeDtypeStructs."""
    info = INPUT_SHAPES[shape_name]
    kind = info["kind"]
    abstract = transformer.abstract_params(cfg)
    p_specs = param_specs(cfg, pol, abstract)
    params = jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=_ns(pol, s)),
        abstract, p_specs, is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
    out = {"params": params, "param_specs": p_specs}

    if kind == "train":
        from repro import optim as optim_mod

        opt = getattr(optim_mod, optimizer_name)(1e-4)
        o_abstract = jax.eval_shape(opt.init, abstract)
        o_specs = opt_state_specs(optimizer_name, p_specs, abstract)
        out["opt_state"] = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=_ns(pol, s)),
            o_abstract, o_specs, is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
        )
        out["opt_specs"] = o_specs
        out["batch"] = batch_specs(cfg, pol, shape_name)
        out["optimizer"] = opt
    elif kind == "prefill":
        out["batch"] = batch_specs(cfg, pol, shape_name)
    else:  # decode
        B, L = info["global_batch"], info["seq_len"]
        caches, c_specs = cache_specs(cfg, pol, B, L)
        out["caches"] = caches
        out["cache_specs"] = c_specs
        da = pol.data_axes
        dsize = 1
        for a in da:
            dsize *= pol.mesh.shape[a]
        bspec = da if B % dsize == 0 and B >= dsize else None
        out["tokens"] = jax.ShapeDtypeStruct(
            (B, 1), jnp.int32, sharding=_ns(pol, P(bspec, None))
        )
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32, sharding=_ns(pol, P()))
        if cfg.is_encoder_decoder:
            out["memory"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16,
                sharding=_ns(pol, P(bspec, None, None)),
            )
    return out
