import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective analysis for §Roofline.

MUST be run as a fresh process (the XLA_FLAGS above execute before any jax
import).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod 16x16
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod  # 2x16x16

Results append to experiments/dryrun/<mesh>.jsonl; benchmarks/roofline.py
renders the table in EXPERIMENTS.md from them.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCHITECTURES, INPUT_SHAPES, get_config, shape_applicable,
)
from repro.launch import roofline as rl
from repro.launch.mesh import HARDWARE, make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.config import ModelConfig
from repro.models.sharding import make_policy

# per-arch training-policy overrides (DESIGN.md §4: memory-driven)
ARCH_OVERRIDES: dict[str, dict] = {
    "deepseek-v3-671b": {"param_dtype": jnp.bfloat16},
}
ARCH_OPTIMIZER: dict[str, str] = {
    # adafactor for the configs whose full Adam state cannot fit 16 GB/chip
    "deepseek-v3-671b": "adafactor",
    "qwen2-72b": "adafactor",
    "llava-next-34b": "adafactor",
}


def _arch_config(arch: str, kind: str = "train") -> ModelConfig:
    cfg = get_config(arch)
    if arch in ARCH_OVERRIDES:
        cfg = dataclasses.replace(cfg, **ARCH_OVERRIDES[arch])
    if kind in ("decode", "prefill"):
        # serving layout (§Perf cycle 7): bf16 weights, stationary on-chip —
        # no optimizer state exists, so FSDP gathering is pure overhead.
        cfg = dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
    return cfg


def _serving_fsdp(arch: str, kind: str) -> bool | None:
    """FSDP only where even bf16 weights exceed the model-axis share.

    None -> make_policy heuristic (training).  Serving: False (replicate
    over data, shard over model) except deepseek-v3, whose 1.34 TB of bf16
    experts must stay sharded over both axes.
    """
    if kind != "decode":
        # train AND prefill use the heuristic: weight gathers amortize over
        # the whole sequence of compute (prefill is throughput-bound, and
        # replicating non-head-divisible attention weights costs tens of GiB
        # — measured as a 54.7 GiB llava prefill peak before this fix).
        return None
    # decode: weights-stationary unless even bf16 weights exceed the
    # model-axis share when replicated over data.
    return arch in ("deepseek-v3-671b", "qwen2-72b")


def _lower_compile(cfg, pol, shape, opt_name, mesh):
    """Lower + compile one step; return (compiled, lower_s, compile_s)."""
    kind = INPUT_SHAPES[shape]["kind"]
    ins = input_specs(cfg, pol, shape, optimizer_name=opt_name)
    t0 = time.time()
    with mesh:
        if kind == "train":
            step = make_train_step(cfg, ins["optimizer"], pol)
            lowered = jax.jit(step).lower(ins["params"], ins["opt_state"], ins["batch"])
        elif kind == "prefill":
            step = make_prefill_step(cfg, pol)
            lowered = jax.jit(step).lower(ins["params"], ins["batch"])
        else:
            step = make_serve_step(cfg, pol)
            args = [ins["params"], ins["caches"], ins["tokens"], ins["pos"]]
            if cfg.is_encoder_decoder:
                args.append(ins["memory"])
            lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _costs_of(compiled, n_devices):
    cost = compiled.cost_analysis()
    coll = rl.parse_collectives(compiled.as_text(), n_devices)
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        coll,
    )


def corrected_costs(cfg: ModelConfig, pol, shape: str, opt_name: str, mesh) -> dict:
    """Depth-differencing correction for scan-once cost analysis.

    XLA's HloCostAnalysis counts each while-loop (scan) body ONCE, so the
    full-depth lowering under-reports flops/bytes/collectives by ~the trip
    count.  We lower UNROLLED 1-cycle and 2-cycle variants of the same
    config; their difference is the exact per-cycle cost (embed/head/MTP
    cancel), and the full-depth estimate is

        X_full ≈ X_1cycle + (n_cycles - 1) · ΔX    (+ encoder analog)

    with fractional n_cycles handling pattern remainders (gemma3's trailing
    4 local layers).
    """
    pat = len(cfg.layer_pattern)
    fk = cfg.first_k_dense
    cycles_full = (cfg.n_layers - fk) / pat

    def variant(n_cycles: int, enc_layers: int | None = None):
        changes = dict(
            n_layers=fk + n_cycles * pat,
            scan_layers=False,
        )
        if cfg.is_encoder_decoder:
            changes["n_encoder_layers"] = enc_layers or 1
        c = dataclasses.replace(cfg, **changes)
        compiled, _, _ = _lower_compile(c, pol, shape, opt_name, mesh)
        return _costs_of(compiled, mesh.size)

    f1, b1, c1 = variant(1, enc_layers=1)
    f2, b2, c2 = variant(2, enc_layers=1)
    out = {
        "flops": f1 + (cycles_full - 1) * (f2 - f1),
        "bytes": b1 + (cycles_full - 1) * (b2 - b1),
        "collective_bytes": c1.total_bytes
        + (cycles_full - 1) * (c2.total_bytes - c1.total_bytes),
        "collective_counts_cycle": {
            k: c2.counts[k] - c1.counts[k] for k in c2.counts
        },
        "collective_bytes_by_op": {
            k: c1.bytes_per_chip[k]
            + (cycles_full - 1) * (c2.bytes_per_chip[k] - c1.bytes_per_chip[k])
            for k in c1.bytes_per_chip
        },
    }
    if cfg.is_encoder_decoder:
        f1e, b1e, c1e = variant(1, enc_layers=2)
        enc_cycles = cfg.n_encoder_layers
        out["flops"] += (enc_cycles - 1) * (f1e - f1)
        out["bytes"] += (enc_cycles - 1) * (b1e - b1)
        out["collective_bytes"] += (enc_cycles - 1) * (
            c1e.total_bytes - c1.total_bytes
        )
    return out


def dryrun_one(arch: str, shape: str, multi_pod: bool, hlo_dir: str | None = None) -> dict:
    """Lower + compile one (arch, shape, mesh) combo; return the record."""
    kind = INPUT_SHAPES[shape]["kind"]
    cfg = _arch_config(arch, kind)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    pol = make_policy(cfg, mesh, multi_pod=multi_pod,
                      fsdp=_serving_fsdp(arch, kind), serving=(kind == "decode"))
    opt_name = ARCH_OPTIMIZER.get(arch, "adamw")

    record = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_devices,
        "fsdp": pol.fsdp_params,
        "optimizer": opt_name if kind == "train" else None,
        "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
    }

    ok, reason = shape_applicable(arch, shape)
    if not ok:
        record.update(status="skipped", reason=reason)
        return record

    # 1) full-depth scan lowering: the compile proof + peak-memory analysis
    compiled, t_lower, t_compile = _lower_compile(cfg, pol, shape, opt_name, mesh)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    flops_once, bytes_once, coll_once = _costs_of(compiled, n_devices)

    # 2) depth-differenced per-chip costs (scan bodies counted correctly).
    # Tiny decode steps can difference to noise-level negatives when XLA
    # folds the shallow variants differently — fall back to the scan-once
    # value ONLY then (a blanket max() would double-count collectives that
    # the full lowering hoists out of the loop as one whole-stack op).
    corr = corrected_costs(cfg, pol, shape, opt_name, mesh)
    flops_pc = corr["flops"] if corr["flops"] > 0 else max(flops_once, 0.0)
    bytes_pc = corr["bytes"] if corr["bytes"] > 0 else max(bytes_once, 0.0)
    coll_pc = (corr["collective_bytes"] if corr["collective_bytes"] > 0
               else max(coll_once.total_bytes, 0.0))
    corr["collective_bytes"] = coll_pc
    terms = rl.roofline_terms(flops_pc, bytes_pc, coll_pc)

    # MODEL_FLOPS: useful-math floor, global then per-chip
    n_params = cfg.param_count_estimate()
    n_active = active_params(cfg)
    B, S = INPUT_SHAPES[shape]["global_batch"], INPUT_SHAPES[shape]["seq_len"]
    tokens = B * S if kind in ("train", "prefill") else B  # decode: 1 tok/seq
    mf_global = rl.model_flops(n_active, tokens, kind)
    mf_pc = mf_global / n_devices

    record.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        n_params=n_params,
        n_params_active=n_active,
        argument_size_bytes=getattr(mem, "argument_size_in_bytes", 0),
        output_size_bytes=getattr(mem, "output_size_in_bytes", 0),
        temp_size_bytes=getattr(mem, "temp_size_in_bytes", 0),
        peak_bytes_per_chip=(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ),
        hbm_per_chip=HARDWARE["hbm_bytes"],
        flops_per_chip=flops_pc,
        bytes_per_chip=bytes_pc,
        collective_bytes_per_chip=corr["collective_bytes"],
        collective_counts_full_hlo=coll_once.counts,
        collective_counts_per_cycle=corr["collective_counts_cycle"],
        collective_bytes_by_op=corr["collective_bytes_by_op"],
        flops_per_chip_scan_once=flops_once,
        bytes_per_chip_scan_once=bytes_once,
        model_flops_global=mf_global,
        model_flops_per_chip=mf_pc,
        useful_flops_ratio=(mf_pc / flops_pc) if flops_pc else None,
        **terms,
    )
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        fn = os.path.join(hlo_dir, f"{arch}_{shape}_{record['mesh']}.hlo.txt")
        with open(fn, "w") as f:
            f.write(hlo)
        record["hlo_path"] = fn
    return record


def active_params(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE: top-k + shared experts only)."""
    if not cfg.n_experts:
        return cfg.param_count_estimate()
    total = cfg.param_count_estimate()
    E = cfg.padded_n_experts
    D, F = cfg.d_model, cfg.moe_d_ff
    moe_layers = sum(1 for s in cfg.layer_specs() if s.moe)
    all_expert = moe_layers * E * 3 * D * F
    active_expert = moe_layers * cfg.top_k * 3 * D * F
    return int(total - all_expert + active_expert)


def dryrun_aggregation(arch: str, n_learners: int, multi_pod: bool,
                       hierarchical: bool = False) -> dict:
    """Lower + compile the controller's aggregation step for one arch's
    packed parameter buffer on the production mesh (the paper's Fig. 4
    workload at pod scale).  Paper-faithful mode: (N, P) stack sharded over
    all axes along P — zero collectives expected.  Hierarchical mode
    (beyond paper): one learner per pod, psum over the pod axis.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import aggregation

    cfg = _arch_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    P_total = cfg.param_count_estimate()
    # pad P to divisibility over all mesh axes
    P_pad = ((P_total + n_devices - 1) // n_devices) * n_devices

    record = {
        "arch": f"fedavg-{arch}", "shape": f"N{n_learners}",
        "kind": "aggregate", "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_devices, "n_params": P_total,
        "hierarchical": hierarchical, "status": "ok",
    }
    axes = tuple(mesh.axis_names)
    with mesh:
        if hierarchical:
            assert multi_pod, "hierarchical aggregation needs the pod axis"
            stack = jax.ShapeDtypeStruct(
                (mesh.shape["pod"], P_pad), jnp.float32,
                sharding=NamedSharding(mesh, P("pod", ("data", "model"))),
            )
            w = jax.ShapeDtypeStruct(
                (mesh.shape["pod"],), jnp.float32,
                sharding=NamedSharding(mesh, P("pod")),
            )
            fn = jax.jit(aggregation.hierarchical_fedavg(mesh))
            lowered = fn.lower(stack, w)
        else:
            stack = jax.ShapeDtypeStruct(
                (n_learners, P_pad), jnp.float32,
                sharding=NamedSharding(mesh, P(None, axes)),
            )
            w = jax.ShapeDtypeStruct(
                (n_learners,), jnp.float32, sharding=NamedSharding(mesh, P())
            )
            fn = jax.jit(
                aggregation.weighted_average,
                out_shardings=NamedSharding(mesh, P(axes)),
            )
            lowered = fn.lower(stack, w)
        compiled = lowered.compile()

    from repro.launch import roofline as _rl

    flops, bytes_, coll = _costs_of(compiled, n_devices)
    mem = compiled.memory_analysis()
    terms = _rl.roofline_terms(flops, bytes_, coll.total_bytes)
    record.update(
        peak_bytes_per_chip=mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        flops_per_chip=flops, bytes_per_chip=bytes_,
        collective_bytes_per_chip=coll.total_bytes,
        collective_counts_full_hlo=coll.counts,
        # analytic floor: read N·P + write P floats per chip-share
        model_bytes_per_chip=(n_learners + 1) * P_pad * 4 / n_devices
        if not hierarchical else 2 * P_pad * 4 / n_devices,
        **terms,
    )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHITECTURES)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--agg", action="store_true",
                    help="dry-run the controller aggregation step instead")
    ap.add_argument("--agg-learners", type=int, default=8)
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        "dryrun must see 512 host-platform devices; run as a fresh process"
    )

    if args.agg:
        os.makedirs(args.out_dir, exist_ok=True)
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        out_path = os.path.join(args.out_dir, f"agg_{mesh_tag}.jsonl")
        archs = [args.arch] if args.arch else list(ARCHITECTURES)
        for arch in archs:
            try:
                rec = dryrun_aggregation(
                    arch, args.agg_learners, args.multi_pod, args.hierarchical
                )
            except Exception as e:  # noqa: BLE001
                rec = {"arch": f"fedavg-{arch}", "status": "error", "error": repr(e)}
            with open(out_path, "a") as f:
                f.write(json.dumps(rec) + "\n")
            if rec["status"] == "ok":
                print(
                    f"agg {arch}: P={rec['n_params']/1e9:.1f}B "
                    f"mem={rec['memory_s']*1e3:.2f}ms coll={rec['collective_s']*1e3:.3f}ms "
                    f"colls={sum(rec['collective_counts_full_hlo'].values())} "
                    f"bytes-eff={rec['model_bytes_per_chip']/max(rec['bytes_per_chip'],1):.2f}",
                    flush=True,
                )
            else:
                print(f"agg {arch}: {rec.get('error')}", flush=True)
        return

    combos = []
    if args.all:
        for a in ARCHITECTURES:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    os.makedirs(args.out_dir, exist_ok=True)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    out_path = os.path.join(args.out_dir, f"{mesh_tag}.jsonl")
    hlo_dir = os.path.join(args.out_dir, "hlo") if args.save_hlo else None

    for arch, shape in combos:
        print(f"=== {arch} × {shape} × {mesh_tag} ===", flush=True)
        try:
            rec = dryrun_one(arch, shape, args.multi_pod, hlo_dir)
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            rec = {
                "arch": arch, "shape": shape, "mesh": mesh_tag,
                "status": "error", "error": repr(e),
                "traceback": traceback.format_exc()[-2000:],
            }
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        if rec["status"] == "ok":
            print(
                f"  ok: compile={rec['compile_s']}s "
                f"peak={rec['peak_bytes_per_chip']/2**30:.2f}GiB/chip "
                f"compute={rec['compute_s']*1e3:.2f}ms "
                f"memory={rec['memory_s']*1e3:.2f}ms "
                f"collective={rec['collective_s']*1e3:.2f}ms "
                f"dominant={rec['dominant']}",
                flush=True,
            )
        else:
            print(f"  {rec['status']}: {rec.get('reason', rec.get('error'))}", flush=True)


if __name__ == "__main__":
    main()
