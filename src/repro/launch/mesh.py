"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model").

A FUNCTION, not a module constant — importing this module never touches jax
device state (dryrun.py sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

from repro.compat import make_auto_mesh

__all__ = ["make_production_mesh", "make_debug_mesh", "make_controller_mesh", "HARDWARE"]

# TPU v5e-class constants used by the roofline analysis (launch/roofline.py).
HARDWARE = {
    "peak_flops_bf16": 197e12,  # per chip, FLOP/s
    "hbm_bandwidth": 819e9,  # per chip, B/s
    "ici_link_bandwidth": 50e9,  # per link, B/s
    "hbm_bytes": 16 * 1024**3,  # per chip
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (shard_map paths exercise on 1 device)."""
    return make_auto_mesh((data, model), ("data", "model"))


def make_controller_mesh(n_shards: int | None = None):
    """1-D ``("data",)`` mesh over the controller's local devices.

    The mesh the sharded aggregation arena lays its ``(n_max, P)`` buffer out
    on (``core/store.ArenaStore(mesh=...)``): ``P`` splits over ``data``, rows
    are replication-free, and every row write / masked reduction stays
    collective-free.  ``n_shards`` defaults to every visible device; pass 1
    for a single-device smoke mesh (identical numerics, same code path).
    """
    import jax

    n = int(n_shards) if n_shards else len(jax.devices())
    return make_auto_mesh((n,), ("data",))
