"""Federated training launcher.

Wires the full stack together: configs → models → learners → controller →
driver, with every paper feature selectable from the CLI:

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-14b --reduced --learners 8 --rounds 5 \
        --protocol semi_sync --server-opt fedadam --secure --quantize

``--arch housing-mlp --size 10m`` reproduces the paper's stress-test model.
Full-scale configs are exercised via ``launch/dryrun.py``; this launcher
trains reduced variants (or the 100M example config) on the host.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim as optim_mod
from repro.configs import ARCHITECTURES, get_config, get_reduced
from repro.core import Driver, FederationEnv, Learner, SelectionPolicy, TerminationCriteria
from repro.data import LMDataIterator, dirichlet_partition, iid_partition, make_housing_data, make_lm_data
from repro.models import mlp as mlp_model
from repro.models import transformer
from repro.checkpoint import save_checkpoint

log = logging.getLogger("repro.train")


def build_lm_learners(cfg, n_learners: int, seed: int = 0,
                      n_seq_per_learner: int = 64, seq_len: int = 64,
                      optimizer=None):
    """One learner per silo over a disjoint synthetic token shard."""
    toks = make_lm_data(n_learners * n_seq_per_learner, seq_len, cfg.vocab_size, seed)
    shards = iid_partition(toks.shape[0], n_learners, seed=seed)
    learners = []
    for i, idx in enumerate(shards):
        it = LMDataIterator(toks[idx], seed=seed + i)

        def loss_fn(params, batch, _cfg=cfg):
            return transformer.lm_loss(params, batch, _cfg)

        def eval_fn(params, batch, _cfg=cfg):
            return {"eval_loss": transformer.lm_loss(params, batch, _cfg)}

        def eval_data(_it=it):
            return _it(16)

        learners.append(
            Learner(
                learner_id=f"learner_{i:03d}",
                loss_fn=loss_fn,
                eval_fn=eval_fn,
                data_fn=it,
                eval_data_fn=eval_data,
                optimizer=optimizer or optim_mod.sgd(0.5),
                num_examples=it.n_examples,
            )
        )
    return learners


def build_housing_learners(size: str, n_learners: int, seed: int = 0,
                           per_learner: int = 100, optimizer=None):
    """Paper §4.2 setup: 100 samples per learner, sampled with replacement."""
    from repro.configs import housing_mlp

    cfg = housing_mlp.config(size)
    data = make_housing_data(seed=seed)
    shards = iid_partition(
        data.x.shape[0], n_learners, seed=seed,
        per_learner=per_learner, with_replacement=True,
    )
    learners = []
    for i, idx in enumerate(shards):
        x, y = data.x[idx], data.y[idx]
        rng = np.random.default_rng(seed + i)

        def data_fn(bs, _x=x, _y=y, _rng=rng):
            j = _rng.integers(0, _x.shape[0], size=bs)
            return _x[j], _y[j]

        learners.append(
            Learner(
                learner_id=f"learner_{i:03d}",
                loss_fn=mlp_model.mse_loss,
                eval_fn=lambda p, b: {"eval_loss": mlp_model.mse_loss(p, b)},
                data_fn=data_fn,
                eval_data_fn=lambda _x=x, _y=y: (_x, _y),
                optimizer=optimizer or optim_mod.sgd(0.01),
                num_examples=x.shape[0],
            )
        )
    return cfg, learners


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="housing-mlp",
                    choices=list(ARCHITECTURES) + ["housing-mlp", "fedlm-100m"])
    ap.add_argument("--size", default="1m", help="housing-mlp size: 100k|1m|10m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of an assigned arch")
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--protocol", default="sync", choices=["sync", "semi_sync", "async"])
    ap.add_argument("--server-opt", default="fedavg",
                    choices=["fedavg", "sgdm", "fedadagrad", "fedyogi", "fedadam"])
    ap.add_argument("--selection", default="all", choices=["all", "random", "stratified"])
    ap.add_argument("--fraction", type=float, default=1.0)
    ap.add_argument("--prox-mu", type=float, default=0.0)
    ap.add_argument("--secure", action="store_true")
    ap.add_argument("--quantize", action="store_true",
                    help="int8 transport codec (Pallas kernel)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(levelname)s %(name)s: %(message)s")

    if args.arch == "housing-mlp":
        cfg, learners = build_housing_learners(args.size, args.learners, args.seed)
        initial = mlp_model.init_params(jax.random.key(args.seed), cfg)
    else:
        if args.arch == "fedlm-100m":
            from repro.configs.fedlm_100m import config as fedlm_config

            cfg = fedlm_config()
        else:
            cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
        learners = build_lm_learners(
            cfg, args.learners, args.seed, optimizer=optim_mod.sgd(args.lr)
        )
        initial = transformer.init_params(jax.random.key(args.seed), cfg)

    env = FederationEnv(
        protocol=args.protocol,
        local_steps=args.local_steps,
        batch_size=args.batch_size,
        learning_rate=args.lr,
        prox_mu=args.prox_mu,
        selection=SelectionPolicy(kind=args.selection, fraction=args.fraction),
        server_optimizer=args.server_opt,
        secure_aggregation=args.secure,
        termination=TerminationCriteria(max_rounds=args.rounds),
    )
    driver = Driver(env)
    if args.quantize:
        from repro.kernels.ops import QuantCodec

        driver.controller.channel.codec = QuantCodec()

    t0 = time.time()
    driver.initialize(initial, learners)
    history = driver.run()
    wall = time.time() - t0

    print("\nround,train_dispatch_s,train_round_s,aggregation_s,"
          "eval_dispatch_s,eval_round_s,federation_round_s,eval_loss")
    for h in history:
        r = h.as_row()
        print(
            f"{r['round']},{r['train_dispatch_s']:.4f},{r['train_round_s']:.4f},"
            f"{r['aggregation_s']:.4f},{r['eval_dispatch_s']:.4f},"
            f"{r['eval_round_s']:.4f},{r['federation_round_s']:.4f},"
            f"{h.metrics.get('eval_loss', float('nan')):.5f}"
        )
    stats = driver.controller.channel.stats
    print(f"\ntotal wall: {wall:.2f}s; wire bytes: {stats.bytes_moved:,}; "
          f"messages: {stats.messages}; serialize: {stats.serialize_s:.3f}s")

    if args.checkpoint_dir:
        path = save_checkpoint(
            args.checkpoint_dir, len(history), driver.controller.global_params,
            metadata={"arch": args.arch, "rounds": len(history)},
        )
        print(f"checkpoint: {path}")


if __name__ == "__main__":
    main()
