"""Serving launcher: prefill a batch of requests, then decode tokens.

Runs reduced configs on the host (the full-scale serve steps are lowered by
``launch/dryrun.py``).  Exercises the exact same ``make_serve_step`` that the
dry-run proves on the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b \
        --batch 4 --prompt-len 32 --gen-len 16

``--push-replicas N`` additionally simulates publishing the served weights to
N replica hosts through the federation transport's serialize-once broadcast
(the same ``Channel.broadcast`` the controller's dispatch uses), printing the
measured one-serialization fan-out accounting.  ``--replica-upload raw|int8``
then echoes the weights back per replica through the measured uplink half
(``Channel.upload``) so both wire directions are accounted.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, get_reduced
from repro.launch.steps import make_serve_step
from repro.models import kvcache, transformer


def push_to_replicas(
    params,
    n_replicas: int,
    bandwidth_gbps: float = 10.0,
    replica_upload: str | None = None,
) -> None:
    """Publish model weights to ``n_replicas`` serving hosts, serialize-once.

    One ``Channel.broadcast`` serialization, N shared envelopes; each replica
    deserializes its own copy (one device_put of the whole wire buffer).
    Prints bytes-on-wire and the broadcast-vs-per-send serialization ratio.

    ``replica_upload`` additionally exercises the measured uplink: every
    replica reports its resident weights back through ``Channel.upload``
    (health-check echo) with the given codec (``"raw"`` or ``"int8"``), so
    the printed accounting covers both wire directions — the full-duplex
    contract the federation controller runs on.
    """
    from repro.core import Channel, packing

    ch = Channel(bandwidth_gbps=bandwidth_gbps, upload_codec=replica_upload or "raw")
    t0 = time.time()
    broadcast = ch.broadcast(params=params)
    envelopes = [broadcast.to({"replica": i}) for i in range(n_replicas)]
    replica_params = ch.recv(envelopes[0])  # one replica decodes as a check
    jax.block_until_ready(replica_params)
    elapsed = time.time() - t0
    tm = ch.telemetry  # the unified observability surface (docs/OBSERVABILITY.md)
    print(
        f"push: {n_replicas} replicas, "
        f"{tm.value('channel.bytes_moved')/1e6:.1f}MB on wire, "
        f"{tm.value('channel.serializations')} serialization(s) "
        f"(vs {n_replicas} per-send), "
        f"{elapsed:.3f}s incl. one decode, "
        f"virtual wire {tm.value('channel.virtual_wire_s', 0.0)*1e3:.1f}ms"
    )
    assert tm.value("channel.serializations") == 1
    assert tm.value("channel.messages") == n_replicas
    if replica_upload:
        buf = packing.pack_numeric(replica_params)
        jax.block_until_ready(buf)
        t0 = time.time()
        for i in range(n_replicas):
            env = ch.upload(buf, metadata={"replica": i})
        echo = ch.recv_upload(env)  # the server decodes one echo as a check
        jax.block_until_ready(echo)
        elapsed = time.time() - t0
        down = tm.value("channel.bytes_moved")
        up = tm.value("channel.upload_bytes")
        print(
            f"echo: {n_replicas} uploads ({replica_upload}), "
            f"{up/1e6:.1f}MB on wire "
            f"({down / max(up, 1):.2f}x vs downlink), "
            f"{elapsed:.3f}s incl. one decode, "
            f"virtual wire {tm.value('channel.upload_virtual_wire_s', 0.0)*1e3:.1f}ms"
        )
        assert tm.value("channel.upload_messages") == n_replicas
        # per-replica round-trip estimate — the same bandwidth-model API the
        # federation's wire-cost-aware task sizing consumes
        rt = ch.round_trip_s(down // n_replicas, up // n_replicas)
        print(f"modeled per-replica round-trip: {rt*1e3:.1f}ms "
              f"(push down + {replica_upload} echo up)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b", choices=ARCHITECTURES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--push-replicas", type=int, default=0,
                    help="simulate serialize-once weight push to N replicas")
    ap.add_argument("--replica-upload", choices=("raw", "int8"), default=None,
                    help="also echo weights back per replica through the "
                         "measured uplink with this codec")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = transformer.init_params(jax.random.key(args.seed), cfg)
    if args.push_replicas:
        push_to_replicas(params, args.push_replicas,
                         replica_upload=args.replica_upload)
    B = args.batch
    max_len = args.prompt_len + args.gen_len

    prompts = jax.random.randint(
        jax.random.key(args.seed + 1), (B, args.prompt_len), 0, cfg.vocab_size
    )
    memory = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq_len, cfg.frontend_dim), jnp.float32
        )
        memory = transformer.encode(params, frames, cfg)

    serve_step = jax.jit(make_serve_step(cfg), static_argnames=())

    # prefill by stepping the decoder over the prompt (cache-building path);
    # production prefill uses the fused forward (see dryrun prefill shapes).
    caches = kvcache.init_cache(cfg, B, max_len)
    t0 = time.time()
    tok = prompts[:, :1]
    for t in range(args.prompt_len):
        nxt, caches = serve_step(params, caches, prompts[:, t : t + 1],
                                 jnp.asarray(t, jnp.int32), memory)
    prefill_s = time.time() - t0

    generated = []
    t0 = time.time()
    for t in range(args.prompt_len, max_len):
        nxt, caches = serve_step(params, caches, nxt, jnp.asarray(t, jnp.int32), memory)
        generated.append(nxt)
    jax.block_until_ready(nxt)
    decode_s = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={B}")
    print(f"prefill: {args.prompt_len} steps in {prefill_s:.2f}s")
    print(
        f"decode:  {args.gen_len} tokens in {decode_s:.2f}s "
        f"({B * args.gen_len / decode_s:.1f} tok/s batch-aggregate)"
    )
    print("sample token ids:", out[0, :12].tolist())
    assert not bool(jnp.any(out < 0)) and not bool(jnp.any(out >= cfg.padded_vocab_size))


if __name__ == "__main__":
    main()
