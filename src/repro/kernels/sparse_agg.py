"""Masked scatter-accumulate: weighted sparse rows → one dense row.

The sparse-arena aggregation kernel.  Each valid arena row is a
``(k,)`` stream of ``(index, value)`` pairs; the reduce scatters every
stream's weighted values straight into a ``(P,)`` f32 accumulator —
the dense ``(N, P)`` stack of ``masked_weighted_average`` is never
built, so the reduce moves ``~N·k + P`` floats instead of ``N·P``.

Lowering: one ``jnp.zeros(P).at[idx].add(contrib)`` under jit.  XLA
compiles scatter-add to the TPU's native combining scatter (and to a
serial loop on CPU — the interpret-mode fallback is the same program
under the CPU backend).  A hand-written Pallas scatter would need
per-element dynamic stores or an O(N·k·P) one-hot matmul; the XLA op
*is* the right kernel here, so this module is deliberately plain jnp.

The column-sharded variant buckets indices per shard inside
``shard_map``: every device receives the full (small) index/value
arena replicated, keeps only the coordinates that land in its column
slice, and scatters locally — zero collectives, same trick as the
column-sharded dense reduce (``aggregation.*_sharded``).

Invalid rows are masked with a ``where`` *before* the weight multiply,
so NaN/Inf garbage in never-written arena rows cannot poison the sum
(the same guard as ``aggregation.masked_weighted_average``).  Under
jit, out-of-range scatter indices are dropped by XLA's default clamp
semantics; masked rows additionally rewrite their indices to 0 with a
zero contribution.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["scatter_accumulate", "scatter_accumulate_sharded"]


@partial(jax.jit, static_argnames=("out_width",))
def scatter_accumulate(
    indices: jax.Array,
    values: jax.Array,
    weights: jax.Array,
    mask: jax.Array,
    out_width: int,
) -> jax.Array:
    """Sum masked, weighted sparse rows into a dense ``(out_width,)`` row.

    ``indices``/``values`` are the ``(N, k)`` sparse arena; ``weights``
    is the ``(N,)`` *normalized* weight vector (zero at masked rows);
    ``mask`` is the ``(N,)`` validity mask.  Within one row the indices
    are unique (top-k output), across rows they collide freely — the
    scatter combines with ``add``, which is exactly the weighted sum.
    """
    contrib = jnp.where(mask[:, None] > 0, values, 0.0).astype(jnp.float32)
    contrib = contrib * weights.astype(jnp.float32)[:, None]
    idx = jnp.where(mask[:, None] > 0, indices, 0)
    return (
        jnp.zeros((out_width,), jnp.float32)
        .at[idx.reshape(-1)]
        .add(contrib.reshape(-1))
    )


def scatter_accumulate_sharded(mesh, axes, out_width: int):
    """Build a column-sharded scatter-accumulate over ``mesh``.

    The returned jitted fn has the :func:`scatter_accumulate` signature
    minus ``out_width``.  Inputs are replicated (the sparse arena is
    ``N·k``-small by construction); the output is a ``(out_width,)`` row
    sharded over ``axes``.  Each shard computes its linearized shard id
    from ``axis_index`` (row-major over ``axes``, matching the
    ``PartitionSpec`` linearization), rebases the global indices into
    its local column window, and scatters only the coordinates that fall
    inside it — no ``psum``, no all-gather.
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n_shards = int(np.prod([mesh.shape[a] for a in axes_t]))
    if out_width % n_shards != 0:
        raise ValueError(
            f"out_width {out_width} not divisible by {n_shards} shards"
        )
    local_w = out_width // n_shards

    def _local(indices, values, weights, mask):
        sid = jnp.int32(0)
        for a in axes_t:
            sid = sid * mesh.shape[a] + jax.lax.axis_index(a)
        local_idx = indices - sid * local_w
        ok = (local_idx >= 0) & (local_idx < local_w) & (mask[:, None] > 0)
        contrib = jnp.where(ok, values, 0.0).astype(jnp.float32)
        contrib = contrib * weights.astype(jnp.float32)[:, None]
        local_idx = jnp.where(ok, local_idx, 0)
        return (
            jnp.zeros((local_w,), jnp.float32)
            .at[local_idx.reshape(-1)]
            .add(contrib.reshape(-1))
        )

    return jax.jit(shard_map(
        _local, mesh=mesh,
        in_specs=(P(), P(), P(), P()),
        out_specs=P(axes_t),
        check_vma=False,
    ))
