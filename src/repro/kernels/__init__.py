"""Pallas TPU kernels for the controller's compute hot-spots.

``fedavg.py`` (fused/masked weighted aggregation) and ``quantize.py`` (int8
group quantization for transport) are the raw kernels; ``ops.py`` holds the
jit'd public wrappers (padding + interpret-mode dispatch on CPU) and
``ref.py`` the pure-XLA oracles the kernels are validated against.
"""
