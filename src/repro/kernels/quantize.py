"""Pallas TPU kernels: blockwise int8 quantize/dequantize for model transport.

Beyond-paper optimization: MetisFL ships raw f32 tensors; int8 block
quantization cuts controller<->learner wire bytes 4x (DESIGN.md §2).  Layout:
the packed (P,) buffer is viewed as (P/group, group) rows; each row gets a
symmetric scale max|x|/127.  Kernels tile rows into VMEM blocks; lanes stay
full with group a multiple of 128.

Validated in interpret mode against ``ref.quantize_ref``/``dequantize_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_pallas", "dequantize_pallas", "DEFAULT_GROUP", "DEFAULT_BLOCK_ROWS"]

DEFAULT_GROUP = 256
DEFAULT_BLOCK_ROWS = 64


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (R, G)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...]


def quantize_pallas(
    x: jax.Array,
    group: int = DEFAULT_GROUP,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(P,) -> (q int8 (P,), scales f32 (P//group,)).  P % (group*block_rows) == 0
    (ops.py pads)."""
    p = x.shape[0]
    rows = p // group
    assert rows % block_rows == 0, (rows, block_rows)
    xg = x.reshape(rows, group)
    grid = (rows // block_rows,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, group), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, group), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, group), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xg)
    return q.reshape(-1), s[:, 0]


def dequantize_pallas(
    q: jax.Array,
    scales: jax.Array,
    group: int = DEFAULT_GROUP,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Inverse of :func:`quantize_pallas`: int8 groups × scales -> float32."""
    rows = q.shape[0] // group
    assert rows % block_rows == 0, (rows, block_rows)
    qg = q.reshape(rows, group)
    grid = (rows // block_rows,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, group), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, group), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, group), jnp.float32),
        interpret=interpret,
    )(qg, scales[:, None])
    return x.reshape(-1)
