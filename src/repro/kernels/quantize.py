"""Pallas TPU kernels: blockwise int8 quantize/dequantize for model transport.

Beyond-paper optimization: MetisFL ships raw f32 tensors; int8 block
quantization cuts controller<->learner wire bytes 4x (DESIGN.md §2).  Layout:
the packed (P,) buffer is viewed as (P/group, group) rows; each row gets a
symmetric scale max|x|/127.  Kernels tile rows into VMEM blocks; lanes stay
full with group a multiple of 128.

Validated in interpret mode against ``ref.quantize_ref``/``dequantize_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "quantize_pallas", "dequantize_pallas", "wire_layout", "scales_padding",
    "effective_block_rows", "DEFAULT_GROUP", "DEFAULT_BLOCK_ROWS",
]

DEFAULT_GROUP = 256
DEFAULT_BLOCK_ROWS = 64


def effective_block_rows(
    n: int, group: int = DEFAULT_GROUP, block_rows: int = DEFAULT_BLOCK_ROWS
) -> int:
    """Kernel block height actually used for an ``(n,)`` buffer.

    ``block_rows`` is a *cap*, not a floor.  A buffer smaller than one full
    ``group * block_rows`` tile shrinks the block to its own row count (zero
    row padding); a larger buffer gets the tallest block whose row padding
    stays within ~6.25% of the needed rows, so wire bytes never balloon to
    the next whole tile (a fixed 64-row tile would pad a 65-row buffer to
    128 rows — 2x on the wire; this rule pads it to 70).  Both codec halves
    derive the same value from ``n`` alone, so the choice needs no extra
    wire state.  Sub-``block_rows`` blocks trade some TPU sublane alignment
    for wire compactness — the uplink is bandwidth-bound, not compute-bound.
    """
    rows_needed = max(1, (n + group - 1) // group)
    if rows_needed <= block_rows:
        return rows_needed
    budget = -(-rows_needed // 16)  # allow ≤ ~6.25% padded rows
    for rows in range(block_rows, 0, -1):
        if (-rows_needed) % rows <= budget:
            return rows
    return 1  # unreachable: rows=1 always pads zero rows


def wire_layout(
    n: int, group: int = DEFAULT_GROUP, block_rows: int = DEFAULT_BLOCK_ROWS
) -> tuple[int, int, int]:
    """Wire layout of one quantized ``(n,)`` buffer.

    Returns ``(n_padded, n_scales, payload_bytes)``: the kernel-tile-padded
    element count (a ``group * effective_block_rows`` multiple — what the
    quantize path actually emits), the number of f32 group scales **shipped**,
    and the total uplink wire bytes (``n_padded`` int8 values followed by
    ``n_scales`` f32 scales).  Only groups that contain real data carry a
    scale: ``n_scales = ceil(n / group)``.  Trailing all-padding groups hold
    ``q == 0`` with scale exactly 1.0 (the quantize kernel's zero-amax
    fallback), so shipping their scales would spend 4 bytes per group on no
    information — the decoder re-synthesizes them from ``n`` alone
    (``scales_padding``).  The transport's int8 upload codec and its tests
    derive payload sizes from this single source of truth, so the kernel's
    padding policy can change without desynchronizing the wire.
    """
    tile = group * effective_block_rows(n, group, block_rows)
    n_padded = ((n + tile - 1) // tile) * tile
    n_scales = (n + group - 1) // group
    return n_padded, n_scales, n_padded + 4 * n_scales


def scales_padding(
    n: int, group: int = DEFAULT_GROUP, block_rows: int = DEFAULT_BLOCK_ROWS
) -> int:
    """How many trailing pad-group scales the decoder must re-synthesize.

    ``wire_layout`` trims the scales of pure-padding groups off the wire;
    the dequantize kernel still wants one scale per padded group, so the
    receiver appends this many 1.0 entries (the quantize kernel's zero-amax
    scale) before dequantizing.  Derived from ``n`` alone, exactly like the
    rest of the wire layout.
    """
    n_padded, n_scales, _ = wire_layout(n, group, block_rows)
    return n_padded // group - n_scales


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (R, G)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...]


def quantize_pallas(
    x: jax.Array,
    group: int = DEFAULT_GROUP,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(P,) -> (q int8 (P,), scales f32 (P//group,)).  P % (group*block_rows) == 0
    (ops.py pads)."""
    p = x.shape[0]
    rows = p // group
    if p % group or rows % block_rows:
        # Trace-time validation (like aggregation.masked_trimmed_mean): a
        # bare assert would vanish under ``python -O`` and let a mis-padded
        # buffer reach the kernel as a shape error deep inside pallas_call.
        raise ValueError(
            f"quantize_pallas needs x.shape[0]={p} divisible by "
            f"group*block_rows={group}*{block_rows}={group * block_rows} "
            "(ops.quantize pads)"
        )
    xg = x.reshape(rows, group)
    grid = (rows // block_rows,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, group), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, group), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, group), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xg)
    return q.reshape(-1), s[:, 0]


def dequantize_pallas(
    q: jax.Array,
    scales: jax.Array,
    group: int = DEFAULT_GROUP,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Inverse of :func:`quantize_pallas`: int8 groups × scales -> float32."""
    rows = q.shape[0] // group
    if q.shape[0] % group or rows % block_rows:
        raise ValueError(
            f"dequantize_pallas needs q.shape[0]={q.shape[0]} divisible by "
            f"group*block_rows={group}*{block_rows}={group * block_rows} "
            "(ops.quantize emits that layout)"
        )
    if scales.shape[0] != rows:
        raise ValueError(
            f"dequantize_pallas got {scales.shape[0]} scales for {rows} "
            f"groups of {group}; re-pad trimmed wire scales first "
            "(kernels.quantize.scales_padding)"
        )
    qg = q.reshape(rows, group)
    grid = (rows // block_rows,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, group), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, group), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, group), jnp.float32),
        interpret=interpret,
    )(qg, scales[:, None])
    return x.reshape(-1)
