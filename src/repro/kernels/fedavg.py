"""Pallas TPU kernel: fused weighted model aggregation.

The paper's hot operation (Fig. 4) restated for the TPU memory hierarchy:
instead of one OpenMP thread per model tensor, the packed ``(N, P)`` learner
stack is tiled along ``P`` into MXU/VPU-aligned VMEM blocks; each grid step
streams one ``(N, block_p)`` tile HBM→VMEM, reduces it against the
``(N,)`` weight vector held in VMEM, and writes the ``(block_p,)`` slice of
the aggregate.

Arithmetic intensity is ~1 FLOP per 2 bytes for f32 inputs (2·N·P FLOPs over
N·P·4 bytes), so the kernel is HBM-bandwidth-bound; the tiling's only job is
to keep the block resident and the lanes full (block_p a multiple of
8·128 = 1024 f32 lanes).  Validated in interpret mode against
``ref.fedavg_ref`` (CPU has no real TPU here); the jit wrapper lives in
``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "fedavg_pallas",
    "masked_fedavg_pallas",
    "choose_block_p",
    "choose_block_p_dividing",
    "choose_block_p_for_shard",
    "DEFAULT_BLOCK_P",
]

# 8 sublanes x 128 lanes x 16 vregs worth of f32 per tile step
DEFAULT_BLOCK_P = 16384

# v5e VMEM is ~128 MiB/core; leave headroom for double-buffering (the Mosaic
# pipeliner keeps 2 in-flight copies of every input tile) and the output tile.
VMEM_BUDGET_BYTES = 64 * 1024 * 1024


def choose_block_p(n_learners: int, dtype_bytes: int = 4,
                   budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest lane-aligned block_p whose working set fits VMEM.

    Working set per grid step ≈ 2·(N·block_p·dtype_bytes)  (double-buffered
    stack tile) + block_p·4 (f32 out) + N·4 (weights).  Solving for block_p
    and rounding down to a multiple of 1024 (8 sublanes × 128 lanes) keeps the
    VPU lanes full while never spilling:  N=8 → 1.0M elements; N=200 → 40k.
    The sweep in EXPERIMENTS.md §Perf confirms HBM-bound behaviour is flat
    across valid block sizes — the only failure mode is exceeding VMEM.
    """
    per_elem = 2 * n_learners * dtype_bytes + 4
    raw = (budget - 4 * n_learners) // per_elem
    aligned = max(1024, (raw // 1024) * 1024)
    return int(min(aligned, 1 << 20))


def choose_block_p_dividing(p: int, n_learners: int, lane_multiple: int = 1024,
                            budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest lane-aligned *divisor* of ``p`` whose working set fits VMEM.

    The arena hot path must not pad: re-padding the whole ``(N, P)`` arena to
    a non-dividing block size would re-introduce exactly the O(N·P) copy the
    arena eliminates.  ``ArenaStore`` pads rows to a ``lane_multiple``
    boundary at allocation, so a lane-aligned divisor always exists; for a
    non-aligned ad-hoc P there may be none, in which case we return
    :func:`choose_block_p` and the caller pads (legacy behaviour).
    """
    cap = choose_block_p(n_learners, budget=budget)
    if p <= 0 or p % lane_multiple:
        return cap
    if p <= cap:
        return p  # single grid step
    k = p // lane_multiple
    best = 0
    for m in range(1, int(k**0.5) + 1):
        if k % m == 0:
            for cand in (m, k // m):
                if lane_multiple * cand <= cap and cand > best:
                    best = cand
    return lane_multiple * best if best else cap


def choose_block_p_for_shard(
    p: int, n_learners: int, n_shards: int, lane_multiple: int = 1024,
    budget: int = VMEM_BUDGET_BYTES,
) -> int:
    """Block size for one column shard of a mesh-sharded arena.

    Under ``shard_map`` the kernel sees the **local** ``(N, p / n_shards)``
    shard, so the block must divide the *shard* width, not the global row —
    a block sized for the global ``P`` would force every device to re-pad its
    shard, reintroducing the O(N·P) copy the arena exists to avoid.
    ``ArenaStore(mesh=...)`` pads rows to ``row_align * n_shards``, so the
    shard width is always lane-aligned and a dividing block exists; a
    non-dividing ad-hoc ``p`` falls back to :func:`choose_block_p` (the
    caller pads, legacy behaviour).
    """
    if n_shards <= 1:
        return choose_block_p_dividing(p, n_learners, lane_multiple, budget)
    if p % n_shards:
        return choose_block_p(n_learners, budget=budget)
    return choose_block_p_dividing(p // n_shards, n_learners, lane_multiple,
                                   budget)


def _fedavg_kernel(w_ref, stack_ref, out_ref):
    """One grid step: out[bp] = sum_n w[n] * stack[n, bp].

    w_ref: (N, 1) f32 in VMEM; stack_ref: (N, BP); out_ref: (1, BP).
    The reduce is expressed as a (1,N)x(N,BP) matmul so the MXU can take it
    when N is large; for small N the VPU handles it as a broadcast-multiply.
    """
    w = w_ref[:, 0]  # (N,)
    block = stack_ref[...].astype(jnp.float32)  # (N, BP)
    acc = jax.lax.dot_general(
        w[None, :], block,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, BP)
    out_ref[...] = acc


def fedavg_pallas(
    stack: jax.Array,
    weights: jax.Array,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool = False,
) -> jax.Array:
    """(N, P) x (N,) -> (P,) weighted mean.  P must be a multiple of block_p
    (ops.py pads).  Weights are normalized inside (f32)."""
    n, p = stack.shape
    assert p % block_p == 0, (p, block_p)
    w = weights.astype(jnp.float32)
    w = (w / jnp.sum(w))[:, None]  # (N, 1)

    grid = (p // block_p,)
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),  # weights: same block each step
            pl.BlockSpec((n, block_p), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=interpret,
    )(w, stack)
    return out[0]


# ---------------------------------------------------------------------------
# Masked variant: aggregation straight off the device-resident arena
# ---------------------------------------------------------------------------


def _masked_fedavg_kernel(w_ref, mask_ref, arena_ref, out_ref):
    """One grid step: out[bp] = sum_n w[n] * mask[n] * arena[n, bp].

    ``w`` arrives pre-masked and pre-normalized, so invalid rows already
    carry zero weight; the explicit ``where`` on the data additionally zeroes
    the row *values* so a dead row containing non-finite garbage (a learner
    that never reported, an invalidated upload) cannot produce 0 * NaN = NaN
    in the aggregate.  The reduce stays a (1,N)x(N,BP) matmul for the MXU.
    """
    w = w_ref[:, 0]  # (N,) masked+normalized
    m = mask_ref[:, 0]  # (N,) 1.0/0.0 validity
    block = arena_ref[...].astype(jnp.float32)  # (N, BP)
    block = jnp.where(m[:, None] > 0, block, 0.0)
    acc = jax.lax.dot_general(
        w[None, :], block,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, BP)
    out_ref[...] = acc


def masked_fedavg_pallas(
    arena: jax.Array,
    weights: jax.Array,
    mask: jax.Array,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool = False,
) -> jax.Array:
    """(N_max, P) x (N_max,) x (N_max,) -> (P,) masked weighted mean.

    The arena-store hot path: the full (possibly part-empty) arena streams
    through VMEM exactly like :func:`fedavg_pallas`, with validity folded into
    the weight vector.  P must be a multiple of ``block_p`` — use
    :func:`choose_block_p_dividing` (as ``ops.masked_fedavg`` does) to pick a
    dividing block for an arena-aligned P without re-padding; ops.py pads for
    ad-hoc shapes.  If every mask entry is zero the weights fall back to
    uniform-over-valid = all-zero, returning a zero buffer (the controller
    raises before that happens).
    """
    from repro.core.aggregation import masked_normalize

    n, p = arena.shape
    assert p % block_p == 0, (p, block_p)
    m = mask.astype(jnp.float32)
    w = masked_normalize(weights, m)

    grid = (p // block_p,)
    out = pl.pallas_call(
        _masked_fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, block_p), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=interpret,
    )(w[:, None], m[:, None], arena)
    return out[0]
