"""Pallas TPU kernel: blocked masked trimmed mean (rank select, no gather).

The byzantine-robust hot path restated for the TPU memory hierarchy: like
``kernels/fedavg.py`` the packed ``(N, P)`` arena is tiled along ``P`` into
VMEM blocks, but the per-column reduction is an order statistic instead of a
dot product.  A full column sort would serialize badly on the VPU, so the
kernel *selects* instead of sorting: for each row ``i`` it computes the
row's per-column rank with one broadcast comparison against the whole block
(ties broken by row index, so ranks are a permutation and the result is
exactly the sort-then-trim answer), then accumulates the row into the mean
iff its rank lands in the surviving band ``[trim_k, n_valid - trim_k)``.
That is O(N^2 · block_p) elementwise VPU work with O(N · block_p) VMEM — no
gather, no scratch permutation, and invalid arena rows are pushed to ``+inf``
so they always rank past the band.

Degenerate cohorts (``n_valid <= 2 * trim_k``) fall back to the untrimmed
masked mean of the valid rows, matching
``core/aggregation.masked_trimmed_mean`` (the pure-jnp production rule this
kernel is benchmarked against).  Validated in interpret mode on CPU against
``ref.masked_trimmed_mean_ref``; the jit wrapper lives in ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fedavg import VMEM_BUDGET_BYTES

__all__ = ["masked_trimmed_mean_pallas", "ROBUST_VMEM_BUDGET_BYTES"]

# The rank-select loop keeps several (N, block_p) f32 temporaries live
# (masked values, iota, comparison masks) on top of the double-buffered input
# tile, so the robust kernel budgets a quarter of the fedavg kernel's VMEM.
ROBUST_VMEM_BUDGET_BYTES = VMEM_BUDGET_BYTES // 4


def _masked_trimmed_mean_kernel(mask_ref, arena_ref, out_ref, *, trim_k):
    """One grid step: out[bp] = trimmed mean over valid rows of arena[:, bp].

    mask_ref: (N, 1) f32 validity; arena_ref: (N, BP); out_ref: (1, BP).
    """
    m = mask_ref[:, 0]  # (N,)
    block = arena_ref[...].astype(jnp.float32)  # (N, BP)
    n = block.shape[0]
    # Invalid rows float to +inf: they rank >= n_valid in every column, so
    # the band test below can never admit them (and their garbage — even
    # NaN — never touches the accumulator).
    x = jnp.where(m[:, None] > 0, block, jnp.inf)
    n_valid = jnp.sum(m)  # f32 scalar
    row_ids = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)  # (N, BP)
    zeros = jnp.zeros((x.shape[1],), jnp.float32)

    def body(i, acc):
        s, c = acc
        xi = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=0)  # (1, BP)
        less = jnp.sum(jnp.where(x < xi, 1.0, 0.0), axis=0)  # (BP,)
        ties = jnp.sum(
            jnp.where((x == xi) & (row_ids < i), 1.0, 0.0), axis=0
        )
        rank = less + ties  # distinct per column: a permutation of 0..N-1
        inband = (rank >= trim_k) & (rank < n_valid - trim_k)
        s = s + jnp.where(inband, xi[0], 0.0)
        c = c + jnp.where(inband, 1.0, 0.0)
        return (s, c)

    s, c = jax.lax.fori_loop(0, n, body, (zeros, zeros))
    trimmed = s / jnp.maximum(c, 1.0)
    # Degenerate cohort: untrimmed masked mean of the valid rows (finite by
    # construction — invalid rows were zeroed, not inf'd, on this path).
    fb_rows = jnp.where(m[:, None] > 0, block, 0.0)
    fallback = jnp.sum(fb_rows, axis=0) / jnp.maximum(n_valid, 1.0)
    out = jnp.where(c > 0, trimmed, jnp.where(n_valid > 0, fallback, 0.0))
    out_ref[...] = out[None, :]


def masked_trimmed_mean_pallas(
    arena: jax.Array,
    mask: jax.Array,
    *,
    trim_k: int,
    block_p: int,
    interpret: bool = False,
) -> jax.Array:
    """(N_max, P) x (N_max,) -> (P,) masked trimmed mean, f32 output.

    P must be a multiple of ``block_p`` (ops.py pads ad-hoc shapes; the
    arena's lane-aligned width admits a dividing block so the hot path never
    re-pads).  ``trim_k`` is static and validated at trace time against the
    arena capacity; a merely-small live cohort falls back at run time.
    """
    n, p = arena.shape
    assert p % block_p == 0, (p, block_p)
    if trim_k < 0 or 2 * trim_k >= n:
        raise ValueError(f"trim_k={trim_k} invalid for N={n}")
    m = mask.astype(jnp.float32)

    grid = (p // block_p,)
    out = pl.pallas_call(
        functools.partial(_masked_trimmed_mean_kernel, trim_k=trim_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, block_p), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=interpret,
    )(m[:, None], arena)
    return out[0]
