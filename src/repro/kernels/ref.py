"""Pure-jnp oracles for every Pallas kernel in this package.

Kernel tests sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "fedavg_ref", "masked_fedavg_ref", "masked_fedavg_q8_ref",
    "masked_trimmed_mean_ref", "quantize_ref", "dequantize_ref",
]


def fedavg_ref(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """(N, P) x (N,) -> (P,) normalized weighted mean in f32."""
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)
    return jnp.einsum("n,np->p", w, stack.astype(jnp.float32))


def masked_fedavg_ref(
    arena: jax.Array, weights: jax.Array, mask: jax.Array
) -> jax.Array:
    """(N, P) x (N,) x (N,) -> (P,) masked normalized weighted mean in f32.

    Uniform-over-valid fallback when all masked weights are zero, matching
    ``core/aggregation.masked_weighted_average``.
    """
    m = mask.astype(jnp.float32)
    w = weights.astype(jnp.float32) * m
    total = jnp.sum(w)
    w = jnp.where(total > 0, w / jnp.where(total > 0, total, 1.0),
                  m / jnp.maximum(jnp.sum(m), 1.0))
    rows = jnp.where(m[:, None] > 0, arena.astype(jnp.float32), 0.0)
    return jnp.einsum("n,np->p", w, rows)


def masked_fedavg_q8_ref(
    q: jax.Array, scales: jax.Array, weights: jax.Array, mask: jax.Array,
    group: int = 256,
) -> jax.Array:
    """f64 oracle for the fused dequant-into-aggregate kernel.

    (N, P) int8 x (N, P//group) f32 x (N,) x (N,) -> (P,): dequantize each
    row exactly (f64), then the masked normalized weighted mean of
    :func:`masked_fedavg_ref` — i.e. dequant-then-reduce at full precision,
    the replay reference the fused single-pass kernel must match.  Computed
    in *host* numpy so the oracle stays genuine f64 even when jax runs
    without the x64 flag.
    """
    import numpy as np

    qh = np.asarray(q).astype(np.float64)
    sh = np.asarray(scales).astype(np.float64)
    n, p = qh.shape
    rows = (qh.reshape(n, p // group, group) * sh[:, :, None]).reshape(n, p)
    m = np.asarray(mask).astype(np.float64)
    w = np.asarray(weights).astype(np.float64) * m
    total = float(w.sum())
    w = w / total if total > 0 else m / max(float(m.sum()), 1.0)
    rows = np.where(m[:, None] > 0, rows, 0.0)
    return jnp.asarray(w @ rows, jnp.float32)


def masked_trimmed_mean_ref(
    arena: jax.Array, mask: jax.Array, trim_k: int
) -> jax.Array:
    """(N, P) x (N,) -> (P,) trimmed mean over valid rows, f32.

    Sort-then-trim oracle: invalid rows float to ``+inf``, the surviving
    band is ranks ``[trim_k, n_valid - trim_k)``; a degenerate cohort falls
    back to the untrimmed masked mean, matching the kernel and
    ``core/aggregation.masked_trimmed_mean``.
    """
    m = mask.astype(jnp.float32)
    n = arena.shape[0]
    rows = jnp.where(m[:, None] > 0, arena.astype(jnp.float32), jnp.inf)
    s = jnp.sort(rows, axis=0)
    n_valid = jnp.sum(m).astype(jnp.int32)
    ranks = jnp.arange(n, dtype=jnp.int32)
    band = (ranks >= trim_k) & (ranks < n_valid - trim_k)
    count = jnp.sum(band.astype(jnp.float32))
    trimmed = jnp.sum(jnp.where(band[:, None], s, 0.0), axis=0) / jnp.maximum(
        count, 1.0
    )
    fb = jnp.where(m[:, None] > 0, arena.astype(jnp.float32), 0.0)
    fallback = jnp.sum(fb, axis=0) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.where(count > 0, trimmed,
                     jnp.where(n_valid > 0, fallback, 0.0))


def quantize_ref(x: jax.Array, group: int = 256) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization.

    x: (P,) with P % group == 0.  Returns (q int8 (P,), scales f32 (P//group,)).
    """
    xg = x.astype(jnp.float32).reshape(-1, group)
    amax = jnp.max(jnp.abs(xg), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xg / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_ref(q: jax.Array, scales: jax.Array, group: int = 256) -> jax.Array:
    """Oracle for ``ops.dequantize``: per-group rescale back to float32."""
    qg = q.astype(jnp.float32).reshape(-1, group)
    return (qg * scales[:, None]).reshape(-1)
