"""Pure-jnp oracles for every Pallas kernel in this package.

Kernel tests sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fedavg_ref", "quantize_ref", "dequantize_ref"]


def fedavg_ref(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """(N, P) x (N,) -> (P,) normalized weighted mean in f32."""
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)
    return jnp.einsum("n,np->p", w, stack.astype(jnp.float32))


def quantize_ref(x: jax.Array, group: int = 256) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization.

    x: (P,) with P % group == 0.  Returns (q int8 (P,), scales f32 (P//group,)).
    """
    xg = x.astype(jnp.float32).reshape(-1, group)
    amax = jnp.max(jnp.abs(xg), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xg / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_ref(q: jax.Array, scales: jax.Array, group: int = 256) -> jax.Array:
    qg = q.astype(jnp.float32).reshape(-1, group)
    return (qg * scales[:, None]).reshape(-1)
