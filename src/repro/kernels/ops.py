"""jit'd public wrappers around the Pallas kernels (padding + dispatch).

``INTERPRET`` flips the kernels into interpret mode — required on CPU, where
the kernel body executes in Python for correctness validation; on a real TPU
it is False and the kernels compile to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fedavg as _fedavg
from repro.kernels import fused_agg as _fused
from repro.kernels import quantize as _quant
from repro.kernels import robust as _robust

# CPU backend -> interpret mode.
INTERPRET = jax.default_backend() == "cpu"

__all__ = [
    "fedavg", "masked_fedavg", "masked_fedavg_sharded",
    "masked_fedavg_q8", "masked_fedavg_q8_sharded",
    "masked_trimmed_mean", "masked_trimmed_mean_sharded",
    "quantize", "dequantize", "QuantCodec",
]


def _pad_to(x: jax.Array, multiple: int, axis: int = -1) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x, size
    pad = multiple - rem
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("block_p",))
def fedavg(stack: jax.Array, weights: jax.Array,
           block_p: int | None = None) -> jax.Array:
    """Kernel-backed FedAvg over a packed (N, P) stack.

    block_p defaults to the largest VMEM-fitting tile for this N
    (``fedavg.choose_block_p``)."""
    if block_p is None:
        block_p = _fedavg.choose_block_p(stack.shape[0])
    padded, p = _pad_to(stack, block_p, axis=1)
    out = _fedavg.fedavg_pallas(padded, weights, block_p=block_p, interpret=INTERPRET)
    return out[:p]


@functools.partial(jax.jit, static_argnames=("block_p",))
def masked_fedavg(arena: jax.Array, weights: jax.Array, mask: jax.Array,
                  block_p: int | None = None) -> jax.Array:
    """Kernel-backed masked FedAvg over a device-resident arena.

    The aggregation step of the arena store (``core/store.ArenaStore``):
    invalid rows are skipped via the mask, so the same compiled kernel serves
    every round regardless of how many learners reported.  The default block
    size *divides* the arena's lane-aligned row width, so the hot path runs
    with zero re-padding (``_pad_to`` is a no-op); only ad-hoc non-aligned
    shapes pay the pad copy."""
    if block_p is None:
        block_p = _fedavg.choose_block_p_dividing(arena.shape[1], arena.shape[0])
    padded, p = _pad_to(arena, block_p, axis=1)
    out = _fedavg.masked_fedavg_pallas(
        padded, weights, mask, block_p=block_p, interpret=INTERPRET
    )
    return out[:p]


@functools.partial(jax.jit, static_argnames=("group", "block_p"))
def masked_fedavg_q8(arena_q: jax.Array, scales: jax.Array,
                     weights: jax.Array, mask: jax.Array,
                     group: int = _quant.DEFAULT_GROUP,
                     block_p: int | None = None) -> jax.Array:
    """Kernel-backed fused dequant-into-aggregate over a quantized arena.

    The int8-arena analogue of :func:`masked_fedavg`: one fused pass reads
    the resident ``(N, P)`` int8 rows plus their ``(N, P//group)`` f32
    scales and emits the masked weighted mean — no f32 ``(N, P)`` stack is
    ever materialized.  The default block divides the arena's lane-aligned
    row width (which ``ArenaStore`` keeps a multiple of lcm(1024, group)),
    so the hot path runs with zero re-padding; ad-hoc non-aligned shapes pay
    a pad copy on both the values and the scales (padding with scale 0.0 —
    the padded tail dequantizes to exact zeros and the extra columns are
    sliced off)."""
    if block_p is None:
        block_p = _fused.choose_block_p_q8_dividing(
            arena_q.shape[1], arena_q.shape[0], group
        )
    padded, p = _pad_to(arena_q, block_p, axis=1)
    spad, _ = _pad_to(scales, block_p // group, axis=1)
    out = _fused.masked_fedavg_q8_pallas(
        padded, spad, weights, mask, group=group, block_p=block_p,
        interpret=INTERPRET,
    )
    return out[:p]


def masked_fedavg_q8_sharded(mesh, axes=None, group: int = _quant.DEFAULT_GROUP):
    """Fused dequant-into-aggregate over a mesh-sharded quantized arena.

    Returns a jitted ``(arena_q (N,P) int8, scales (N,P//group), weights,
    mask) -> (P,)`` running :func:`masked_fedavg_q8` per column shard under
    ``shard_map``.  Values and scales carry the same ``P(None, axes)``
    column sharding (``ArenaStore(arena_dtype="int8", mesh=...)`` keeps the
    shard width a whole number of groups), weight normalization reduces only
    over the replicated ``(N,)`` vectors, and the compiled program contains
    zero collectives, exactly like :func:`masked_fedavg_sharded`.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.aggregation import arena_axes

    ax = arena_axes(mesh, axes)
    n_shards = int(np.prod([mesh.shape[a] for a in ax], dtype=np.int64))

    def _local(arena_q, scales, weights, mask):
        block_p = _fused.choose_block_p_q8_for_shard(
            arena_q.shape[1] * n_shards, arena_q.shape[0], n_shards, group
        )
        return masked_fedavg_q8(arena_q, scales, weights, mask,
                                group=group, block_p=block_p)

    sm = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(None, ax), P(None, ax), P(), P()),
        out_specs=P(ax),
        check_vma=False,
    )
    return jax.jit(sm)


@functools.partial(jax.jit, static_argnames=("trim_k", "block_p"))
def masked_trimmed_mean(arena: jax.Array, weights: jax.Array, mask: jax.Array,
                        trim_k: int = 1, block_p: int | None = None) -> jax.Array:
    """Kernel-backed masked trimmed mean over a device-resident arena.

    The robust-rule hot path (``kernels/robust.py`` rank-select kernel):
    signature-compatible with ``core/aggregation.masked_trimmed_mean`` —
    ``weights`` is accepted and ignored, order statistics being deliberately
    weight-blind.  The default block divides the arena's lane-aligned width
    under the robust kernel's tighter VMEM budget, so the hot path runs with
    zero re-padding; ad-hoc shapes pay the pad copy."""
    del weights  # order statistics are weight-blind by design
    if block_p is None:
        block_p = _fedavg.choose_block_p_dividing(
            arena.shape[1], arena.shape[0],
            budget=_robust.ROBUST_VMEM_BUDGET_BYTES,
        )
    padded, p = _pad_to(arena, block_p, axis=1)
    out = _robust.masked_trimmed_mean_pallas(
        padded, mask, trim_k=trim_k, block_p=block_p, interpret=INTERPRET
    )
    return out[:p]


def masked_trimmed_mean_sharded(mesh, axes=None, trim_k: int = 1):
    """Kernel-backed masked trimmed mean over a mesh-sharded arena.

    Returns a jitted ``(arena (N_max,P), weights, mask) -> (P,)`` running
    :func:`masked_trimmed_mean` per column shard under ``shard_map`` — the
    rule is coordinate-wise, so each device rank-selects within its own
    ``(N_max, P/n_shards)`` slice and the compiled program contains zero
    collectives, exactly like :func:`masked_fedavg_sharded`.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.aggregation import arena_axes

    ax = arena_axes(mesh, axes)
    n_shards = int(np.prod([mesh.shape[a] for a in ax], dtype=np.int64))

    def _local(arena, weights, mask):
        block_p = _fedavg.choose_block_p_for_shard(
            arena.shape[1] * n_shards, arena.shape[0], n_shards,
            budget=_robust.ROBUST_VMEM_BUDGET_BYTES,
        )
        return masked_trimmed_mean(arena, weights, mask, trim_k=trim_k,
                                   block_p=block_p)

    sm = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(None, ax), P(), P()),
        out_specs=P(ax),
        check_vma=False,
    )
    return jax.jit(sm)


@functools.partial(jax.jit, static_argnames=("group", "block_rows"))
def quantize(x: jax.Array, group: int = _quant.DEFAULT_GROUP,
             block_rows: int = _quant.DEFAULT_BLOCK_ROWS):
    """Returns (q, scales); the caller keeps x.shape[0] for dequantize."""
    padded, _ = _pad_to(x, group * block_rows)
    return _quant.quantize_pallas(padded, group, block_rows, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("group", "block_rows", "orig_size"))
def dequantize(q: jax.Array, scales: jax.Array, orig_size: int,
               group: int = _quant.DEFAULT_GROUP,
               block_rows: int = _quant.DEFAULT_BLOCK_ROWS) -> jax.Array:
    """Inverse of :func:`quantize`, sliced back to ``orig_size`` elements."""
    x = _quant.dequantize_pallas(q, scales, group, block_rows, interpret=INTERPRET)
    return x[:orig_size]


def masked_fedavg_sharded(mesh, axes=None):
    """Kernel-backed masked FedAvg over a mesh-sharded arena.

    Returns a jitted ``(arena (N_max,P), weights, mask) -> (P,)`` that runs
    :func:`masked_fedavg` **per column shard** under ``shard_map``: each
    device's Pallas call sees only its local ``(N_max, P/n_shards)`` shard
    (so ``choose_block_p_dividing`` picks a block that divides the *shard*
    width — see ``kernels.fedavg.choose_block_p_for_shard``), the weight
    normalization reduces only over the replicated ``(N_max,)`` vectors, and
    the compiled program contains zero collectives.  The output keeps the
    ``P(axes)`` column sharding of ``core/store.ArenaStore(mesh=...)``.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.aggregation import arena_axes

    ax = arena_axes(mesh, axes)
    n_shards = int(np.prod([mesh.shape[a] for a in ax], dtype=np.int64))

    def _local(arena, weights, mask):
        # arena here is the device-local (N, P/n_shards) shard; size the
        # block from the global width so the choice is explicit and testable.
        block_p = _fedavg.choose_block_p_for_shard(
            arena.shape[1] * n_shards, arena.shape[0], n_shards
        )
        return masked_fedavg(arena, weights, mask, block_p=block_p)

    sm = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(None, ax), P(), P()),
        out_specs=P(ax),
        check_vma=False,
    )
    return jax.jit(sm)


_DTYPE_CODES = {"float32": 0, "bfloat16": 1, "float16": 2, "float64": 3}
_DTYPE_NAMES = {v: k for k, v in _DTYPE_CODES.items()}


class QuantCodec:
    """Transport codec for ``core/transport.Channel``: pytree -> int8 + scales.

    Encodes every float leaf; integer leaves pass through.  Stateless: shape
    and dtype ride along in the encoded leaf, so any receiver can decode
    (lossy to the int8 step, ~0.4% relative error — measured in
    EXPERIMENTS.md and acceptable for FL model shipping).
    """

    @staticmethod
    def encode(params):
        """Quantize every float leaf to int8 + scales (ints pass through)."""
        def enc(leaf):
            leaf = jnp.asarray(leaf)
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            flat = leaf.astype(jnp.float32).reshape(-1)
            q, s = quantize(flat)
            return {
                "__quant__": jnp.asarray(
                    [flat.shape[0], _DTYPE_CODES[str(leaf.dtype)]] + list(leaf.shape),
                    jnp.int64,
                ),
                "q": q,
                "s": s,
            }

        return jax.tree_util.tree_map(enc, params)

    @staticmethod
    def decode(encoded):
        """Reconstruct the pytree encoded by :meth:`encode` (lossy to int8)."""
        def is_q(x):
            return isinstance(x, dict) and "__quant__" in x

        def dec(leaf):
            if not is_q(leaf):
                return leaf
            meta = [int(v) for v in leaf["__quant__"]]
            size, dtc, shape = meta[0], meta[1], tuple(meta[2:])
            x = dequantize(leaf["q"], leaf["s"], size)
            return x.reshape(shape).astype(_DTYPE_NAMES[dtc])

        return jax.tree_util.tree_map(dec, encoded, is_leaf=is_q)
