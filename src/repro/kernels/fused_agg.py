"""Pallas TPU kernel: fused dequant-into-aggregate over a quantized arena.

The quantized-resident arena (``core/store.ArenaStore(arena_dtype="int8")``)
keeps each learner row as int8 groups plus per-group f32 scales — 4x fewer
resident HBM bytes than the f32 arena.  The naive way to aggregate it is
dequantize-then-reduce: materialize the f32 ``(N, P)`` stack (write 4·N·P
bytes, read them back) and run ``masked_fedavg`` — three passes over the
dominant traffic.  This kernel fuses the two: each grid step streams one
``(N, block_p)`` int8 tile plus its ``(N, block_p/group)`` scale tile
HBM→VMEM, dequantizes in registers (``q.astype(f32) * scale`` broadcast per
group), masks dead rows and reduces against the normalized weight vector —
**one pass** over the quantized bytes, ~N·P + 4·N·P/group + 4·P bytes moved
instead of ~9·N·P.

Tiling follows ``kernels/fedavg.py``: ``block_p`` is VMEM-budgeted, lane-
aligned, a multiple of the quant group (so every tile holds whole groups)
and — on the arena hot path — an exact divisor of the padded row width, so
nothing is ever re-padded.  Validated in interpret mode against the f64
``ref.masked_fedavg_q8_ref`` oracle; the jit wrapper and the column-sharded
``shard_map`` variant (zero collectives) live in ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fedavg import VMEM_BUDGET_BYTES
from repro.kernels.quantize import DEFAULT_GROUP

__all__ = [
    "masked_fedavg_q8_pallas",
    "choose_block_p_q8",
    "choose_block_p_q8_dividing",
    "choose_block_p_q8_for_shard",
]

# block_p must be both VPU-lane-aligned (1024 = 8 sublanes x 128 lanes of
# f32) and a whole number of quant groups; group is a multiple of 128 by
# the quantize kernel's contract, so aligning to lcm keeps both.
_LANE_MULTIPLE = 1024


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


def choose_block_p_q8(
    n_learners: int, group: int = DEFAULT_GROUP,
    budget: int = VMEM_BUDGET_BYTES,
) -> int:
    """Largest aligned block_p whose fused working set fits VMEM.

    Working set per grid step ≈ 2·N·block_p (double-buffered int8 tile)
    + 2·N·(block_p/group)·4 (scale tiles) + N·block_p·4 (the in-kernel f32
    dequantized block) + block_p·4 (out) + 2·N·4 (weights + mask).  Solving
    for block_p and rounding down to a multiple of lcm(1024, group) keeps
    the lanes full and every tile a whole number of groups.  The fused
    working set per element (~6·N bytes) is smaller than the f32 kernel's
    (~8·N), so the quantized arena sustains *larger* tiles at equal VMEM.
    """
    per_elem = 2 * n_learners + 4 * n_learners + (8 * n_learners) // group + 4
    raw = (budget - 8 * n_learners) // per_elem
    align = _lcm(_LANE_MULTIPLE, group)
    aligned = max(align, (raw // align) * align)
    return int(min(aligned, 1 << 20))


def choose_block_p_q8_dividing(
    p: int, n_learners: int, group: int = DEFAULT_GROUP,
    budget: int = VMEM_BUDGET_BYTES,
) -> int:
    """Largest aligned *divisor* of ``p`` whose working set fits VMEM.

    The quantized-arena analogue of ``fedavg.choose_block_p_dividing``: the
    hot path must not pad (re-padding the resident ``(N, P)`` int8 buffer
    would reintroduce the O(N·P) copy the arena eliminates), and every tile
    must hold whole quant groups so the scale tile stays rectangular.
    ``ArenaStore`` pads rows to ``row_align`` (a multiple of
    lcm(1024, group) for the defaults), so an aligned divisor always
    exists; a non-aligned ad-hoc ``p`` falls back to
    :func:`choose_block_p_q8` and the caller pads (legacy behaviour).
    """
    cap = choose_block_p_q8(n_learners, group, budget)
    align = _lcm(_LANE_MULTIPLE, group)
    if p <= 0 or p % align:
        return cap
    if p <= cap:
        return p  # single grid step
    k = p // align
    best = 0
    for m in range(1, int(k**0.5) + 1):
        if k % m == 0:
            for cand in (m, k // m):
                if align * cand <= cap and cand > best:
                    best = cand
    return align * best if best else cap


def choose_block_p_q8_for_shard(
    p: int, n_learners: int, n_shards: int, group: int = DEFAULT_GROUP,
    budget: int = VMEM_BUDGET_BYTES,
) -> int:
    """Block size for one column shard of a mesh-sharded quantized arena.

    Under ``shard_map`` the kernel sees the **local** ``(N, p / n_shards)``
    int8 shard (and the matching scale shard), so the block must divide the
    shard width — exactly the contract of
    ``fedavg.choose_block_p_for_shard``, restated for the group-aligned
    quantized layout.
    """
    if n_shards <= 1:
        return choose_block_p_q8_dividing(p, n_learners, group, budget)
    if p % n_shards:
        return choose_block_p_q8(n_learners, group, budget)
    return choose_block_p_q8_dividing(p // n_shards, n_learners, group, budget)


def _masked_fedavg_q8_kernel(w_ref, mask_ref, q_ref, s_ref, out_ref, *,
                             group: int):
    """One grid step: out[bp] = sum_n w[n]·mask[n]·q[n,bp]·s[n,bp/group].

    ``w`` arrives pre-masked and pre-normalized; the explicit ``where``
    additionally zeroes dead-row *values* so garbage scales (e.g. a NaN
    scale from a never-finalized row) cannot produce 0·NaN = NaN in the
    aggregate.  Dequantization is a per-group broadcast multiply in
    registers — the f32 block never round-trips through HBM — and the
    reduce stays a (1,N)x(N,BP) matmul for the MXU.
    """
    w = w_ref[:, 0]  # (N,) masked+normalized
    m = mask_ref[:, 0]  # (N,) 1.0/0.0 validity
    q = q_ref[...].astype(jnp.float32)  # (N, BP)
    s = s_ref[...]  # (N, BP/group) f32
    n, bp = q.shape
    block = (q.reshape(n, bp // group, group) * s[:, :, None]).reshape(n, bp)
    block = jnp.where(m[:, None] > 0, block, 0.0)
    acc = jax.lax.dot_general(
        w[None, :], block,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, BP)
    out_ref[...] = acc


def masked_fedavg_q8_pallas(
    q: jax.Array,
    scales: jax.Array,
    weights: jax.Array,
    mask: jax.Array,
    *,
    group: int = DEFAULT_GROUP,
    block_p: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """(N,P) int8 x (N,P/group) f32 x (N,) x (N,) -> (P,) masked weighted mean.

    The quantized-arena hot path: one fused pass that dequantizes and
    reduces tile by tile.  ``P`` must be a multiple of ``block_p`` and
    ``block_p`` a multiple of ``group`` — use
    :func:`choose_block_p_q8_dividing` (as ``ops.masked_fedavg_q8`` does)
    for an arena-aligned P; ops.py pads ad-hoc shapes.  All-zero masks fall
    back to the zero buffer exactly like ``masked_fedavg_pallas``.
    """
    from repro.core.aggregation import masked_normalize

    n, p = q.shape
    if block_p is None:
        block_p = choose_block_p_q8_dividing(p, n, group)
    if p % block_p or block_p % group:
        raise ValueError(
            f"masked_fedavg_q8_pallas needs P={p} divisible by "
            f"block_p={block_p} and block_p divisible by group={group}"
        )
    if scales.shape != (n, p // group):
        raise ValueError(
            f"scales shape {scales.shape} does not match {n} rows of "
            f"{p}//{group}={p // group} groups"
        )
    m = mask.astype(jnp.float32)
    w = masked_normalize(weights, m)

    grid = (p // block_p,)
    sblock = block_p // group
    out = pl.pallas_call(
        functools.partial(_masked_fedavg_q8_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, block_p), lambda i: (0, i)),
            pl.BlockSpec((n, sblock), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=interpret,
    )(w[:, None], m[:, None], q, scales)
    return out[0]
