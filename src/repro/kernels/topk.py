"""Top-k magnitude sparsification for the sparse uplink (error feedback).

Beyond-paper optimization: int8 quantization (``kernels/quantize.py``)
bought ~4x on the wire; magnitude top-k with error feedback opens the
10-100x regime (the sparsification family surveyed in arXiv:2104.14362).
The learner accumulates its full update into an f32 residual, ships only
the ``k`` largest-magnitude coordinates as ``(indices:int32, values)``
pairs, and subtracts what it sent — unsent mass is *carried*, not lost,
so the scheme stays unbiased over rounds.

Selection uses ``jax.lax.top_k`` on ``|x|`` — the XLA-native top-k with a
deterministic lowest-index tie-break, which lowers to the TPU sort unit
directly; a hand-rolled Pallas tournament would re-implement exactly that
lowering.  The pack/unpack halves are pure device-side ``jnp`` programs
(one fused jit each), so the CPU fallback is the same program under the
XLA CPU backend — no interpret-mode shim needed.

Values ship either as f32 (8 bytes/coordinate with the int32 index) or as
int8 with per-group f32 scales (~5 bytes/coordinate), the same symmetric
``amax/127`` scheme as ``kernels/quantize.py`` but over the dense *sent
value* vector (length ``k``), not the parameter axis.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "topk_select", "densify", "ef_residual",
    "quantize_values", "dequantize_values",
    "effective_k", "wire_layout_topk",
    "DEFAULT_VALUE_GROUP", "VALUE_DTYPES",
]

DEFAULT_VALUE_GROUP = 64
VALUE_DTYPES = ("f32", "int8")


def effective_k(n: int, k: int) -> int:
    """The per-buffer k actually sent: ``k`` clamped to ``[1, n]``.

    Tiny buffers (bias-only layers, toy tests) clamp down; the clamp is
    derived from ``n`` alone on both codec halves, so the envelope's
    ``codec_params`` stay constant across uploads (the codec-identity
    check in the controller compares them structurally).
    """
    return max(1, min(int(k), int(n)))


def wire_layout_topk(
    n: int, k: int, value_dtype: str = "f32",
    group: int = DEFAULT_VALUE_GROUP,
) -> tuple[int, int, int]:
    """Wire layout of one sparse ``(n,)`` upload.

    Returns ``(k_eff, n_scales, payload_bytes)``: the clamped coordinate
    count, the number of f32 value-group scales shipped (0 for f32
    values), and the total payload bytes — ``4*k_eff`` int32 indices
    followed by either ``4*k_eff`` f32 values or ``k_eff`` int8 values
    plus ``4*n_scales`` scale bytes.
    """
    k_eff = effective_k(n, k)
    if value_dtype == "f32":
        return k_eff, 0, 8 * k_eff
    if value_dtype != "int8":
        raise ValueError(
            f"value_dtype must be one of {VALUE_DTYPES}, got {value_dtype!r}"
        )
    n_scales = -(-k_eff // group)
    return k_eff, n_scales, 5 * k_eff + 4 * n_scales


@partial(jax.jit, static_argnames=("k",))
def topk_select(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """The ``k`` largest-|x| coordinates of a flat buffer.

    Returns ``(indices:int32, values)`` with values carrying their sign
    (gathered from ``x``, not from ``|x|``).  ``jax.lax.top_k`` breaks
    magnitude ties toward the lowest index, so selection is deterministic
    — the conformance references replay this exact kernel rather than an
    f64 re-selection that could flip near-ties.
    """
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    idx = idx.astype(jnp.int32)
    return idx, x[idx]


@partial(jax.jit, static_argnames=("width",))
def densify(indices: jax.Array, values: jax.Array, width: int) -> jax.Array:
    """Scatter one sparse ``(idx, val)`` stream into a dense f32 row."""
    return (
        jnp.zeros((width,), jnp.float32)
        .at[indices]
        .add(values.astype(jnp.float32))
    )


@jax.jit
def ef_residual(
    acc: jax.Array, indices: jax.Array, values: jax.Array
) -> jax.Array:
    """Error-feedback carry: subtract the sent coordinates from ``acc``.

    With f32 values the sent coordinates zero out exactly (``x - x``);
    with quantized values the residual keeps the quantization error, so
    error feedback absorbs both the sparsification *and* the value-dtype
    loss.
    """
    return acc.at[indices].add(-values.astype(acc.dtype))


@partial(jax.jit, static_argnames=("group",))
def quantize_values(
    values: jax.Array, group: int = DEFAULT_VALUE_GROUP
) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization of a dense value vector.

    Groups of ``group`` values share one f32 scale ``max|v|/127`` (1.0
    for all-zero groups, so dequantization never divides by zero).
    Returns ``(q:int8 (k,), scales:f32 (ceil(k/group),))``.
    """
    k = values.shape[0]
    n_scales = -(-k // group)
    v = jnp.pad(values.astype(jnp.float32), (0, n_scales * group - k))
    v = v.reshape(n_scales, group)
    amax = jnp.max(jnp.abs(v), axis=1)
    scales = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(v / scales[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:k], scales


@partial(jax.jit, static_argnames=("group",))
def dequantize_values(
    q: jax.Array, scales: jax.Array, group: int = DEFAULT_VALUE_GROUP
) -> jax.Array:
    """Inverse of :func:`quantize_values`: ``q * scale`` per group."""
    k = q.shape[0]
    n_scales = scales.shape[0]
    v = jnp.pad(q.astype(jnp.float32), (0, n_scales * group - k))
    return (v.reshape(n_scales, group) * scales[:, None]).reshape(-1)[:k]
