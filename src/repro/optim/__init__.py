from repro.optim.optimizers import (
    Optimizer,
    OptState,
    sgd,
    momentum,
    adam,
    adamw,
    adafactor,
    apply_fedprox,
)

__all__ = [
    "Optimizer",
    "OptState",
    "sgd",
    "momentum",
    "adam",
    "adamw",
    "adafactor",
    "apply_fedprox",
]
