"""Local (learner-side) optimizers as pure pytree transforms.

The paper's stress tests use Vanilla SGD; a production learner also needs
momentum/Adam/AdamW, and FedProx's proximal term for heterogeneous silos.
Implemented optax-style — ``init(params) -> state``, ``update(grads, state,
params) -> (updates, state)`` — but self-contained (no external deps) and
fully jit/pjit compatible: states are pytrees mirroring the params, so they
shard with the same PartitionSpecs as the model under the production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "OptState", "sgd", "momentum", "adam", "adamw", "apply_fedprox"]

OptState = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], OptState]
    # (grads, state, params) -> (updates, new_state); apply: p + u
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]

    def apply(self, params: Any, grads: Any, state: OptState) -> tuple[Any, OptState]:
        updates, state = self.update(grads, state, params)
        return jax.tree_util.tree_map(lambda p, u: p + u, params, updates), state


def _zeros_like_tree(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree_util.tree_map(lambda g: -lr * g, grads), state

    return Optimizer("sgd", init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return _zeros_like_tree(params)

    def update(grads, state, params):
        new_m = jax.tree_util.tree_map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: -lr * (beta * m + g), new_m, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer("momentum", init, update)


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def _adam_core(lr, b1, b2, eps, weight_decay):
    def init(params):
        return AdamState(jnp.zeros((), jnp.int32), _zeros_like_tree(params), _zeros_like_tree(params))

    def update(grads, state, params):
        step = state.step + 1
        m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
        v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def u(mh, vh, p):
            upd = -lr * (mh / c1) / (jnp.sqrt(vh / c2) + eps)
            if weight_decay:
                upd = upd - lr * weight_decay * p
            return upd

        return jax.tree_util.tree_map(u, m, v, params), AdamState(step, m, v)

    return init, update


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    init, update = _adam_core(lr, b1, b2, eps, 0.0)
    return Optimizer("adam", init, update)


def adamw(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    init, update = _adam_core(lr, b1, b2, eps, weight_decay)
    return Optimizer("adamw", init, update)


def apply_fedprox(loss_fn: Callable, mu: float, global_params: Any) -> Callable:
    """Wrap a local loss with the FedProx proximal term μ/2‖w − w_global‖²."""

    def prox_loss(params, *args, **kwargs):
        base = loss_fn(params, *args, **kwargs)
        sq = sum(
            jnp.sum((p - g.astype(p.dtype)) ** 2)
            for p, g in zip(
                jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(global_params),
            )
        )
        return base + 0.5 * mu * sq

    return prox_loss


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored second moments, for the very large
# configs whose full Adam state would not fit the per-chip HBM share
# (deepseek-v3-671b; see DESIGN.md §4 and the roofline memory notes).
# ---------------------------------------------------------------------------


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any  # row second-moment (last dim reduced) for >=2D leaves
    vc: Any  # col second-moment (second-to-last dim reduced)
    v: Any  # full second moment for <2D leaves


def adafactor(
    lr: float = 1e-2,
    decay_base: float = 0.8,
    eps1: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros((), jnp.float32)

        def vc(p):
            return (
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p) else jnp.zeros((), jnp.float32)
            )

        def v(p):
            return jnp.zeros((), jnp.float32) if _factored(p) else jnp.zeros(p.shape, jnp.float32)

        t = jax.tree_util.tree_map
        return AdafactorState(jnp.zeros((), jnp.int32), t(vr, params), t(vc, params), t(v, params))

    def update(grads, state, params):
        step = state.step + 1
        beta2 = 1.0 - step.astype(jnp.float32) ** (-decay_base)

        def upd(g, vr, vc, v):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if g.ndim >= 2:
                nvr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                nvc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = (
                    nvr[..., None]
                    * nvc[..., None, :]
                    / jnp.maximum(jnp.mean(nvr, axis=-1, keepdims=True)[..., None], eps1)
                )
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps1))
                nv = v
            else:
                nv = beta2 * v + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(nv, eps1))
                nvr, nvc = vr, vc
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            return -lr * u, nvr, nvc, nv

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_vr = treedef.flatten_up_to(state.vr)
        flat_vc = treedef.flatten_up_to(state.vc)
        flat_v = treedef.flatten_up_to(state.v)
        outs = [upd(g, vr, vc, v) for g, vr, vc, v in zip(flat_g, flat_vr, flat_vc, flat_v)]
        updates = treedef.unflatten([o[0].astype(p.dtype) for o, p in
                                     zip(outs, treedef.flatten_up_to(params))])
        new_state = AdafactorState(
            step,
            treedef.unflatten([o[1] for o in outs]),
            treedef.unflatten([o[2] for o in outs]),
            treedef.unflatten([o[3] for o in outs]),
        )
        return updates, new_state

    return Optimizer("adafactor", init, update)
