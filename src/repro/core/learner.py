"""The Federation Learner: local training/evaluation over a private shard.

Mirrors MetisFL's learner servicer (paper Fig. 9/10): it receives a
``TrainTask`` (RunTask), immediately acknowledges, trains in the background
(the round engine's executor provides the background thread), and reports
completion with the locally trained model plus execution metadata — the
engine receives it as an ``UploadArrived`` event (the MarkTaskCompleted
analogue; see ``core/engine.py``).  Evaluation (EvaluateModel) is a
synchronous call.

The learner owns: its private data iterator, a jit-compiled local step, and a
local optimizer.  It never sees other learners' data or models — only packed
global-model envelopes from the controller.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.scheduler import TrainTask
from repro.optim import Optimizer, apply_fedprox

__all__ = ["LocalUpdate", "EvalReport", "Learner"]


@dataclasses.dataclass
class LocalUpdate:
    """Payload of MarkTaskCompleted (the engine's ``UploadArrived`` event).

    ``upload`` is the measured-wire fast path: when the learner holds both
    the federation's manifest and a channel handle (shipped once at
    registration), it packs its trained params into the flat ``(P,)`` buffer
    — already padded to the controller's arena row width — and sends it
    through ``Channel.upload``, so the update arrives as a codec-encoded
    ``UploadEnvelope`` with uplink byte/time accounting already charged; the
    controller decodes it straight into the arena row.  ``buffer`` is the
    pre-envelope flat-buffer path (manifest but no channel — kept for direct
    ``Learner`` API use).  Both ``None`` means the controller must pack
    ``params`` itself (the legacy path).
    """

    learner_id: str
    round_id: int
    params: Any
    num_examples: int
    metrics: dict
    seconds_per_step: float
    buffer: Any = None
    upload: Any = None


@dataclasses.dataclass
class EvalReport:
    """Result of one synchronous EvaluateModel call on a learner."""

    learner_id: str
    round_id: int
    metrics: dict
    num_examples: int


class Learner:
    """A federation learner bound to a loss function and a private dataset.

    ``loss_fn(params, batch) -> scalar`` defines local training;
    ``eval_fn(params, batch) -> dict`` defines evaluation.  ``data_fn(batch
    _size) -> batch`` and ``eval_data_fn()`` supply private data.  All model
    structure lives in the loss function — the learner is model-agnostic,
    like MetisFL's learner wrapper around user fit/evaluate functions.
    """

    def __init__(
        self,
        learner_id: str,
        loss_fn: Callable[[Any, Any], jax.Array],
        eval_fn: Callable[[Any, Any], dict],
        data_fn: Callable[[int], Any],
        eval_data_fn: Callable[[], Any],
        optimizer: Optimizer,
        num_examples: int,
    ):
        self.learner_id = learner_id
        self._loss_fn = loss_fn
        self._eval_fn = eval_fn
        self._data_fn = data_fn
        self._eval_data_fn = eval_data_fn
        self._optimizer = optimizer
        self.num_examples = num_examples
        self._step_cache: dict[float, Callable] = {}
        self.alive = True
        self._manifest = None
        self._upload_pad: int | None = None
        self._channel = None
        # Error-feedback residual of the sparse (topk) uplink: the f32
        # (padded_params,) carry of everything sparsification left behind.
        # None until the first sparse upload; rides checkpoints via
        # export_residual/restore_residual.
        self._residual: jax.Array | None = None

    # -- wire contract ------------------------------------------------------
    def accept_manifest(
        self, manifest: Any, pad_to: int | None = None, channel: Any = None
    ) -> None:
        """Receive the federation's wire contract (shipped once, at join).

        MetisFL ships the model's proto descriptors to every participant at
        registration; this is the analogue.  With a manifest resident the
        learner packs its trained model into a flat ``(P,)`` buffer itself,
        pre-padded to ``pad_to`` (the controller's arena row width), so the
        upload path never re-flattens a pytree.  With a ``channel`` handle
        also resident the buffer additionally crosses the measured uplink
        (``Channel.upload`` — codec-encoded, byte/time-accounted) and the
        update carries an ``UploadEnvelope`` instead of an in-process buffer.
        """
        self._manifest = manifest
        self._upload_pad = pad_to
        self._channel = channel

    # -- heartbeat ----------------------------------------------------------
    def ping(self) -> bool:
        """Heartbeat: True while the learner is alive (driver monitoring)."""
        return self.alive

    def shutdown(self) -> None:
        """Mark the learner dead (driver shutdown / failure injection)."""
        self.alive = False

    # -- training -----------------------------------------------------------
    def _build_step(self, loss_fn: Callable) -> Callable:
        opt = self._optimizer

        @jax.jit
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = opt.apply(params, grads, opt_state)
            return params, opt_state, loss

        return step

    def _make_step(self, prox_mu: float, global_params: Any) -> Callable:
        # The prox-free step is cached across tasks: rebuilding the jitted
        # closure per fit() would recompile every round, so the measured
        # seconds-per-step would be compile time, not training speed — which
        # is exactly what semi-sync task sizing consumes.  The FedProx step
        # closes over this task's global params and cannot be reused.
        if prox_mu > 0.0:
            return self._build_step(
                apply_fedprox(self._loss_fn, prox_mu, global_params)
            )
        step = self._step_cache.get(0.0)
        if step is None:
            step = self._step_cache[0.0] = self._build_step(self._loss_fn)
        return step

    def _topk_codec(self) -> Any | None:
        """The channel's topk upload codec, or None when the uplink is dense."""
        codec = getattr(self._channel, "upload_codec", None)
        return codec if getattr(codec, "codec_id", None) == "topk" else None

    def _upload_sparse(
        self, trained: jax.Array, base: jax.Array, codec: Any, task: TrainTask
    ) -> Any:
        """Error-feedback sparse uplink: accumulate, send top-k, carry the rest.

        ``acc = residual + (trained - base)`` is the full un-sent update
        mass; the codec ships its ``k`` largest-magnitude coordinates and
        the residual keeps ``acc - sent`` — *exactly* zero at sent
        coordinates for f32 values, the quantization error for int8-grouped
        values (the subtraction uses the dequantized wire values via
        ``unpack_coords``, so the carry sees what the controller sees).
        """
        from repro.kernels import topk as topk_kernels

        acc = trained - base
        if self._residual is not None:
            acc = self._residual + acc
        upload = self._channel.upload(
            acc,
            metadata={"learner_id": self.learner_id,
                      "round_id": task.round_id},
        )
        idx, val = codec.unpack_coords(upload.payload, int(acc.shape[0]))
        self._residual = topk_kernels.ef_residual(acc, idx, val)
        telemetry = getattr(self._channel, "telemetry", None)
        if telemetry is not None:
            telemetry.gauge("learner.residual_norm").set(
                float(jnp.linalg.norm(self._residual))
            )
        return upload

    def export_residual(self) -> Any | None:
        """Host copy of the error-feedback residual (checkpoint save).

        None before the first sparse upload — a restored learner that never
        uploaded starts from a zero carry either way.
        """
        if self._residual is None:
            return None
        import numpy as np

        return np.asarray(jax.device_get(self._residual))

    def restore_residual(self, buffer: Any | None) -> None:
        """Reload a checkpointed error-feedback residual (restore half)."""
        self._residual = (
            None if buffer is None else jnp.asarray(buffer, jnp.float32)
        )

    def fit(self, params: Any, task: TrainTask) -> LocalUpdate:
        """Run ``task.local_steps`` local optimization steps (paper T2-T3)."""
        step = self._make_step(task.prox_mu, params)
        opt_state = self._optimizer.init(params)
        losses = []
        topk_codec = self._topk_codec()
        base = None
        if topk_codec is not None and self._manifest is not None:
            # Sparse uplink ships *deltas*: snapshot the received model at
            # the wire width so the update is computed against exactly what
            # the controller broadcast (async-safe — the controller no
            # longer holds every learner's base version).
            base = packing.pack_numeric(params, pad_to=self._upload_pad)
        t0 = time.perf_counter()
        for _ in range(task.local_steps):
            batch = self._data_fn(task.batch_size)
            params, opt_state, loss = step(params, opt_state, batch)
        jax.block_until_ready(loss)
        elapsed = time.perf_counter() - t0
        losses.append(float(loss))
        buffer = upload = None
        if self._manifest is not None:
            # Flat-buffer upload fast path: pack learner-side (off the
            # controller's arrival path), padded to the arena row width.
            buffer = packing.pack_numeric(params, pad_to=self._upload_pad)
            if self._channel is not None:
                # Measured uplink: the packed row crosses the channel as a
                # codec-encoded wire envelope; the in-process buffer is
                # dropped so arrival reads exactly what the wire carried.
                if base is not None:
                    upload = self._upload_sparse(
                        buffer, base, topk_codec, task
                    )
                else:
                    upload = self._channel.upload(
                        buffer,
                        metadata={"learner_id": self.learner_id,
                                  "round_id": task.round_id},
                    )
                buffer = None
        return LocalUpdate(
            learner_id=self.learner_id,
            round_id=task.round_id,
            params=params,
            num_examples=self.num_examples,
            metrics={"train_loss": losses[-1], "local_steps": task.local_steps},
            seconds_per_step=elapsed / max(task.local_steps, 1),
            buffer=buffer,
            upload=upload,
        )

    # -- evaluation ---------------------------------------------------------
    def evaluate(self, params: Any, round_id: int) -> EvalReport:
        """Synchronous EvaluateModel over the learner's private eval data."""
        batch = self._eval_data_fn()
        metrics = {k: float(v) for k, v in self._eval_fn(params, batch).items()}
        return EvalReport(
            learner_id=self.learner_id,
            round_id=round_id,
            metrics=metrics,
            num_examples=self.num_examples,
        )
