"""The Federation Driver: initialization → monitoring → shutdown (Fig. 8).

The driver parses the federated environment, creates the MetisFL Context
(controller + learners + channels + keys), ships the initial model state,
monitors the federation with heartbeats, and tears everything down in the
paper's order (learners first, then controller).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Sequence

from repro.core.config import FederationConfig
from repro.core.controller import Controller
from repro.core.engine import RoundTimings
from repro.core.learner import Learner
from repro.core.scheduler import (
    AsyncProtocol,
    BufferedAsyncProtocol,
    DeadlineCohortProtocol,
    ReputationProtocol,
    SemiSyncProtocol,
    SyncProtocol,
)
from repro.core.selection import SelectionPolicy
from repro.core.server_opt import make_server_optimizer
from repro.core.store import ModelStore
from repro.core.transport import Channel

log = logging.getLogger("repro.driver")

__all__ = ["FederationEnv", "TerminationCriteria", "Driver"]


@dataclasses.dataclass(frozen=True)
class TerminationCriteria:
    """Federated-training termination signals (paper Fig. 8)."""

    max_rounds: int = 10
    max_wallclock_s: float | None = None
    target_metric: str | None = None  # e.g. "eval_loss"
    target_value: float | None = None
    target_mode: str = "min"  # min | max


@dataclasses.dataclass(frozen=True)
class FederationEnv:
    """The YAML-equivalent federated-environment description.

    The workflow knobs (protocol, steps, batch size, learning rates,
    termination) live here as flat fields.  The controller-machinery knobs
    (store mode, arena sharding, upload codec, journal, checkpointing...)
    are collected in one validated
    :class:`~repro.core.config.FederationConfig` at :attr:`config` — the
    documented entry point is ``FederationEnv(config=FederationConfig(...))``.
    The legacy flat machinery fields (``store_mode=``, ``upload_codec=``...)
    remain as aliases: when no ``config`` is passed they populate one; when
    a ``config`` is passed it wins and the flat fields mirror its values.
    """

    protocol: str = "sync"  # sync|semi_sync|async|buffered_async|deadline|reputation
    local_steps: int = 1
    batch_size: int = 100
    learning_rate: float = 0.01
    hyperperiod_s: float = 1.0
    staleness_alpha: float = 0.5
    prox_mu: float = 0.0
    selection: SelectionPolicy = SelectionPolicy()
    server_optimizer: str = "fedavg"
    server_lr: float = 1.0
    secure_aggregation: bool = False
    lineage_length: int = 1
    store_capacity_bytes: int | None = None
    # "arena" | "stack" | "auto": auto picks the legacy hash-map store when
    # its exclusive features (lineage > 1, byte-capacity eviction) are
    # configured, and the device-resident arena otherwise.
    store_mode: str = "auto"
    # 0 = single-device arena; N > 0 column-shards the arena over an N-device
    # 1-D ("data",) controller mesh (launch/mesh.make_controller_mesh); -1
    # shards over every visible device.  Ignored when the auto-pick above
    # falls back to the hash-map store; combining it with an explicit
    # store_mode="stack" raises.
    arena_shards: int = 0
    # Flat-buffer upload fast path: ship the wire manifest to every learner
    # at registration so uploads arrive as packed (P,) buffers and the
    # controller never flattens a pytree on arrival.  False keeps the legacy
    # pack-on-arrival path (parity/debugging).
    flat_uploads: bool = True
    # Uplink wire format for update buffers: "raw" (bit-transparent f32
    # bytes) or "int8" (blockwise quantization, ~3.9x fewer uplink bytes).
    upload_codec: str = "raw"
    # Resident precision of the arena rows: "f32" (default) or "int8"
    # (quantized-resident arena + fused dequant-into-aggregate reduce,
    # ~4x less device memory; fedavg-only, no secure — docs/ARENA.md).
    arena_dtype: str = "f32"
    # How a "topk" upload lands: "densify" (scatter into the dense row —
    # every store/rule keeps working) or "direct" (resident (n, k) sparse
    # arena + masked scatter-accumulate; fedavg/staleness only).
    sparse_mode: str = "densify"
    # EWMA decay for the per-learner seconds-per-step estimate (0 = legacy
    # last-sample behaviour; see core/scheduler.LearnerProfile).
    profile_decay: float = 0.5
    # Semi-sync only: subtract each learner's modeled round-trip wire time
    # from the hyper-period step budget (wire-cost-aware task sizing).
    wire_aware: bool = True
    # Buffered-async (FedBuff) only: aggregate every K arrivals.
    buffer_k: int = 8
    # Deadline-cohort only: wall-clock budget a cohort member's predicted
    # round trip must fit inside.
    deadline_s: float = 1.0
    # Reputation only: top fraction of ranked learners kept per round.
    reputation_fraction: float = 0.5
    # Community-model reduction: "fedavg" | "median" | "trimmed_mean"
    # (robust rules reject staleness-weighted protocols — see
    # core/config.FederationConfig and docs/PROTOCOLS.md).
    aggregation_rule: str = "fedavg"
    # Rows trimmed per side by "trimmed_mean" (ignored otherwise).
    trim_k: int = 1
    bandwidth_gbps: float = 10.0
    latency_ms: float = 0.5
    heartbeat_every_s: float = 5.0
    termination: TerminationCriteria = TerminationCriteria()
    # The typed machinery-knob surface (core/config.FederationConfig).
    # None (default): built from the flat alias fields above.  When given,
    # the config is authoritative and the aliases mirror it.
    config: FederationConfig | None = None

    def __post_init__(self) -> None:
        """Reconcile the typed config with the flat alias fields."""
        if self.config is None:
            object.__setattr__(
                self,
                "config",
                FederationConfig(
                    store_mode=self.store_mode,
                    arena_shards=self.arena_shards,
                    upload_codec=self.upload_codec,
                    flat_uploads=self.flat_uploads,
                    wire_aware=self.wire_aware,
                    profile_decay=self.profile_decay,
                    prox_mu=self.prox_mu,
                    aggregation_rule=self.aggregation_rule,
                    trim_k=self.trim_k,
                    arena_dtype=self.arena_dtype,
                    sparse_mode=self.sparse_mode,
                ),
            )
        else:
            for field in (
                "store_mode", "arena_shards", "upload_codec", "flat_uploads",
                "wire_aware", "profile_decay", "prox_mu",
                "aggregation_rule", "trim_k", "arena_dtype", "sparse_mode",
            ):
                object.__setattr__(self, field, getattr(self.config, field))

    def make_protocol(self):
        """Instantiate the protocol policy this environment describes."""
        if self.protocol == "sync":
            return SyncProtocol(self.local_steps, self.batch_size, self.learning_rate,
                                prox_mu=self.prox_mu)
        if self.protocol == "semi_sync":
            return SemiSyncProtocol(
                self.hyperperiod_s, self.batch_size, self.learning_rate,
                default_steps=self.local_steps, prox_mu=self.prox_mu,
                wire_aware=self.wire_aware,
            )
        if self.protocol == "async":
            return AsyncProtocol(
                self.local_steps, self.batch_size, self.learning_rate,
                self.staleness_alpha, prox_mu=self.prox_mu,
            )
        if self.protocol == "buffered_async":
            return BufferedAsyncProtocol(
                buffer_k=self.buffer_k, local_steps=self.local_steps,
                batch_size=self.batch_size, learning_rate=self.learning_rate,
                staleness_alpha=self.staleness_alpha, prox_mu=self.prox_mu,
            )
        if self.protocol == "deadline":
            return DeadlineCohortProtocol(
                deadline_s=self.deadline_s, local_steps=self.local_steps,
                batch_size=self.batch_size, learning_rate=self.learning_rate,
                prox_mu=self.prox_mu,
            )
        if self.protocol == "reputation":
            return ReputationProtocol(
                fraction=self.reputation_fraction,
                local_steps=self.local_steps, batch_size=self.batch_size,
                learning_rate=self.learning_rate, prox_mu=self.prox_mu,
            )
        raise ValueError(f"unknown protocol {self.protocol}")


class Driver:
    """Owns the federation lifecycle."""

    def __init__(self, env: FederationEnv, aggregate_fn=None):
        self.env = env
        cfg = env.config
        store_mode = env.store_mode
        if store_mode == "auto":
            wants_hash_map = env.lineage_length > 1 or env.store_capacity_bytes is not None
            store_mode = "stack" if wants_hash_map else "arena"
        arena_mesh = None
        if env.arena_shards and env.store_mode == "stack":
            # Mirror Controller's arena_mesh+stack rejection: an explicitly
            # requested stack store cannot be sharded — only the documented
            # auto-pick fallback (lineage/eviction configured) drops the knob.
            raise ValueError(
                "arena_shards requires an arena store; it cannot combine with "
                "store_mode='stack'"
            )
        if env.arena_shards and store_mode == "arena":
            from repro.launch.mesh import make_controller_mesh

            arena_mesh = make_controller_mesh(
                None if env.arena_shards < 0 else env.arena_shards
            )
        self.controller = Controller(
            protocol=env.make_protocol(),
            selection=env.selection,
            aggregate_fn=aggregate_fn,
            server_optimizer=make_server_optimizer(env.server_optimizer, lr=env.server_lr),
            store=(
                ModelStore(env.lineage_length, env.store_capacity_bytes)
                if store_mode == "stack" else None
            ),
            channel=Channel(env.bandwidth_gbps, env.latency_ms,
                            upload_codec=env.upload_codec),
            secure=env.secure_aggregation,
            store_mode=store_mode,
            arena_mesh=arena_mesh,
            flat_uploads=env.flat_uploads,
            profile_decay=env.profile_decay,
            aggregation_rule=env.aggregation_rule,
            trim_k=env.trim_k,
            arena_dtype=env.arena_dtype,
            sparse_mode=env.sparse_mode,
            journal_sink=cfg.journal_sink,
            journal_capacity=cfg.journal_capacity,
            checkpoint_every=cfg.checkpoint_every,
            checkpoint_dir=cfg.checkpoint_dir,
        )
        self._learners: list[Learner] = []
        self._last_heartbeat = 0.0

    # -- initialization (Fig. 8 top) ----------------------------------------
    def initialize(self, initial_params: Any, learners: Sequence[Learner]) -> None:
        """Ship the initial model and register live learners (Fig. 8 init)."""
        log.info("driver: initializing controller with model state")
        self.controller.set_initial_model(initial_params)
        for learner in learners:
            if not learner.ping():
                raise RuntimeError(f"learner {learner.learner_id} not alive at init")
            self.controller.register_learner(learner)
            self._learners.append(learner)
        log.info("driver: %d learners registered", len(learners))

    # -- monitoring ----------------------------------------------------------
    def _heartbeat(self) -> None:
        now = time.monotonic()
        if now - self._last_heartbeat < self.env.heartbeat_every_s:
            return
        self._last_heartbeat = now
        dead = [l.learner_id for l in self._learners if not l.ping()]
        if dead:
            raise RuntimeError(f"dead learners detected: {dead}")

    def _terminated(self, t_start: float, history: list[RoundTimings]) -> bool:
        crit = self.env.termination
        if len(history) >= crit.max_rounds:
            return True
        if crit.max_wallclock_s is not None and time.monotonic() - t_start > crit.max_wallclock_s:
            return True
        if crit.target_metric and history and crit.target_value is not None:
            val = history[-1].metrics.get(crit.target_metric)
            if val is not None:
                if crit.target_mode == "min" and val <= crit.target_value:
                    return True
                if crit.target_mode == "max" and val >= crit.target_value:
                    return True
        return False

    # -- run ------------------------------------------------------------------
    def run(self) -> list[RoundTimings]:
        """Run federation rounds (one engine loop) until termination fires."""
        t_start = time.monotonic()
        history: list[RoundTimings] = []
        engine = self.controller.engine
        if getattr(self.controller.protocol, "continuous", False):
            history = engine.run(total_updates=self.env.termination.max_rounds)
        else:
            while not self._terminated(t_start, history):
                self._heartbeat()
                timings = engine.run(rounds=1)[0]
                history.append(timings)
                log.info(
                    "round %d: fed=%.3fs agg=%.4fs metrics=%s",
                    timings.round_id, timings.federation_round_s,
                    timings.aggregation_s, timings.metrics,
                )
        self.shutdown()
        return history

    # -- shutdown (learners first, then controller) ---------------------------
    def shutdown(self) -> None:
        """Tear the federation down: learners first, then the controller."""
        for learner in self._learners:
            learner.shutdown()
        self.controller.shutdown()
        log.info("driver: federation shut down")
