"""Typed federation configuration: the controller/transport knob surface.

The controller grew organically — store mode, arena sharding, upload codec,
wire-aware sizing, EWMA decay, journal and checkpoint knobs all arrived as
flat keyword arguments scattered over ``Controller`` and ``FederationEnv``.
:class:`FederationConfig` collapses that sprawl into one frozen, validated
dataclass:

* every knob is declared once, with its default and its validity range
  (``__post_init__`` rejects bad values at construction, not three layers
  down inside the engine);
* :meth:`FederationConfig.from_kwargs` builds a config from loose keyword
  arguments and rejects unknown keys by name — the typo-proof entry point
  for YAML/CLI front-ends;
* ``FederationEnv(config=...)`` (``core/driver.py``) is the documented way
  to configure a federation; the legacy flat fields remain as aliases that
  populate (or are populated from) the config.

The training-loop knobs (protocol, steps, batch size, learning rates,
termination) stay on :class:`~repro.core.driver.FederationEnv` — they
describe the *workflow*; this config describes the *machinery* underneath.
"""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["FederationConfig"]

_STORE_MODES = ("auto", "arena", "stack")
_UPLOAD_CODECS = ("raw", "int8", "topk")
_AGGREGATION_RULES = ("fedavg", "median", "trimmed_mean")
_ARENA_DTYPES = ("f32", "int8")
_SPARSE_MODES = ("direct", "densify")


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    """The controller-machinery knobs, typed and validated.

    Parameters
    ----------
    store_mode:
        ``"auto"`` (default) picks the legacy hash-map store when its
        exclusive features (lineage > 1, byte-capacity eviction) are
        configured and the device-resident arena otherwise; ``"arena"`` /
        ``"stack"`` force a backing.
    arena_shards:
        0 = single-device arena; N > 0 column-shards over an N-device mesh;
        -1 shards over every visible device.
    upload_codec:
        Uplink wire format: ``"raw"`` (bit-transparent f32), ``"int8"``
        (blockwise quantization) or ``"topk"`` (magnitude top-k delta
        sparsification with learner-side error feedback — requires
        ``flat_uploads``; see ``docs/DISPATCH.md``).
    flat_uploads:
        Ship the wire manifest at registration so uploads arrive as packed
        flat buffers (the fast path); False keeps pack-on-arrival parity.
    wire_aware:
        Semi-sync only: subtract modeled round-trip wire time from the
        hyper-period step budget.
    profile_decay:
        EWMA decay for the per-learner seconds-per-step estimate, in
        ``[0, 1)``; 0 reproduces last-sample behaviour.
    prox_mu:
        FedProx proximal coefficient (>= 0; 0 disables the proximal term).
    checkpoint_every / checkpoint_dir:
        Crash-consistency cadence: every k completed rounds the engine
        persists the federation state into ``checkpoint_dir``
        (``Controller.save_checkpoint``); both must be set to take effect.
    journal_sink / journal_capacity:
        The engine flight recorder (``core/journal.EventJournal``): an
        optional JSONL sink (path or file object) and the in-memory ring
        bound (0 disables recording).
    aggregation_rule:
        The community-model reduction: ``"fedavg"`` (weighted mean, the
        default), ``"median"`` (coordinate-wise median) or
        ``"trimmed_mean"`` (drop the ``trim_k`` extremes per coordinate per
        side).  The robust rules are order statistics — weight-blind and
        byzantine-tolerant — and are rejected by the staleness-weighted
        protocols (async/FedBuff), whose damping has no order-statistic
        analogue (see docs/PROTOCOLS.md support matrix).
    trim_k:
        Rows trimmed per side by ``"trimmed_mean"`` (>= 1; ignored by the
        other rules).  Must satisfy ``2 * trim_k < n_live`` at aggregate
        time; the arena capacity bound is checked at setup.
    arena_dtype:
        Resident precision of the arena rows: ``"f32"`` (default) keeps
        full-precision rows; ``"int8"`` keeps blockwise-quantized rows
        (int8 groups + per-group f32 scales, ~4x less device memory) and
        aggregates through the fused dequant-into-aggregate path.
        Requires an arena store with the default ``"fedavg"`` rule and no
        secure aggregation — see the support matrix in ``docs/ARENA.md``.
    sparse_mode:
        How a ``"topk"`` upload lands in the store: ``"densify"`` (default)
        scatters the sparse delta into the existing dense f32/int8 row, so
        every store mode and aggregation rule keeps working; ``"direct"``
        keeps the ``(n_max, k)`` index/value arena resident and aggregates
        through the masked scatter-accumulate — the fast path, restricted
        to an arena store with ``"fedavg"``/staleness weighting and the
        default f32 rows.  Ignored (must stay ``"densify"``) for the dense
        codecs — see the support matrix in ``docs/ARENA.md``.
    """

    store_mode: str = "auto"
    arena_shards: int = 0
    upload_codec: str = "raw"
    flat_uploads: bool = True
    wire_aware: bool = True
    profile_decay: float = 0.5
    prox_mu: float = 0.0
    checkpoint_every: int | None = None
    checkpoint_dir: str | None = None
    journal_sink: Any = None
    journal_capacity: int = 4096
    aggregation_rule: str = "fedavg"
    trim_k: int = 1
    arena_dtype: str = "f32"
    sparse_mode: str = "densify"

    def __post_init__(self) -> None:
        """Validate every knob at construction time."""
        if self.store_mode not in _STORE_MODES:
            raise ValueError(
                f"store_mode must be one of {_STORE_MODES}, "
                f"got {self.store_mode!r}"
            )
        if not isinstance(self.arena_shards, int) or self.arena_shards < -1:
            raise ValueError(
                f"arena_shards must be an int >= -1, got {self.arena_shards!r}"
            )
        if self.arena_shards and self.store_mode == "stack":
            raise ValueError(
                "arena_shards requires an arena store; it cannot combine "
                "with store_mode='stack'"
            )
        if (
            isinstance(self.upload_codec, str)
            and self.upload_codec not in _UPLOAD_CODECS
        ):
            raise ValueError(
                f"upload_codec must be one of {_UPLOAD_CODECS} (or a codec "
                f"object), got {self.upload_codec!r}"
            )
        if not 0.0 <= float(self.profile_decay) < 1.0:
            raise ValueError(
                f"profile_decay must be in [0, 1), got {self.profile_decay!r}"
            )
        if float(self.prox_mu) < 0.0:
            raise ValueError(f"prox_mu must be >= 0, got {self.prox_mu!r}")
        if self.checkpoint_every is not None and int(self.checkpoint_every) < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1 (or None), "
                f"got {self.checkpoint_every!r}"
            )
        if int(self.journal_capacity) < 0:
            raise ValueError(
                f"journal_capacity must be >= 0, got {self.journal_capacity!r}"
            )
        if self.aggregation_rule not in _AGGREGATION_RULES:
            raise ValueError(
                f"aggregation_rule must be one of {_AGGREGATION_RULES}, "
                f"got {self.aggregation_rule!r}"
            )
        if not isinstance(self.trim_k, int) or self.trim_k < 1:
            raise ValueError(f"trim_k must be an int >= 1, got {self.trim_k!r}")
        if self.arena_dtype not in _ARENA_DTYPES:
            raise ValueError(
                f"arena_dtype must be one of {_ARENA_DTYPES}, "
                f"got {self.arena_dtype!r}"
            )
        if self.arena_dtype == "int8" and self.store_mode == "stack":
            raise ValueError(
                "arena_dtype='int8' requires an arena store; it cannot "
                "combine with store_mode='stack'"
            )
        if self.arena_dtype == "int8" and self.aggregation_rule != "fedavg":
            raise ValueError(
                "arena_dtype='int8' supports only aggregation_rule='fedavg'; "
                "the robust order-statistic rules sort full-precision rows "
                f"(got {self.aggregation_rule!r}) — see docs/ARENA.md"
            )
        if self.sparse_mode not in _SPARSE_MODES:
            raise ValueError(
                f"sparse_mode must be one of {_SPARSE_MODES}, "
                f"got {self.sparse_mode!r}"
            )
        is_topk = self.upload_codec == "topk" or (
            not isinstance(self.upload_codec, str)
            and getattr(self.upload_codec, "codec_id", None) == "topk"
        )
        if is_topk and not self.flat_uploads:
            raise ValueError(
                "upload_codec='topk' requires flat_uploads=True: the "
                "error-feedback residual lives learner-side against the "
                "shipped wire manifest"
            )
        if self.sparse_mode == "direct":
            if not is_topk:
                raise ValueError(
                    "sparse_mode='direct' requires upload_codec='topk' "
                    f"(got {self.upload_codec!r})"
                )
            if self.store_mode == "stack":
                raise ValueError(
                    "sparse_mode='direct' requires an arena store; it "
                    "cannot combine with store_mode='stack'"
                )
            if self.aggregation_rule != "fedavg":
                raise ValueError(
                    "sparse_mode='direct' supports only "
                    "aggregation_rule='fedavg'; the robust order-statistic "
                    "rules need dense rows — use sparse_mode='densify' "
                    f"(got {self.aggregation_rule!r})"
                )
            if self.arena_dtype != "f32":
                raise ValueError(
                    "sparse_mode='direct' keeps its own (n, k) sparse "
                    "arena; it cannot combine with "
                    f"arena_dtype={self.arena_dtype!r}"
                )

    @classmethod
    def from_kwargs(cls, **kwargs: Any) -> "FederationConfig":
        """Build a config from loose keyword arguments, typo-proof.

        Unknown keys raise ``TypeError`` naming the valid fields — the
        entry point for YAML/CLI front-ends that collect knobs as dicts.
        """
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(kwargs) - known)
        if unknown:
            raise TypeError(
                f"unknown FederationConfig field(s) {unknown}; "
                f"valid fields: {sorted(known)}"
            )
        return cls(**kwargs)

    def replace(self, **changes: Any) -> "FederationConfig":
        """A copy with the given fields changed (re-validated)."""
        return dataclasses.replace(self, **changes)
