"""Global (server-side) optimizers over packed buffers.

MetisFL's Table 1 'GlobalOpt' row: the controller may apply a server-side
optimization rule to the aggregated model instead of plain replacement.  We
implement the standard adaptive-server family (Reddi et al., *Adaptive
Federated Optimization*): the aggregated learner average defines a
*pseudo-gradient* ``Δ = x_global - x_agg`` which a server optimizer consumes.

All states/updates are flat ``(P,)`` buffers, so server optimization inherits
the same embarrassing parallelism as aggregation.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["ServerOptState", "ServerOptimizer", "make_server_optimizer"]


class ServerOptState(NamedTuple):
    """Adaptive-server state: step counter plus first/second moments."""

    step: jax.Array  # scalar int32
    m: jax.Array  # first moment, (P,)
    v: jax.Array  # second moment, (P,)


@dataclasses.dataclass(frozen=True)
class ServerOptimizer:
    """A (init, apply) pair over packed buffers."""

    name: str
    init: Callable[[jax.Array], ServerOptState]
    # (state, x_global, x_agg) -> (new_state, new_x_global)
    apply: Callable[[ServerOptState, jax.Array, jax.Array], tuple[ServerOptState, jax.Array]]


def make_server_optimizer(
    name: str = "fedavg",
    lr: float = 1.0,
    beta1: float = 0.9,
    beta2: float = 0.99,
    eps: float = 1e-3,
    momentum: float = 0.9,
) -> ServerOptimizer:
    """Build a server optimizer: fedavg | sgdm | fedadagrad | fedyogi | fedadam."""

    def init(x: jax.Array) -> ServerOptState:
        z = jnp.zeros_like(x, dtype=jnp.float32)
        return ServerOptState(step=jnp.zeros((), jnp.int32), m=z, v=z)

    def _delta(x_global, x_agg):
        # server pseudo-gradient: direction from global towards the average
        return x_global.astype(jnp.float32) - x_agg.astype(jnp.float32)

    if name == "fedavg":

        def apply(state, x_global, x_agg):
            # plain replacement (lr=1) or a server learning rate interpolation
            new = x_global.astype(jnp.float32) - lr * _delta(x_global, x_agg)
            return state._replace(step=state.step + 1), new

    elif name == "sgdm":

        def apply(state, x_global, x_agg):
            g = _delta(x_global, x_agg)
            m = momentum * state.m + g
            new = x_global.astype(jnp.float32) - lr * m
            return ServerOptState(state.step + 1, m, state.v), new

    elif name in ("fedadagrad", "fedyogi", "fedadam"):

        def apply(state, x_global, x_agg):
            g = _delta(x_global, x_agg)
            m = beta1 * state.m + (1.0 - beta1) * g
            g2 = g * g
            if name == "fedadagrad":
                v = state.v + g2
            elif name == "fedyogi":
                v = state.v - (1.0 - beta2) * g2 * jnp.sign(state.v - g2)
            else:  # fedadam
                v = beta2 * state.v + (1.0 - beta2) * g2
            new = x_global.astype(jnp.float32) - lr * m / (jnp.sqrt(v) + eps)
            return ServerOptState(state.step + 1, m, v), new

    else:
        raise ValueError(f"unknown server optimizer: {name}")

    return ServerOptimizer(name=name, init=init, apply=jax.jit(apply))
