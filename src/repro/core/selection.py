"""Learner selection policies for the federation controller.

Before each training/evaluation round the controller *selects* the
participating learners (paper Figs. 9/10: "select learners" precedes task
scheduling).  The paper's stress tests use full participation; production
controllers also sample.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["SelectionPolicy", "select_learners"]


@dataclasses.dataclass(frozen=True)
class SelectionPolicy:
    """How the controller picks each round's cohort: everyone (``all``),
    uniformly at random, or dataset-size-weighted (``stratified``)."""

    kind: str = "all"  # all | random | stratified
    fraction: float = 1.0  # for random/stratified: fraction of learners per round
    min_learners: int = 1
    seed: int = 0


def select_learners(
    policy: SelectionPolicy,
    learner_ids: Sequence[str],
    round_id: int,
    num_examples: dict[str, int] | None = None,
) -> list[str]:
    """Select the round's participants per ``policy`` (deterministic in
    ``(seed, round_id)`` so runs are reproducible)."""
    ids = list(learner_ids)
    if not ids:
        return []
    if policy.kind == "all":
        return ids

    k = max(policy.min_learners, int(round(policy.fraction * len(ids))))
    k = min(k, len(ids))
    rng = np.random.default_rng(np.uint32(policy.seed) + np.uint32(round_id))

    if policy.kind == "random":
        return [ids[i] for i in rng.choice(len(ids), size=k, replace=False)]

    if policy.kind == "stratified":
        # Sample proportionally to dataset size (larger silos more likely),
        # without replacement — a simple importance-sampling selection.
        if not num_examples:
            raise ValueError("stratified selection needs num_examples")
        w = np.array([num_examples.get(i, 1) for i in ids], dtype=np.float64)
        w = w / w.sum()
        return [ids[i] for i in rng.choice(len(ids), size=k, replace=False, p=w)]

    raise ValueError(f"unknown selection kind: {policy.kind}")
