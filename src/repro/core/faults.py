"""Deterministic, seeded fault injection for scale-out stress runs.

The stress harness (``tests/stress/harness.py``) drives ``RoundEngine.run``
with thousands of simulated learners; this module supplies the chaos — and
makes it *replayable*.  Every stochastic decision (who drops out, whose
upload is lost or duplicated, how badly a straggler's step time inflates,
what bandwidth cap a learner gets) is drawn from its own
``numpy.random.default_rng`` seeded by ``(spec.seed, *key)`` where the key
names the decision (``("fate", learner_id, round_id)`` etc.) — so outcomes
are a pure function of the fault seed and the decision's identity, never of
thread timing, draw order, or Python's per-process ``hash()`` salt.  Two
runs with the same seed therefore inject byte-identical faults
(``tests/stress/test_stress.py`` pins byte-identical journal JSONL).

Fault taxonomy (all knobs on :class:`FaultSpec`):

- **Churn** — per-round learner dropout (``dropout_rate``) and rejoin of
  previously-dropped learners (``rejoin_rate``), floor-guarded by
  ``min_active``.  The harness maps these onto
  ``Controller.deregister_learner`` / ``register_learner``.
- **Upload faults** — loss (``upload_loss_rate``: the payload crosses the
  wire but the engine treats it as lost) and duplication
  (``upload_dup_rate``: the engine re-posts the arrival once), decided per
  ``(learner, round)`` by :meth:`FaultInjector.upload_fate` and stamped
  into upload metadata by :class:`FaultyChannel`.
- **Stragglers** — a fixed ``straggler_rate`` subset of learners whose
  reported step time is inflated by a Pareto-tailed factor
  (``straggler_tail``) each round: the heavy-tailed client populations
  that motivate buffered asynchrony.
- **Bandwidth caps** — per-learner log-uniform caps between
  ``bandwidth_min_gbps`` and ``bandwidth_max_gbps``, threaded through
  ``Channel.set_learner_bandwidth`` into the virtual wire clock.

Counters land under ``engine.faults.*`` in the controller's telemetry
(``stragglers`` here; ``dropouts``/``rejoins`` in the controller;
``uploads_lost``/``uploads_duplicated``/``uploads_late``/``deadline_fires``
in the engine) — see ``docs/STRESS.md`` for the full catalogue.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any

import numpy as np

from repro.core.transport import Channel

__all__ = ["FaultSpec", "FaultInjector", "FaultyChannel"]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault model for one stress run (all rates in [0, 1]).

    ``seed`` is the *only* source of randomness — same spec, same faults.
    ``base_step_time_s`` is the healthy simulated seconds-per-step that
    straggler inflation multiplies.  Bandwidth caps are disabled when
    either bound is 0.  ``min_active`` floors churn so the federation
    never drops below a quorum.
    """

    seed: int = 0
    dropout_rate: float = 0.0
    rejoin_rate: float = 0.0
    upload_loss_rate: float = 0.0
    upload_dup_rate: float = 0.0
    straggler_rate: float = 0.0
    straggler_tail: float = 1.5
    base_step_time_s: float = 1e-4
    bandwidth_min_gbps: float = 0.0
    bandwidth_max_gbps: float = 0.0
    min_active: int = 1

    def __post_init__(self):
        """Validate rates, tail, and bandwidth bounds at construction."""
        for f in ("dropout_rate", "rejoin_rate", "upload_loss_rate",
                  "upload_dup_rate", "straggler_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")
        if self.upload_loss_rate + self.upload_dup_rate > 1.0:
            raise ValueError("upload_loss_rate + upload_dup_rate must be <= 1")
        if self.straggler_tail <= 0:
            raise ValueError("straggler_tail must be positive")
        if self.base_step_time_s <= 0:
            raise ValueError("base_step_time_s must be positive")
        if self.bandwidth_min_gbps < 0 or self.bandwidth_max_gbps < 0:
            raise ValueError("bandwidth bounds must be >= 0")
        if (self.bandwidth_min_gbps > 0) != (self.bandwidth_max_gbps > 0):
            raise ValueError("set both bandwidth bounds or neither")
        if self.bandwidth_min_gbps > self.bandwidth_max_gbps:
            raise ValueError("bandwidth_min_gbps must be <= bandwidth_max_gbps")
        if self.min_active < 1:
            raise ValueError("min_active must be >= 1")


class FaultInjector:
    """Draws every fault decision from ``(seed, decision-key)``-keyed rngs.

    Stateless per decision — the only mutable state is the ``_down`` roster
    that :meth:`churn` maintains so rejoins target actually-dropped
    learners.  Pass the controller's ``Telemetry`` to count straggler
    inflations under ``engine.faults.stragglers``.
    """

    def __init__(self, spec: FaultSpec, telemetry: Any = None):
        """Bind a spec; optionally a Telemetry for the straggler counter."""
        self.spec = spec
        self._down: dict[str, int] = {}
        self._c_stragglers = (
            telemetry.counter("engine.faults.stragglers")
            if telemetry is not None else None
        )

    def _rng(self, *key: Any) -> np.random.Generator:
        """A fresh generator for one named decision (order-independent).

        Seed material is ``[spec.seed] + crc32(str(k))`` per key part —
        crc32, not ``hash()``, because Python string hashing is salted
        per process and would break cross-run determinism.
        """
        return np.random.default_rng(
            [self.spec.seed & 0xFFFFFFFF]
            + [zlib.crc32(str(k).encode()) for k in key]
        )

    # -- stragglers ---------------------------------------------------------
    def is_straggler(self, learner_id: str) -> bool:
        """Whether this learner belongs to the fixed straggler subset."""
        if self.spec.straggler_rate <= 0:
            return False
        return bool(
            self._rng("straggler", learner_id).uniform()
            < self.spec.straggler_rate
        )

    def step_time(self, learner_id: str, round_id: int) -> float:
        """Simulated seconds-per-step for one fit (Pareto-inflated tail).

        Healthy learners report ``base_step_time_s``; stragglers multiply
        it by ``(1 - u)^(-1/tail)`` — a Pareto draw whose tail index is
        ``straggler_tail`` (heavier for smaller values), redrawn per round.
        """
        t = self.spec.base_step_time_s
        if self.is_straggler(learner_id):
            u = self._rng("steptime", learner_id, round_id).uniform()
            t *= float((1.0 - u) ** (-1.0 / self.spec.straggler_tail))
            if self._c_stragglers is not None:
                self._c_stragglers.add(1)
        return t

    # -- bandwidth ----------------------------------------------------------
    def bandwidth_cap(self, learner_id: str) -> float | None:
        """Per-learner log-uniform bandwidth cap in Gbps (None = uncapped)."""
        lo, hi = self.spec.bandwidth_min_gbps, self.spec.bandwidth_max_gbps
        if lo <= 0:
            return None
        u = self._rng("bandwidth", learner_id).uniform()
        return float(np.exp(np.log(lo) + u * (np.log(hi) - np.log(lo))))

    # -- upload fate --------------------------------------------------------
    def upload_fate(self, learner_id: str, round_id: int) -> str:
        """Fate of one upload: ``"lost"``, ``"dup"``, or ``"ok"``.

        One uniform draw per ``(learner, round)`` split against the loss
        then loss+dup thresholds, so the three outcomes are mutually
        exclusive and individually seeded.
        """
        loss, dup = self.spec.upload_loss_rate, self.spec.upload_dup_rate
        if loss <= 0 and dup <= 0:
            return "ok"
        u = self._rng("fate", learner_id, round_id).uniform()
        if u < loss:
            return "lost"
        if u < loss + dup:
            return "dup"
        return "ok"

    # -- churn --------------------------------------------------------------
    def churn(
        self, round_id: int, active_ids: list[str]
    ) -> tuple[list[str], list[str]]:
        """Per-round membership churn: who leaves, who rejoins.

        Each active learner leaves with ``dropout_rate`` (floor-guarded so
        at least ``min_active`` stay); each currently-down learner rejoins
        with ``rejoin_rate``.  Down learners are iterated in sorted order
        and both decisions are per-``(learner, round)`` seeded, so churn
        is deterministic regardless of caller iteration order.  Updates
        the internal down-roster; returns ``(leave, rejoin)`` id lists.
        """
        spec = self.spec
        leave: list[str] = []
        if spec.dropout_rate > 0:
            budget = len(active_ids) - spec.min_active
            for lid in active_ids:
                if budget <= 0:
                    break
                if self._rng("drop", lid, round_id).uniform() < spec.dropout_rate:
                    leave.append(lid)
                    budget -= 1
        rejoin: list[str] = []
        if spec.rejoin_rate > 0:
            for lid in sorted(self._down):
                if self._rng("rejoin", lid, round_id).uniform() < spec.rejoin_rate:
                    rejoin.append(lid)
        for lid in leave:
            self._down[lid] = int(round_id)
        for lid in rejoin:
            self._down.pop(lid, None)
        return leave, rejoin


class FaultyChannel(Channel):
    """A :class:`Channel` whose uplink stamps fault fates into metadata.

    ``upload()`` consults the injector's :meth:`FaultInjector.upload_fate`
    for the sending ``(learner_id, round_id)`` and, when the fate is not
    ``"ok"``, writes ``metadata["fault"] = "lost"|"dup"`` before minting
    the envelope — the wire half still measures the payload (a lost upload
    crossed the wire; it is lost *at* the controller), and the engine's
    arrival handler enacts the fate.
    """

    def __init__(self, injector: FaultInjector, **kwargs: Any):
        """A measured channel bound to one fault injector."""
        super().__init__(**kwargs)
        self.injector = injector

    def upload(
        self, buffer: Any, metadata: dict | None = None, codec: Any = None
    ) -> Any:
        """Encode one upload, stamping its injected fate into metadata."""
        md = dict(metadata or {})
        lid, rid = md.get("learner_id"), md.get("round_id")
        if lid is not None and rid is not None:
            fate = self.injector.upload_fate(lid, int(rid))
            if fate != "ok":
                md["fault"] = fate
        return super().upload(buffer, metadata=md, codec=codec)
