"""Deterministic, seeded fault injection for scale-out stress runs.

The stress harness (``tests/stress/harness.py``) drives ``RoundEngine.run``
with thousands of simulated learners; this module supplies the chaos — and
makes it *replayable*.  Every stochastic decision (who drops out, whose
upload is lost or duplicated, how badly a straggler's step time inflates,
what bandwidth cap a learner gets) is drawn from its own
``numpy.random.default_rng`` seeded by ``(spec.seed, *key)`` where the key
names the decision (``("fate", learner_id, round_id)`` etc.) — so outcomes
are a pure function of the fault seed and the decision's identity, never of
thread timing, draw order, or Python's per-process ``hash()`` salt.  Two
runs with the same seed therefore inject byte-identical faults
(``tests/stress/test_stress.py`` pins byte-identical journal JSONL).

Fault taxonomy (all knobs on :class:`FaultSpec`):

- **Churn** — per-round learner dropout (``dropout_rate``) and rejoin of
  previously-dropped learners (``rejoin_rate``), floor-guarded by
  ``min_active``.  The harness maps these onto
  ``Controller.deregister_learner`` / ``register_learner``.
- **Upload faults** — loss (``upload_loss_rate``: the payload crosses the
  wire but the engine treats it as lost) and duplication
  (``upload_dup_rate``: the engine re-posts the arrival once), decided per
  ``(learner, round)`` by :meth:`FaultInjector.upload_fate` and stamped
  into upload metadata by :class:`FaultyChannel`.
- **Stragglers** — a fixed ``straggler_rate`` subset of learners whose
  reported step time is inflated by a Pareto-tailed factor
  (``straggler_tail``) each round: the heavy-tailed client populations
  that motivate buffered asynchrony.
- **Bandwidth caps** — per-learner log-uniform caps between
  ``bandwidth_min_gbps`` and ``bandwidth_max_gbps``, threaded through
  ``Channel.set_learner_bandwidth`` into the virtual wire clock.
- **Adversaries** — a fixed ``adversarial_fraction`` subset of learners
  (byzantine clients) whose upload *payloads* are corrupted in flight:
  each round one fate is drawn from ``adversarial_fates`` (``"nan"`` —
  poison the buffer with NaNs, ``"scale"`` — multiply it by
  ``adversarial_scale``, ``"sign_flip"`` — negate it, ``"garbage"`` —
  replace it with finite uniform noise) and applied by
  :class:`FaultyChannel` before the envelope is minted.  Corruption only
  applies when the transport fate is ``"ok"`` — a lost upload never
  reaches ingest, so the admission screen's rejected counter reconciles
  exactly with the number of injected ``nan`` fates.

Counters land under ``engine.faults.*`` in the controller's telemetry
(``stragglers`` here; ``dropouts``/``rejoins`` in the controller;
``uploads_lost``/``uploads_duplicated``/``uploads_late``/``deadline_fires``
in the engine) — see ``docs/STRESS.md`` for the full catalogue.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any

import numpy as np

from repro.core.transport import Channel

__all__ = ["ADVERSARIAL_FATES", "FaultSpec", "FaultInjector", "FaultyChannel"]

#: The byzantine payload corruptions FaultyChannel can stamp onto uploads.
ADVERSARIAL_FATES = ("nan", "scale", "sign_flip", "garbage")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault model for one stress run (all rates in [0, 1]).

    ``seed`` is the *only* source of randomness — same spec, same faults.
    ``base_step_time_s`` is the healthy simulated seconds-per-step that
    straggler inflation multiplies.  Bandwidth caps are disabled when
    either bound is 0.  ``min_active`` floors churn so the federation
    never drops below a quorum.
    """

    seed: int = 0
    dropout_rate: float = 0.0
    rejoin_rate: float = 0.0
    upload_loss_rate: float = 0.0
    upload_dup_rate: float = 0.0
    straggler_rate: float = 0.0
    straggler_tail: float = 1.5
    base_step_time_s: float = 1e-4
    bandwidth_min_gbps: float = 0.0
    bandwidth_max_gbps: float = 0.0
    min_active: int = 1
    # Byzantine clients: a fixed adversarial_fraction of learners corrupt
    # every upload payload, drawing one fate per round from
    # adversarial_fates (see ADVERSARIAL_FATES).  "scale" multiplies the
    # buffer by adversarial_scale.
    adversarial_fraction: float = 0.0
    adversarial_fates: tuple = ("scale", "sign_flip")
    adversarial_scale: float = 100.0

    def __post_init__(self):
        """Validate rates, tail, and bandwidth bounds at construction."""
        for f in ("dropout_rate", "rejoin_rate", "upload_loss_rate",
                  "upload_dup_rate", "straggler_rate",
                  "adversarial_fraction"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")
        if self.upload_loss_rate + self.upload_dup_rate > 1.0:
            raise ValueError("upload_loss_rate + upload_dup_rate must be <= 1")
        if self.straggler_tail <= 0:
            raise ValueError("straggler_tail must be positive")
        if self.base_step_time_s <= 0:
            raise ValueError("base_step_time_s must be positive")
        if self.bandwidth_min_gbps < 0 or self.bandwidth_max_gbps < 0:
            raise ValueError("bandwidth bounds must be >= 0")
        if (self.bandwidth_min_gbps > 0) != (self.bandwidth_max_gbps > 0):
            raise ValueError("set both bandwidth bounds or neither")
        if self.bandwidth_min_gbps > self.bandwidth_max_gbps:
            raise ValueError("bandwidth_min_gbps must be <= bandwidth_max_gbps")
        if self.min_active < 1:
            raise ValueError("min_active must be >= 1")
        object.__setattr__(
            self, "adversarial_fates", tuple(self.adversarial_fates)
        )
        if self.adversarial_fraction > 0:
            if not self.adversarial_fates:
                raise ValueError(
                    "adversarial_fraction > 0 needs at least one fate in "
                    "adversarial_fates"
                )
            unknown = [f for f in self.adversarial_fates
                       if f not in ADVERSARIAL_FATES]
            if unknown:
                raise ValueError(
                    f"unknown adversarial fate(s) {unknown}; "
                    f"valid: {ADVERSARIAL_FATES}"
                )
        if self.adversarial_scale <= 0:
            raise ValueError("adversarial_scale must be positive")


class FaultInjector:
    """Draws every fault decision from ``(seed, decision-key)``-keyed rngs.

    Stateless per decision — the only mutable state is the ``_down`` roster
    that :meth:`churn` maintains so rejoins target actually-dropped
    learners.  Pass the controller's ``Telemetry`` to count straggler
    inflations under ``engine.faults.stragglers``.
    """

    def __init__(self, spec: FaultSpec, telemetry: Any = None):
        """Bind a spec; optionally a Telemetry for the straggler counter."""
        self.spec = spec
        self._down: dict[str, int] = {}
        self._c_stragglers = (
            telemetry.counter("engine.faults.stragglers")
            if telemetry is not None else None
        )

    def _rng(self, *key: Any) -> np.random.Generator:
        """A fresh generator for one named decision (order-independent).

        Seed material is ``[spec.seed] + crc32(str(k))`` per key part —
        crc32, not ``hash()``, because Python string hashing is salted
        per process and would break cross-run determinism.
        """
        return np.random.default_rng(
            [self.spec.seed & 0xFFFFFFFF]
            + [zlib.crc32(str(k).encode()) for k in key]
        )

    # -- stragglers ---------------------------------------------------------
    def is_straggler(self, learner_id: str) -> bool:
        """Whether this learner belongs to the fixed straggler subset."""
        if self.spec.straggler_rate <= 0:
            return False
        return bool(
            self._rng("straggler", learner_id).uniform()
            < self.spec.straggler_rate
        )

    def step_time(self, learner_id: str, round_id: int) -> float:
        """Simulated seconds-per-step for one fit (Pareto-inflated tail).

        Healthy learners report ``base_step_time_s``; stragglers multiply
        it by ``(1 - u)^(-1/tail)`` — a Pareto draw whose tail index is
        ``straggler_tail`` (heavier for smaller values), redrawn per round.
        """
        t = self.spec.base_step_time_s
        if self.is_straggler(learner_id):
            u = self._rng("steptime", learner_id, round_id).uniform()
            t *= float((1.0 - u) ** (-1.0 / self.spec.straggler_tail))
            if self._c_stragglers is not None:
                self._c_stragglers.add(1)
        return t

    # -- bandwidth ----------------------------------------------------------
    def bandwidth_cap(self, learner_id: str) -> float | None:
        """Per-learner log-uniform bandwidth cap in Gbps (None = uncapped)."""
        lo, hi = self.spec.bandwidth_min_gbps, self.spec.bandwidth_max_gbps
        if lo <= 0:
            return None
        u = self._rng("bandwidth", learner_id).uniform()
        return float(np.exp(np.log(lo) + u * (np.log(hi) - np.log(lo))))

    # -- upload fate --------------------------------------------------------
    def upload_fate(self, learner_id: str, round_id: int) -> str:
        """Fate of one upload: ``"lost"``, ``"dup"``, or ``"ok"``.

        One uniform draw per ``(learner, round)`` split against the loss
        then loss+dup thresholds, so the three outcomes are mutually
        exclusive and individually seeded.
        """
        loss, dup = self.spec.upload_loss_rate, self.spec.upload_dup_rate
        if loss <= 0 and dup <= 0:
            return "ok"
        u = self._rng("fate", learner_id, round_id).uniform()
        if u < loss:
            return "lost"
        if u < loss + dup:
            return "dup"
        return "ok"

    # -- adversaries --------------------------------------------------------
    def is_adversarial(self, learner_id: str) -> bool:
        """Whether this learner belongs to the fixed byzantine subset."""
        if self.spec.adversarial_fraction <= 0:
            return False
        return bool(
            self._rng("adversary", learner_id).uniform()
            < self.spec.adversarial_fraction
        )

    def adversarial_fate(self, learner_id: str, round_id: int) -> str | None:
        """The payload corruption for one upload (None for honest learners).

        Adversaries corrupt *every* upload; which fate they apply is
        redrawn per ``(learner, round)`` from ``spec.adversarial_fates``.
        """
        if not self.is_adversarial(learner_id):
            return None
        fates = self.spec.adversarial_fates
        if len(fates) == 1:
            return fates[0]
        i = int(self._rng("advfate", learner_id, round_id).integers(len(fates)))
        return fates[i]

    def corrupt(
        self, buffer: Any, fate: str, learner_id: str, round_id: int
    ) -> Any:
        """Apply one adversarial fate to an upload payload (host-side copy).

        ``"nan"`` poisons the whole buffer (any single NaN makes the L2
        norm non-finite, so the admission screen rejects it); ``"scale"``
        and ``"sign_flip"`` stay finite — scale blow-ups are clippable,
        sign flips are norm-invariant and *invisible* to the screen, which
        is exactly why they need a robust aggregation rule.  ``"garbage"``
        replaces the payload with finite uniform noise drawn from the
        decision-keyed rng (never NaN, so only ``"nan"`` fates feed the
        rejected counter).
        """
        arr = np.array(buffer, copy=True)
        if fate == "nan":
            arr[...] = np.nan
        elif fate == "scale":
            arr *= self.spec.adversarial_scale
        elif fate == "sign_flip":
            arr = -arr
        elif fate == "garbage":
            rng = self._rng("garbage", learner_id, round_id)
            arr = rng.uniform(-1.0, 1.0, size=arr.shape).astype(arr.dtype)
        else:  # pragma: no cover - spec validation rejects unknown fates
            raise ValueError(f"unknown adversarial fate {fate!r}")
        return arr

    # -- churn --------------------------------------------------------------
    def churn(
        self, round_id: int, active_ids: list[str]
    ) -> tuple[list[str], list[str]]:
        """Per-round membership churn: who leaves, who rejoins.

        Each active learner leaves with ``dropout_rate`` (floor-guarded so
        at least ``min_active`` stay); each currently-down learner rejoins
        with ``rejoin_rate``.  Down learners are iterated in sorted order
        and both decisions are per-``(learner, round)`` seeded, so churn
        is deterministic regardless of caller iteration order.  Updates
        the internal down-roster; returns ``(leave, rejoin)`` id lists.
        """
        spec = self.spec
        leave: list[str] = []
        if spec.dropout_rate > 0:
            budget = len(active_ids) - spec.min_active
            for lid in active_ids:
                if budget <= 0:
                    break
                if self._rng("drop", lid, round_id).uniform() < spec.dropout_rate:
                    leave.append(lid)
                    budget -= 1
        rejoin: list[str] = []
        if spec.rejoin_rate > 0:
            for lid in sorted(self._down):
                if self._rng("rejoin", lid, round_id).uniform() < spec.rejoin_rate:
                    rejoin.append(lid)
        for lid in leave:
            self._down[lid] = int(round_id)
        for lid in rejoin:
            self._down.pop(lid, None)
        return leave, rejoin


class FaultyChannel(Channel):
    """A :class:`Channel` whose uplink stamps fault fates into metadata.

    ``upload()`` consults the injector's :meth:`FaultInjector.upload_fate`
    for the sending ``(learner_id, round_id)`` and, when the fate is not
    ``"ok"``, writes ``metadata["fault"] = "lost"|"dup"`` before minting
    the envelope — the wire half still measures the payload (a lost upload
    crossed the wire; it is lost *at* the controller), and the engine's
    arrival handler enacts the fate.

    Byzantine learners additionally have their payload corrupted in
    flight (:meth:`FaultInjector.corrupt`) with the fate stamped as
    ``metadata["adversarial"]`` and counted under
    ``engine.faults.adversarial.<fate>`` — but only when the transport
    fate is ``"ok"``: a corrupted-then-lost upload would break the
    rejected-counter reconciliation the stress tests pin.
    """

    def __init__(self, injector: FaultInjector, **kwargs: Any):
        """A measured channel bound to one fault injector."""
        super().__init__(**kwargs)
        self.injector = injector
        self._adv_counters = {
            fate: self.telemetry.counter(f"engine.faults.adversarial.{fate}")
            for fate in ADVERSARIAL_FATES
        }

    def upload(
        self, buffer: Any, metadata: dict | None = None, codec: Any = None
    ) -> Any:
        """Encode one upload, stamping its injected fate into metadata."""
        md = dict(metadata or {})
        lid, rid = md.get("learner_id"), md.get("round_id")
        if lid is not None and rid is not None:
            fate = self.injector.upload_fate(lid, int(rid))
            if fate != "ok":
                md["fault"] = fate
            else:
                # Scripted/duck-typed injectors in tests may only implement
                # upload_fate; adversarial corruption is opt-in.
                adv_fate = getattr(self.injector, "adversarial_fate", None)
                adv = adv_fate(lid, int(rid)) if adv_fate is not None else None
                if adv is not None:
                    buffer = self.injector.corrupt(buffer, adv, lid, int(rid))
                    md["adversarial"] = adv
                    self._adv_counters[adv].add(1)
        return super().upload(buffer, metadata=md, codec=codec)
