"""Unified telemetry registry: the controller's single metrics surface.

MetisFL treats the controller as the first-class citizen of an FL system;
this module is where its runtime state becomes *observable*.  Every counter
that used to live as a bespoke attribute — ``ChannelStats`` fields,
``ArenaStore.bytes_ingested``, ``Controller.dispatch_serializations`` — is
now an instrument registered in one :class:`Telemetry` registry, reachable
through ``controller.telemetry``:

* :class:`Counter` — monotonically increasing totals (messages, bytes,
  serializations, cumulative seconds).
* :class:`Gauge` — last-set point-in-time values (current model version,
  round id).
* :class:`Histogram` — streaming summaries (count/sum/min/max/last) of
  per-event observations (per-round wall-clock, aggregation seconds).

``snapshot()`` renders the whole registry as one JSON-able dict — the same
payload feeds the event journal's records (``core/journal.py``), the nightly
bench JSON artifact (``benchmarks/bench_round.py --journal``) and ad-hoc
inspection.  Names are dotted paths (``channel.upload_bytes``,
``store.arena.total_writes``, ``controller.dispatch_serializations``); the
full catalogue lives in ``docs/OBSERVABILITY.md``.

Thread-safety: each instrument mutates under its own lock and the registry
itself locks get-or-create, so executor threads (the engine's dispatch pool)
can bump counters concurrently with a ``snapshot()`` reader.
"""

from __future__ import annotations

import threading

__all__ = ["Counter", "Gauge", "Histogram", "Telemetry"]


class Counter:
    """A monotonically increasing total (int or float).

    ``add`` is the only mutator; integer adds keep the value an ``int`` so
    exact-count assertions (``stats.messages == 3``) stay exact.
    """

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value: int | float = 0

    @property
    def value(self) -> int | float:
        """The current cumulative total."""
        with self._lock:
            return self._value

    def add(self, n: int | float = 1) -> None:
        """Increase the total by ``n`` (must be >= 0: counters never go down)."""
        if n < 0:
            raise ValueError(f"counter {self.name}: add() must be >= 0, got {n}")
        with self._lock:
            self._value += n

    def render(self) -> int | float:
        """The snapshot representation (the scalar total)."""
        return self.value


class Gauge:
    """A point-in-time value: the last ``set()`` wins."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value: int | float = 0

    @property
    def value(self) -> int | float:
        """The most recently set value."""
        with self._lock:
            return self._value

    def set(self, v: int | float) -> None:
        """Record the current value (overwrites the previous one)."""
        with self._lock:
            self._value = v

    def render(self) -> int | float:
        """The snapshot representation (the scalar value)."""
        return self.value


class Histogram:
    """A streaming summary of per-event observations.

    Tracks ``count``/``sum``/``min``/``max``/``last`` — enough for the
    bench artifacts (mean = sum/count) without storing samples.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.last = 0.0

    def observe(self, v: float) -> None:
        """Fold one observation into the summary."""
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            self.last = v

    @property
    def mean(self) -> float:
        """Mean observation (0.0 before the first observe)."""
        with self._lock:
            return self.sum / self.count if self.count else 0.0

    def render(self) -> dict:
        """The snapshot representation: a count/sum/min/max/last dict."""
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "last": 0.0}
            return {"count": self.count, "sum": self.sum, "min": self.min,
                    "max": self.max, "last": self.last}


class Telemetry:
    """The instrument registry — one per federation (``controller.telemetry``).

    ``counter``/``gauge``/``histogram`` are get-or-create: the first call
    registers the instrument, later calls return the same object (asking for
    an existing name with a different instrument kind raises).  ``value``
    reads one instrument's scalar; ``snapshot`` renders everything at once.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, Counter | Gauge | Histogram] = {}

    def _get_or_create(self, cls, name: str, help: str):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, help)
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"telemetry name {name!r} is a {inst.kind}, not a "
                    f"{cls.kind}"
                )
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        """Get or create the :class:`Counter` registered under ``name``."""
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        """Get or create the :class:`Gauge` registered under ``name``."""
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        """Get or create the :class:`Histogram` registered under ``name``."""
        return self._get_or_create(Histogram, name, help)

    def get(self, name: str):
        """The instrument registered under ``name`` (None if absent)."""
        with self._lock:
            return self._instruments.get(name)

    def value(self, name: str, default: int | float = 0) -> int | float:
        """One instrument's scalar value (histograms: their mean).

        The single read API the observability surface consolidates on:
        ``controller.telemetry.value("channel.upload_bytes")`` replaces the
        old direct attribute pokes.  ``default`` is returned for names that
        were never registered.
        """
        inst = self.get(name)
        if inst is None:
            return default
        if isinstance(inst, Histogram):
            return inst.mean
        return inst.value

    def names(self) -> list[str]:
        """Every registered instrument name, sorted."""
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> dict:
        """Render the whole registry as one JSON-able dict.

        Counters and gauges render as scalars, histograms as their
        count/sum/min/max/last summary.  This is the payload the journal's
        round records and the nightly bench JSON embed.
        """
        with self._lock:
            instruments = list(self._instruments.values())
        return {inst.name: inst.render() for inst in sorted(
            instruments, key=lambda i: i.name)}
