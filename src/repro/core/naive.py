"""The 'old Python controller' baseline MetisFL was re-engineered against.

The paper (§3) describes the original Python controller: per-tensor handling,
GIL-serialized aggregation, blocking dispatch.  To reproduce the paper's 10×
claim we need that comparison point, so this module implements controller
operations the slow way — deliberately:

* :func:`naive_aggregate` — iterate tensors in Python, and within each tensor
  iterate learners in Python, accumulating on host numpy one learner at a
  time (no packing, no fusion, no vectorized (N,P) reduce).
* :func:`naive_serialize` / :func:`naive_deserialize` — per-tensor pickling
  (framework-native object transport instead of flat bytes).
* :class:`NaiveDispatcher` — strictly sequential, blocking task dispatch.

Everything here is used only by benchmarks/tests as the baseline arm.
"""

from __future__ import annotations

import pickle
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np

__all__ = ["naive_aggregate", "naive_serialize", "naive_deserialize", "NaiveDispatcher"]


def naive_aggregate(models: Sequence[Any], weights: Sequence[float]) -> Any:
    """Per-tensor, per-learner Python-loop FedAvg (the GIL-era controller).

    models: list of parameter pytrees (one per learner).
    """
    wsum = float(sum(weights))
    norm = [float(w) / wsum for w in weights]
    flat_models = [jax.tree_util.tree_leaves(m) for m in models]
    treedef = jax.tree_util.tree_structure(models[0])
    n_tensors = len(flat_models[0])
    out_leaves = []
    for t in range(n_tensors):  # one "thread" per tensor... except sequential
        acc = None
        for i, fm in enumerate(flat_models):  # learner loop, host-side
            contrib = np.asarray(fm[t], dtype=np.float64) * norm[i]
            acc = contrib if acc is None else acc + contrib
        out_leaves.append(np.asarray(acc, dtype=np.asarray(flat_models[0][t]).dtype))
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


def naive_serialize(params: Any) -> list[bytes]:
    """Per-tensor pickle — the framework-native-object wire format."""
    return [
        pickle.dumps(np.asarray(leaf))
        for leaf in jax.tree_util.tree_leaves(params)
    ]


def naive_deserialize(blobs: list[bytes], treedef) -> Any:
    """Inverse of :func:`naive_serialize`: per-tensor unpickle + unflatten."""
    leaves = [pickle.loads(b) for b in blobs]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class NaiveDispatcher:
    """Blocking, sequential task dispatch: serialize + run + wait per learner."""

    def __init__(self):
        self.dispatch_s = 0.0

    def dispatch(self, params: Any, learners: Sequence[Callable[[Any], Any]]) -> list[Any]:
        """Serialize, send, and block on each learner strictly in turn."""
        results = []
        treedef = jax.tree_util.tree_structure(params)
        for learner_fn in learners:
            t0 = time.perf_counter()
            blobs = naive_serialize(params)
            received = naive_deserialize(blobs, treedef)
            self.dispatch_s += time.perf_counter() - t0
            results.append(learner_fn(received))  # blocks until done
        return results
