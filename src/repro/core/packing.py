"""Flat tensor transport: the MetisFL wire format, JAX-native.

MetisFL ships a model over the network as a sequence of *flattened byte
tensors* plus a small structural proto (shape, dtype, byte order) that lets the
receiver reconstruct the original tensors.  This module is the JAX analogue:

* :func:`pack_bytes` / :func:`unpack_bytes` — the wire format.  A pytree of
  arrays becomes one contiguous ``uint8`` buffer plus a :class:`Manifest`.
  This is what the (simulated) transport layer moves and measures.

* :func:`pack_numeric` / :func:`unpack_numeric` — the aggregation format.  All
  leaves are flattened, cast to a common accumulation dtype and concatenated
  into a single 1-D buffer.  The federation controller aggregates *these*
  buffers: a weighted reduction over ``(n_learners, n_params)`` that is
  embarrassingly parallel across params — the TPU-native statement of the
  paper's one-OpenMP-thread-per-tensor design (Fig. 4).

The manifest is a plain, picklable Python object (no closures), so it can be
generated once by the driver and shipped to every participant, exactly like
MetisFL's proto descriptors.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TensorSpec",
    "Manifest",
    "build_manifest",
    "pack_numeric",
    "unpack_numeric",
    "pack_bytes",
    "pack_bytes_from_numeric",
    "unpack_bytes",
    "pack_row_bytes",
    "unpack_row_bytes",
    "num_params",
    "round_up",
]


def round_up(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``n``."""
    if multiple <= 0:
        raise ValueError("multiple must be positive")
    return ((n + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Structural descriptor of one tensor on the wire (a proto-tensor)."""

    name: str
    shape: tuple[int, ...]
    dtype: str  # numpy dtype string, e.g. "float32", "bfloat16"
    offset: int  # element offset into the numeric buffer
    size: int  # number of elements

    @property
    def nbytes(self) -> int:
        """Wire size of this tensor in bytes (original dtype)."""
        return self.size * np.dtype(jnp.dtype(self.dtype)).itemsize


@dataclasses.dataclass(frozen=True)
class Manifest:
    """Full structural description of a packed model.

    ``specs`` are ordered by traversal order of the original pytree;
    ``treedef`` reconstructs the container structure.  ``byteorder`` is
    recorded the way MetisFL's proto does, so a receiver on different
    endianness could byteswap (JAX is little-endian everywhere; we record it
    for wire fidelity).
    """

    specs: tuple[TensorSpec, ...]
    treedef: Any
    byteorder: str = "little"

    @property
    def total_elements(self) -> int:
        """Total scalar element count across every packed tensor."""
        return sum(s.size for s in self.specs)

    @property
    def total_bytes(self) -> int:
        """Total wire bytes across every packed tensor."""
        return sum(s.nbytes for s in self.specs)

    def spec_by_name(self, name: str) -> TensorSpec:
        """Look up one tensor's spec by its pytree key-path name."""
        for s in self.specs:
            if s.name == name:
                return s
        raise KeyError(name)


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path)


def build_manifest(params: Any) -> Manifest:
    """Build the structural manifest for a parameter pytree.

    The numeric offsets index into the *accumulation-dtype* buffer produced by
    :func:`pack_numeric` (one element per original element, regardless of the
    original dtype).
    """
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    offset = 0
    for path, leaf in leaves_with_path:
        leaf = jnp.asarray(leaf)
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        specs.append(
            TensorSpec(
                name=_leaf_name(path),
                shape=tuple(int(d) for d in leaf.shape),
                dtype=str(leaf.dtype),
                offset=offset,
                size=size,
            )
        )
        offset += size
    return Manifest(specs=tuple(specs), treedef=treedef)


def num_params(params: Any) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(jnp.shape(l)) or 1) for l in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Numeric packing (aggregation format)
# ---------------------------------------------------------------------------


def pack_numeric(
    params: Any, dtype: jnp.dtype = jnp.float32, pad_to: int | None = None
) -> jax.Array:
    """Flatten a pytree into one 1-D buffer in the accumulation dtype.

    jit-compatible; under ``pjit`` the output buffer inherits a sharding over
    the flattened dimension, so the downstream aggregation reduce is local to
    every device (no collectives) — see ``core/aggregation.py``.

    ``pad_to`` zero-pads the buffer length up to the next multiple — the
    VPU-lane alignment the arena store (``core/store.ArenaStore``) and the
    Pallas kernels tile on, so an aligned upload is one full-row write with no
    per-call padding downstream.  ``unpack_numeric`` is oblivious: the
    manifest records the logical offsets and the zero tail never escapes.
    """
    leaves = jax.tree_util.tree_leaves(params)
    if not leaves:
        buf = jnp.zeros((0,), dtype=dtype)
    else:
        flat = [jnp.ravel(jnp.asarray(l)).astype(dtype) for l in leaves]
        buf = jnp.concatenate(flat, axis=0)
    if pad_to is not None and buf.shape[0] % pad_to:
        buf = jnp.pad(buf, (0, round_up(buf.shape[0], pad_to) - buf.shape[0]))
    return buf


def unpack_numeric(buffer: jax.Array, manifest: Manifest) -> Any:
    """Inverse of :func:`pack_numeric`: restore shapes, dtypes and structure."""
    leaves = []
    for spec in manifest.specs:
        seg = jax.lax.slice(buffer, (spec.offset,), (spec.offset + spec.size,))
        leaves.append(seg.reshape(spec.shape).astype(jnp.dtype(spec.dtype)))
    return jax.tree_util.tree_unflatten(manifest.treedef, leaves)


# ---------------------------------------------------------------------------
# Byte packing (wire format)
# ---------------------------------------------------------------------------


def pack_bytes(params: Any) -> tuple[np.ndarray, Manifest]:
    """Serialize a pytree to one contiguous byte buffer (host-side).

    This is the transport representation: it preserves the original dtypes
    bit-exactly (bf16 stays 2 bytes on the wire).  Single-copy: each tensor's
    bytes are written directly into a preallocated wire buffer — the fast
    (de)serialization MetisFL attributes its dispatch-time win to.  Not
    jit-compatible by design; serialization is a controller-edge operation.
    """
    manifest = build_manifest(params)
    out = np.empty((manifest.total_bytes,), np.uint8)
    cursor = 0
    for leaf in jax.tree_util.tree_leaves(params):
        arr = np.ascontiguousarray(np.asarray(leaf))
        n = arr.nbytes
        out[cursor : cursor + n] = arr.reshape(-1).view(np.uint8)
        cursor += n
    return out, manifest


def pack_bytes_from_numeric(buffer: Any, manifest: Manifest) -> np.ndarray:
    """Wire bytes straight off a flat numeric buffer — no pytree walk.

    The serialize-once broadcast path (``core/transport.Channel.broadcast``)
    feeds the controller's already-maintained ``global_buffer`` here instead
    of re-flattening ``global_params`` leaf by leaf: one device→host transfer
    of the whole buffer, then a single ``astype``/byte view when the model is
    dtype-homogeneous (the common case), or one cast per spec otherwise.  A
    zero-padded tail (``pack_numeric(pad_to=...)``) is sliced off.

    Bit-identical to ``pack_bytes(unpack_numeric(buffer, manifest))[0]`` —
    i.e. to serializing exactly the pytree the controller's numeric state
    decodes to.  The wire bytes are always *materialized* (one O(P) copy,
    like ``pack_bytes``), never a zero-copy alias of ``buffer``: the channel
    contract is to perform the real serialization work it accounts for, and
    on accelerator backends the host transfer is unavoidable anyway.
    """
    if not manifest.specs:
        return np.empty((0,), np.uint8)
    host = np.asarray(buffer)[: manifest.total_elements]
    dtypes = {s.dtype for s in manifest.specs}
    if len(dtypes) == 1:
        dt = jnp.dtype(next(iter(dtypes)))
        wire = host.astype(dt, copy=True)
        return wire.reshape(-1).view(np.uint8)
    out = np.empty((manifest.total_bytes,), np.uint8)
    cursor = 0
    for spec in manifest.specs:
        seg = host[spec.offset : spec.offset + spec.size]
        raw = np.ascontiguousarray(seg.astype(jnp.dtype(spec.dtype)))
        out[cursor : cursor + spec.nbytes] = raw.reshape(-1).view(np.uint8)
        cursor += spec.nbytes
    return out


def pack_row_bytes(buffer: Any, dtype: Any = jnp.float32) -> np.ndarray:
    """Wire bytes of one flat ``(P,)`` numeric buffer (the upload row format).

    The uplink mirror of :func:`pack_bytes_from_numeric` for a *single* flat
    buffer with no manifest: one device→host transfer plus one cast/copy,
    then a zero-copy byte view.  Like the downlink path, the wire bytes are
    always *materialized* (one O(P) copy, never an alias of the caller's
    buffer): the channel's contract is to perform the serialization work it
    accounts for, and an aliased envelope would mutate if the caller's buffer
    did.  This is what the transport's ``raw`` upload codec puts on the wire
    — ``P * itemsize`` bytes, bit-identical to the numeric buffer.
    """
    dt = np.dtype(jnp.dtype(dtype))
    host = np.asarray(buffer)
    return host.reshape(-1).astype(dt, copy=True).view(np.uint8)


@functools.partial(jax.jit, static_argnames=("num_elements", "dtype"))
def _bitcast_row_device(wire: jax.Array, num_elements: int, dtype: str) -> jax.Array:
    """Device-side inverse of :func:`pack_row_bytes` (compiled per layout)."""
    dt = jnp.dtype(dtype)
    if dt.itemsize == 1:
        row = jax.lax.bitcast_convert_type(wire, dt)
    else:
        row = jax.lax.bitcast_convert_type(wire.reshape(num_elements, dt.itemsize), dt)
    return row.reshape(num_elements)


def unpack_row_bytes(wire: np.ndarray, num_elements: int, dtype: Any = "float32") -> jax.Array:
    """Inverse of :func:`pack_row_bytes`: **one** ``device_put`` of the wire
    bytes, then a jitted device-side bitcast back to the ``(P,)`` row.

    Mirrors :func:`unpack_bytes`' one-transfer design on the upload direction:
    a controller ingesting N uploads per round pays N single O(P) transfers
    and zero host-side numeric work, regardless of model depth.
    """
    dt = jnp.dtype(dtype)
    if int(np.size(wire)) != int(num_elements) * dt.itemsize:
        raise ValueError(
            f"row payload holds {int(np.size(wire))} bytes, expected "
            f"{int(num_elements) * dt.itemsize} for {num_elements} "
            f"{dt.name} elements"
        )
    dev = jnp.asarray(np.ascontiguousarray(wire))
    return _bitcast_row_device(dev, int(num_elements), str(dt))


@functools.partial(jax.jit, static_argnames="manifest")
def _unpack_bytes_device(buffer: jax.Array, manifest: Manifest) -> Any:
    """Device-side wire decode: slice + bitcast every tensor out of one
    resident ``uint8`` buffer (compiled once per manifest, cached)."""
    leaves = []
    cursor = 0
    for spec in manifest.specs:
        dt = jnp.dtype(spec.dtype)
        seg = jax.lax.slice(buffer, (cursor,), (cursor + spec.nbytes,))
        if dt == jnp.dtype(bool):
            leaf = seg.astype(bool)  # XLA cannot bitcast to pred
        elif dt.itemsize == 1:
            leaf = jax.lax.bitcast_convert_type(seg, dt)
        else:
            leaf = jax.lax.bitcast_convert_type(seg.reshape(spec.size, dt.itemsize), dt)
        leaves.append(leaf.reshape(spec.shape))
        cursor += spec.nbytes
    return jax.tree_util.tree_unflatten(manifest.treedef, leaves)


def unpack_bytes(buffer: np.ndarray, manifest: Manifest) -> Any:
    """Inverse of :func:`pack_bytes`: **one** ``device_put`` of the whole wire
    buffer, then device-side slices + bitcasts per tensor.

    The legacy implementation transferred one tensor at a time (one host→
    device copy per leaf — hundreds for a deep model); this path moves the
    buffer once and reconstructs every tensor on device through a jitted
    program cached per manifest, so a receiver's deserialization cost is a
    single O(P) transfer regardless of how many tensors the model has.
    """
    if not manifest.specs:
        return jax.tree_util.tree_unflatten(manifest.treedef, [])
    dev = jnp.asarray(np.ascontiguousarray(buffer))
    return _unpack_bytes_device(dev, manifest)
