"""The controller flight recorder: an append-only, replayable event journal.

Every typed event the round engine processes (``Dispatched`` /
``UploadArrived`` / ``AggregateFired`` / ``Evaluated`` / ``EngineStopped``)
is serialized into one compact JSON-able record and appended here, in
processing order.  The journal is the engine's durable observability
surface: the in-memory ``event_log`` deque holds the typed objects for
tests; the journal holds their wire form — taggable, greppable, tailable.

Design constraints (the engine loop is latency-critical):

* **No arrays, no pytrees** — records carry ids, counts and byte sizes, not
  model state.  Serializing a record is dict construction only; JSON
  encoding happens at flush time.
* **No sink I/O on the loop thread** — with a file sink attached, records
  are buffered and drained by a background flush thread; ``record()`` never
  blocks on the filesystem.  The ``EngineStopped`` record triggers a
  synchronous :meth:`flush`, so when ``engine.run()`` returns the sink holds
  every record (the flush-on-stop guarantee).
* **Deterministic under test** — timestamps come from an injectable
  ``clock`` hook; with a fixed clock, two identical runs produce identical
  JSONL byte-for-byte (``tests/test_journal.py``).

:meth:`replay` folds a record stream back into per-round
:class:`RoundSummary` objects — cohort membership, arrival order, staleness
histogram, policy decisions, wire bytes up/down — the per-round provenance
view that tests assert on and ``launch/serve.py``-style tooling can tail.
Schema reference: ``docs/OBSERVABILITY.md``.
"""

from __future__ import annotations

import collections
import dataclasses
import io
import json
import math
import threading
import time
from typing import Any, Callable, Iterable

__all__ = ["EventJournal", "RoundSummary", "jsonable"]


def jsonable(obj: Any) -> Any:
    """Coerce a value into plain JSON types (dicts/lists/str/int/float/bool).

    Numpy and JAX zero-dim scalars become Python numbers; unknown objects
    fall back to ``repr`` — a journal record must always serialize, whatever
    a learner put in its metrics dict.
    """
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    item = getattr(obj, "item", None)
    if callable(item) and getattr(obj, "ndim", None) in (0, None):
        try:
            return jsonable(item())
        except (TypeError, ValueError):
            pass
    return repr(obj)


@dataclasses.dataclass
class RoundSummary:
    """Per-round provenance reconstructed from the journal by :meth:`replay`.

    ``cohort`` lists dispatched learners in dispatch order; ``arrivals``
    lists uploads in processing order; ``staleness`` histograms the model-
    version lag of each arrival (``{lag: count}``).  ``down_bytes`` /
    ``up_bytes`` are this round's wire deltas (cumulative channel totals at
    the aggregate, minus the previous round's).  ``weighting`` / ``trigger``
    record the policy decision that fired the aggregate; ``metrics`` is the
    reduced eval report (round-based policies only).

    The admission-control fields answer "why is this learner's row not in
    the aggregate": ``rejected`` lists ``{"learner", "reason", "norm"}``
    dicts for uploads the screen refused (the row never touched the store),
    ``clipped`` lists learners whose upload was norm-clipped before the row
    write (still aggregated, at reduced magnitude), and ``quarantined``
    lists learners that crossed the quarantine threshold during the round
    (excluded from *subsequent* cohort selection until decay releases them).
    """

    round_id: int
    cohort: list = dataclasses.field(default_factory=list)
    arrivals: list = dataclasses.field(default_factory=list)
    staleness: dict = dataclasses.field(default_factory=dict)
    rejected: list = dataclasses.field(default_factory=list)
    clipped: list = dataclasses.field(default_factory=list)
    quarantined: list = dataclasses.field(default_factory=list)
    aggregated: bool = False
    n_arrived: int = 0
    weighting: str | None = None
    trigger: str | None = None
    model_version: int | None = None
    down_bytes: int | None = None
    up_bytes: int | None = None
    metrics: dict = dataclasses.field(default_factory=dict)


class EventJournal:
    """Thread-safe append-only journal of the engine's typed events.

    ``capacity`` bounds the in-memory ring (0 disables recording entirely —
    the bench baseline); ``sink`` optionally persists records as JSONL (a
    path string or a writable text-file object); ``clock`` injects
    timestamps (``time.time`` by default; tests pass a counter for
    deterministic output).  ``cursor`` is the total number of records ever
    recorded — it rides along in federation checkpoints so a resumed
    engine's records continue the sequence numbering.
    """

    def __init__(
        self,
        capacity: int = 4096,
        sink: Any = None,
        clock: Callable[[], float] = time.time,
        flush_interval_s: float = 0.05,
    ):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = int(capacity)
        self.clock = clock
        self.flush_interval_s = float(flush_interval_s)
        self._sink_spec = sink
        self._sink_file: Any = None
        self._owns_sink = isinstance(sink, str)
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=self.capacity)
        self._pending: list[dict] = []
        self._seq = 0
        self._sink_lock = threading.Lock()
        self._flusher: threading.Thread | None = None
        self._wake = threading.Event()
        self._stop = False

    # -- recording ----------------------------------------------------------
    @property
    def enabled(self) -> bool:
        """False when nothing is retained (capacity 0 and no sink)."""
        return self.capacity > 0 or self._sink_spec is not None

    @property
    def cursor(self) -> int:
        """Total records ever recorded (== the next record's ``seq``)."""
        with self._lock:
            return self._seq

    def seek(self, cursor: int) -> None:
        """Reset the sequence counter (checkpoint restore: records resume
        numbering where the interrupted run's journal left off)."""
        with self._lock:
            self._seq = int(cursor)

    def record(self, event: Any, **context: Any) -> dict | None:
        """Serialize one typed event (plus caller context) and append it.

        Called by the engine loop for every event it processes.  The record
        is a flat dict — ``seq`` (processing order), ``t`` (clock hook),
        ``kind`` plus the event's scalar fields and any ``context`` the
        engine attached (byte sizes, staleness, model version).  With a file
        sink the record is buffered for the background flusher; an
        ``engine_stopped`` record flushes synchronously (the flush-on-stop
        guarantee).  Returns the record (None when recording is disabled).
        """
        if not self.enabled:
            return None
        payload = _serialize_event(event)
        if context:
            payload.update({k: jsonable(v) for k, v in context.items()})
        with self._lock:
            rec = {"seq": self._seq, "t": float(self.clock()), **payload}
            self._seq += 1
            if self.capacity:
                self._ring.append(rec)
            if self._sink_spec is not None:
                self._pending.append(rec)
        if self._sink_spec is not None:
            if payload.get("kind") == "engine_stopped":
                self.flush()
            else:
                self._ensure_flusher()
                self._wake.set()
        return rec

    def records(self) -> list[dict]:
        """A copy of the in-memory ring, in processing order."""
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    # -- sink / flushing ----------------------------------------------------
    def _ensure_flusher(self) -> None:
        if self._flusher is not None or self._stop:
            return
        with self._sink_lock:
            if self._flusher is None and not self._stop:
                t = threading.Thread(
                    target=self._flush_loop, name="journal-flush", daemon=True
                )
                self._flusher = t
                t.start()

    def _flush_loop(self) -> None:
        while not self._stop:
            self._wake.wait(timeout=self.flush_interval_s)
            self._wake.clear()
            self._drain()

    def _open_sink(self):
        if self._sink_file is None:
            if self._owns_sink:
                self._sink_file = open(self._sink_spec, "a", encoding="utf-8")
            else:
                self._sink_file = self._sink_spec
        return self._sink_file

    def _drain(self) -> None:
        with self._lock:
            batch, self._pending = self._pending, []
        if not batch:
            return
        with self._sink_lock:
            f = self._open_sink()
            for rec in batch:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
            f.flush()

    def flush(self) -> None:
        """Synchronously drain buffered records to the sink (no-op without one)."""
        if self._sink_spec is None:
            return
        self._drain()

    def close(self) -> None:
        """Stop the background flusher, flush, and close an owned sink file."""
        self._stop = True
        self._wake.set()
        if self._flusher is not None:
            self._flusher.join(timeout=5.0)
            self._flusher = None
        self._drain()
        if self._owns_sink and self._sink_file is not None:
            self._sink_file.close()
            self._sink_file = None

    # -- serialization ------------------------------------------------------
    def to_jsonl(self, records: Iterable[dict] | None = None) -> str:
        """Render records (default: the ring) as one JSONL string."""
        out = io.StringIO()
        for rec in self.records() if records is None else records:
            out.write(json.dumps(rec, sort_keys=True) + "\n")
        return out.getvalue()

    @staticmethod
    def read_jsonl(path: str) -> list[dict]:
        """Load a journal sink file back into a list of records."""
        with open(path, encoding="utf-8") as f:
            return [json.loads(line) for line in f if line.strip()]

    # -- replay -------------------------------------------------------------
    def replay(self, records: Iterable[dict] | None = None) -> list[RoundSummary]:
        """Fold a record stream into per-round :class:`RoundSummary` objects.

        Defaults to the in-memory ring; pass ``read_jsonl(path)`` records to
        replay a sink file (e.g. after a crash).  Summaries come back sorted
        by round id; rounds that never aggregated (in-flight at shutdown)
        appear with ``aggregated=False``.
        """
        recs = self.records() if records is None else list(records)
        rounds: dict[int, RoundSummary] = {}

        def summary(rid: int) -> RoundSummary:
            return rounds.setdefault(int(rid), RoundSummary(round_id=int(rid)))

        prev_down = prev_up = 0
        for rec in recs:
            kind = rec.get("kind")
            rid = rec.get("round")
            if kind == "dispatch" and rid is not None:
                summary(rid).cohort.append(rec.get("learner"))
            elif kind == "upload" and rid is not None:
                s = summary(rid)
                s.arrivals.append(rec.get("learner"))
                lag = rec.get("staleness")
                if lag is not None:
                    lag = int(lag)
                    s.staleness[lag] = s.staleness.get(lag, 0) + 1
            elif kind == "aggregate" and rid is not None:
                s = summary(rid)
                s.aggregated = True
                s.n_arrived = int(rec.get("n_arrived", 0))
                s.weighting = rec.get("weighting")
                s.trigger = rec.get("trigger")
                if rec.get("model_version") is not None:
                    s.model_version = int(rec["model_version"])
                down, up = rec.get("bytes_down"), rec.get("bytes_up")
                if down is not None:
                    s.down_bytes = int(down) - prev_down
                    prev_down = int(down)
                if up is not None:
                    s.up_bytes = int(up) - prev_up
                    prev_up = int(up)
            elif kind == "upload_rejected" and rid is not None:
                summary(rid).rejected.append({
                    "learner": rec.get("learner"),
                    "reason": rec.get("reason"),
                    "norm": rec.get("norm"),
                })
            elif kind == "upload_clipped" and rid is not None:
                summary(rid).clipped.append(rec.get("learner"))
            elif kind == "quarantine" and rid is not None:
                summary(rid).quarantined.append(rec.get("learner"))
            elif kind == "evaluate" and rid is not None:
                summary(rid).metrics = rec.get("metrics", {})
        return [rounds[k] for k in sorted(rounds)]


def _serialize_event(event: Any) -> dict:
    """One typed engine event → its flat JSON-able payload.

    Matched by class name (the engine imports the journal, not vice versa).
    Unknown event types — anything tests or tooling post through
    ``engine.post`` — serialize as ``kind="external"`` with their type name;
    a journal record must never fail to serialize.
    """
    name = type(event).__name__
    if name == "Dispatched":
        task = event.task
        return {
            "kind": "dispatch",
            "round": int(event.round_id),
            "learner": event.learner_id,
            "local_steps": int(task.local_steps),
            "batch_size": int(task.batch_size),
        }
    if name == "UploadArrived":
        if event.update is None:
            return {"kind": "upload", "round": None, "learner": None,
                    "error": repr(event.error)}
        u = event.update
        return {
            "kind": "upload",
            "round": int(u.round_id),
            "learner": u.learner_id,
            "num_examples": int(u.num_examples),
        }
    if name == "AggregateFired":
        out = {
            "kind": "aggregate",
            "round": int(event.round_id),
            "n_arrived": int(event.n_arrived),
            "trigger": event.trigger,
        }
        if getattr(event, "members", None):
            out["members"] = list(event.members)
        return out
    if name == "UploadRejected":
        norm = float(event.norm)
        return {
            "kind": "upload_rejected",
            "round": int(event.round_id),
            "learner": event.learner_id,
            "reason": event.reason,
            # NaN/inf norms (the usual rejection cause) are not JSON —
            # stringify so sink files stay loadable by strict parsers.
            "norm": norm if math.isfinite(norm) else repr(norm),
        }
    if name == "UploadClipped":
        return {
            "kind": "upload_clipped",
            "round": int(event.round_id),
            "learner": event.learner_id,
            "norm": float(event.norm),
            "limit": float(event.limit),
        }
    if name == "LearnerQuarantined":
        return {
            "kind": "quarantine",
            "round": int(event.round_id),
            "learner": event.learner_id,
            "score": float(event.score),
        }
    if name == "DeadlineExpired":
        return {"kind": "deadline", "round": int(event.round_id)}
    if name == "Evaluated":
        return {
            "kind": "evaluate",
            "round": int(event.round_id),
            "metrics": jsonable(event.metrics),
        }
    if name == "EngineStopped":
        return {
            "kind": "engine_stopped",
            "completed": int(event.completed),
            "error": event.error,
        }
    return {"kind": "external", "type": name}
