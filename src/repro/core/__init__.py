"""Federation controller substrate — the paper's contribution.

Public API re-exports for the common path:

    from repro.core import (
        pack_numeric, unpack_numeric, build_manifest,
        fedavg, Controller, Learner, Driver, FederationEnv,
    )
"""

from repro.core.packing import (
    Manifest,
    TensorSpec,
    build_manifest,
    num_params,
    pack_bytes,
    pack_bytes_from_numeric,
    pack_numeric,
    round_up,
    unpack_bytes,
    unpack_numeric,
)
from repro.core.aggregation import (
    coordinate_median,
    fedavg,
    fedavg_sharded,
    hierarchical_fedavg,
    masked_coordinate_median,
    masked_fedavg,
    masked_fedavg_sharded,
    masked_median_sharded,
    masked_staleness_average,
    masked_staleness_sharded,
    masked_trimmed_mean,
    masked_trimmed_mean_sharded,
    masked_weighted_average,
    staleness_weights,
    trimmed_mean,
    weighted_average,
)
from repro.core.config import FederationConfig
from repro.core.journal import EventJournal, RoundSummary
from repro.core.metrics import Counter, Gauge, Histogram, Telemetry
from repro.core.store import ArenaStore, ModelRecord, ModelStore
from repro.core.scheduler import (
    AsyncProtocol,
    BufferedAsyncProtocol,
    DeadlineCohortProtocol,
    LearnerProfile,
    ProtocolPolicy,
    ReputationProtocol,
    SemiSyncProtocol,
    SyncProtocol,
    TrainTask,
)
from repro.core.selection import SelectionPolicy, select_learners
from repro.core.server_opt import ServerOptimizer, make_server_optimizer
from repro.core.learner import EvalReport, Learner, LocalUpdate
from repro.core.engine import (
    AggregateFired,
    DeadlineExpired,
    Dispatched,
    EngineStopped,
    Evaluated,
    LearnerQuarantined,
    RoundEngine,
    RoundTimings,
    UploadArrived,
    UploadClipped,
    UploadRejected,
    UploadRejectedError,
)
from repro.core.faults import (
    ADVERSARIAL_FATES,
    FaultInjector,
    FaultSpec,
    FaultyChannel,
)
from repro.core.controller import Controller
from repro.core.driver import Driver, FederationEnv, TerminationCriteria
from repro.core.transport import (
    Broadcast,
    Channel,
    ChannelStats,
    Envelope,
    Int8UploadCodec,
    RawUploadCodec,
    UploadEnvelope,
    get_upload_codec,
)

__all__ = [
    "Manifest", "TensorSpec", "build_manifest", "num_params",
    "pack_bytes", "pack_bytes_from_numeric", "pack_numeric", "round_up",
    "unpack_bytes", "unpack_numeric",
    "fedavg", "weighted_average", "coordinate_median", "trimmed_mean",
    "masked_fedavg", "masked_staleness_average", "masked_weighted_average",
    "masked_fedavg_sharded", "masked_staleness_sharded",
    "masked_coordinate_median", "masked_trimmed_mean",
    "masked_median_sharded", "masked_trimmed_mean_sharded",
    "staleness_weights", "fedavg_sharded", "hierarchical_fedavg",
    "ModelRecord", "ModelStore", "ArenaStore",
    "SyncProtocol", "SemiSyncProtocol", "AsyncProtocol", "TrainTask",
    "BufferedAsyncProtocol", "DeadlineCohortProtocol", "ReputationProtocol",
    "ProtocolPolicy", "LearnerProfile",
    "SelectionPolicy", "select_learners",
    "ServerOptimizer", "make_server_optimizer",
    "Learner", "LocalUpdate", "EvalReport",
    "Controller", "RoundTimings", "RoundEngine",
    "Dispatched", "UploadArrived", "AggregateFired", "Evaluated",
    "EngineStopped", "DeadlineExpired",
    "UploadRejected", "UploadClipped", "LearnerQuarantined",
    "UploadRejectedError",
    "FaultSpec", "FaultInjector", "FaultyChannel", "ADVERSARIAL_FATES",
    "Telemetry", "Counter", "Gauge", "Histogram",
    "EventJournal", "RoundSummary",
    "Driver", "FederationEnv", "TerminationCriteria", "FederationConfig",
    "Broadcast", "Channel", "ChannelStats", "Envelope",
    "UploadEnvelope", "RawUploadCodec", "Int8UploadCodec", "get_upload_codec",
]
