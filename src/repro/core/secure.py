"""Masked secure aggregation with exact cancellation.

MetisFL performs secure aggregation with CKKS homomorphic encryption
(PALISADE).  FHE has no JAX analogue, so — per DESIGN.md §2 — we implement the
*masking* family the paper's Table 1 attributes to Flower/FedML
(LightSecAgg-style pairwise masking):

Every ordered pair of learners ``(i, j)`` derives a shared one-time pad from a
pairwise seed; learner ``i`` adds ``+m_ij`` and learner ``j`` adds ``-m_ij``
to its upload.  The controller's sum of all masked uploads equals the sum of
the true uploads **exactly**, while any individual upload is masked by a
uniform pad over ``Z_2^32``.

Exactness requires working over the integers: learners encode their (already
FedAvg-weighted) buffers in int32 **fixed point** (the plaintext analogue of
the CKKS encode step), mask with wrapping int32 addition, and the controller
sums and decodes.  Cancellation is bit-exact; the only error is the fixed-
point quantization, bounded by ``N / (2 * scale)`` per coordinate.  Both
properties are verified by hypothesis tests.

Dropout recovery (SecAgg+ secret-sharing of seeds) is out of scope: all
selected participants must survive to unmasking, as in the paper's synchronous
stress tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PairwiseMasker",
    "MaskSession",
    "encode_fixed",
    "decode_fixed",
    "secure_fedavg",
    "secure_fedavg_arena",
    "FIXED_SCALE",
]

FIXED_SCALE = float(1 << 16)


@dataclasses.dataclass(frozen=True)
class MaskSession:
    """One secure-aggregation epoch: the session every mask seed derives from.

    A session is keyed by ``(base_seed, epoch)`` where ``epoch`` is the
    synchronous round id or — on the continuous (async) path — the global
    **model version** the community update commits: every epoch gets fresh
    one-time pads, so an upload masked in one session can never be unmasked
    against pads from another (the plaintext analogue of rotating the CKKS
    session keys per round).  Both the controller and the replay references
    in ``tests/test_conformance.py`` derive seeds through this object, so
    the key schedule has a single source of truth.
    """

    base_seed: int
    epoch: int

    @property
    def seed(self) -> int:
        """The session's 31-bit mask seed (an integer hash of the key pair)."""
        mixed = (
            (self.base_seed * 2654435761)
            ^ (self.epoch * 2246822519)
            ^ 0x9E3779B9
        )
        return mixed % (1 << 31)

    def masker(self, n_participants: int) -> PairwiseMasker:
        """The session's pairwise mask generator over ``n_participants``."""
        return PairwiseMasker(
            base_seed=self.seed, participants=tuple(range(n_participants))
        )


def _pair_seed(base_seed: int, i: int, j: int) -> int:
    """Order-independent pairwise seed (canonicalized to i < j)."""
    a, b = (i, j) if i < j else (j, i)
    mod = 1 << 32
    return ((base_seed * 2654435761) % mod) ^ ((a * 40503) % mod) ^ ((b * 9973) % mod)


def _mask(seed: int, size: int) -> jax.Array:
    key = jax.random.key(seed)
    return jax.random.bits(key, (size,), dtype=jnp.uint32).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class PairwiseMasker:
    """Mask generator for one secure-aggregation session."""

    base_seed: int
    participants: tuple[int, ...]

    def net_mask(self, idx: int, size: int) -> jax.Array:
        """Sum of signed pairwise pads learner ``idx`` applies to its upload."""
        total = jnp.zeros((size,), jnp.int32)
        for other in self.participants:
            if other == idx:
                continue
            m = _mask(_pair_seed(self.base_seed, idx, other), size)
            sign = 1 if idx < other else -1
            total = total + jnp.int32(sign) * m  # wrapping adds on Z_2^32
        return total


def encode_fixed(buffer: jax.Array, scale: float = FIXED_SCALE) -> jax.Array:
    """float32 -> int32 fixed point (plaintext analogue of CKKS encode)."""
    return jnp.round(buffer.astype(jnp.float32) * scale).astype(jnp.int32)


def decode_fixed(ints: jax.Array, scale: float = FIXED_SCALE) -> jax.Array:
    """int32 fixed point -> float32 (plaintext analogue of CKKS decode)."""
    return ints.astype(jnp.float32) / scale


def mask_upload(
    masker: PairwiseMasker, idx: int, weighted_buffer: jax.Array,
    scale: float = FIXED_SCALE,
) -> jax.Array:
    """Learner-side: fixed-point encode + apply net pad.  Upload is uniform-
    masked int32; the controller learns nothing about an individual model."""
    enc = encode_fixed(weighted_buffer, scale)
    return enc + masker.net_mask(idx, weighted_buffer.shape[0])


def secure_fedavg(
    buffers: Sequence[jax.Array],
    weights: Sequence[float],
    base_seed: int = 0,
    scale: float = FIXED_SCALE,
) -> jax.Array:
    """End-to-end secure FedAvg: weight→encode→mask→sum→decode.

    FedAvg weights are folded in learner-side (each learner uploads
    ``(w_i / Σw) * x_i`` in fixed point), so the controller only ever sums
    masked integers.  Returns the weighted average as float32, exact up to
    fixed-point quantization.
    """
    n = len(buffers)
    masker = PairwiseMasker(base_seed=base_seed, participants=tuple(range(n)))
    wsum = float(sum(weights))
    if wsum <= 0:
        raise ValueError("weights must sum to a positive value")
    total = jnp.zeros((buffers[0].shape[0],), jnp.int32)
    for i, (buf, w) in enumerate(zip(buffers, weights)):
        total = total + mask_upload(masker, i, buf * jnp.float32(w / wsum), scale)
    return decode_fixed(total, scale)


def secure_fedavg_arena(
    arena: jax.Array,
    rows: Sequence[int],
    weights: Sequence[float],
    num_params: int | None = None,
    base_seed: int = 0,
    scale: float = FIXED_SCALE,
    out_sharding: Any = None,
) -> jax.Array:
    """Secure FedAvg over selected rows of a device-resident arena.

    The arena-store statement of :func:`secure_fedavg`: participants are the
    given ``rows`` of the persistent ``(n_max, P)`` buffer
    (``core/store.ArenaStore``), sliced on device — no stack rebuild, no host
    round-trip.  Mask seeds are derived from the *position* in ``rows`` (the
    session's participant index), so the result is bit-identical to
    ``secure_fedavg`` on the same buffers in the same order with the same
    ``base_seed`` — the property the arena/stack parity tests assert.

    ``out_sharding`` supports the **sharded** arena
    (``core/store.ArenaStore(mesh=...)``): pass the arena's row sharding
    (``P(axes)`` over the mesh) and the masked int32 accumulator is committed
    to it, keeping every wrap-add column-sharded alongside the buffer instead
    of congregating on one device (ignored when ``num_params`` does not
    divide the shard count — the layout hint simply no-ops).  Sharding never
    changes the result:
    the whole pipeline is exact int32 arithmetic, so the sharded sum stays
    **bit-identical** to the single-device arena path (asserted by
    ``tests/test_arena_sharded.py``).
    """
    n = len(rows)
    if n == 0:
        raise ValueError("secure aggregation needs at least one participant row")
    if n != len(weights):
        raise ValueError("rows and weights must have equal length")
    p = int(num_params) if num_params is not None else int(arena.shape[1])
    masker = PairwiseMasker(base_seed=base_seed, participants=tuple(range(n)))
    wsum = float(sum(weights))
    if wsum <= 0:
        raise ValueError("weights must sum to a positive value")
    if out_sharding is not None:
        try:
            out_sharding.shard_shape((p,))  # layout only applies when p divides
        except ValueError:
            out_sharding = None
    total = jnp.zeros((p,), jnp.int32)
    if out_sharding is not None:
        total = jax.device_put(total, out_sharding)
    for i, (row, w) in enumerate(zip(rows, weights)):
        buf = jax.lax.dynamic_slice(arena, (int(row), 0), (1, p))[0]
        total = total + mask_upload(masker, i, buf * jnp.float32(w / wsum), scale)
    return decode_fixed(total, scale)
