"""The Federation Controller — model state, transport and store plumbing.

Implements the controller lifecycle of paper Figs. 1/9/10 with the
re-engineered operations of §3, but — since the event-driven round engine
landed (``core/engine.py``) — it no longer *runs* protocols itself: the
engine's single arrival-driven loop consults the protocol policy
(``core/scheduler.ProtocolPolicy``) and calls back into the controller's
plumbing surface:

* **serialize-once broadcast dispatch** — the global model is serialized at
  most **once per model version** (:meth:`Controller._broadcast`,
  ``Channel.broadcast`` straight off the flat ``global_buffer``), so
  per-round dispatch cost is O(P + N), independent of federation size at
  fixed payload.
* **measured upload ingest** (:meth:`Controller.ingest`) — learners hold the
  manifest and the channel handle (shipped once at registration) and send
  packed ``(P,)`` buffers through the measured uplink; arrival is a codec
  decode plus a straight donated arena row write, and the EWMA learner
  profile (``core/scheduler.LearnerProfile``) absorbs the task's measured
  seconds-per-step and wire bytes.
* **aggregation plumbing** — :meth:`Controller.aggregate_round` (cohort
  FedAvg / secure sum) and :meth:`Controller.aggregate_community`
  (staleness-damped async update, in the clear or through a per-epoch
  :class:`~repro.core.secure.MaskSession`), both committing through the
  server optimizer and bumping the model version.
* **wire-cost model** (:meth:`Controller.wire_time_s`) — the per-learner
  round-trip virtual wire estimate (downlink broadcast + uplink payload)
  the semi-sync policy subtracts from its hyper-period budget.
* **device-resident arena** (``store_mode="arena"``, the default) — uploads
  are donated in-place row writes into a persistent ``(n_max, P)`` device
  buffer (``core/store.ArenaStore``), optionally column-sharded over a mesh
  (``arena_mesh=``); ``store_mode="stack"`` keeps the legacy hash-map +
  re-stack path for parity.

Workflow execution — cohort selection, dispatch, arrival handling,
aggregation timing, evaluation fan-out — lives in ``engine.run``; see
``docs/ENGINE.md``.
"""

from __future__ import annotations

import math
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import aggregation, packing, transport
from repro.core.engine import RoundEngine, RoundTimings, UploadRejectedError
from repro.core.journal import EventJournal, jsonable
from repro.core.learner import Learner, LocalUpdate
from repro.core.metrics import Telemetry
from repro.core.scheduler import LearnerProfile, ProtocolPolicy, SyncProtocol
from repro.core.selection import SelectionPolicy
from repro.core.server_opt import ServerOptimizer, make_server_optimizer
from repro.core.store import ArenaStore, ModelRecord, ModelStore
from repro.core.transport import Broadcast, Channel, get_upload_codec

__all__ = ["RoundTimings", "Controller"]


AggregateFn = Callable[[jax.Array, jax.Array], jax.Array]


class Controller:
    """The federation controller: model state + transport + store plumbing.

    Protocol execution is delegated to :attr:`engine`
    (``core/engine.RoundEngine``): ``controller.engine.run(rounds=N)`` for
    the round-based policies, ``engine.run(total_updates=N)`` for the
    continuous (async) one.

    Parameters
    ----------
    protocol:
        A :class:`~repro.core.scheduler.ProtocolPolicy`
        (Sync/SemiSync/Async protocol object).
    aggregate_fn:
        ``(stack (N,P), weights (N,)) -> (P,)``.  Defaults to the fused
        FedAvg; swap in the Pallas kernel op or a robust rule.
    store_mode:
        ``"arena"`` (default) aggregates straight off the device-resident
        :class:`ArenaStore`; ``"stack"`` is the legacy re-stack path.
    masked_aggregate_fn:
        ``(arena (N_max,P), weights (N_max,), mask (N_max,)) -> (P,)`` — the
        arena-path rule.  Defaults to the fused masked FedAvg (or, if a
        custom ``aggregate_fn`` was given, to ``aggregate_fn`` with the mask
        folded into the weights — correct for the weighted-average family,
        not for order statistics like the median; pass an explicit masked
        rule for those).
    secure:
        If True, uploads are mask-encoded and the controller only sums
        (``core/secure``) — it never sees an individual model.  Composes
        with every policy, including the continuous (async) one: each
        community update opens a fresh per-epoch mask session keyed by the
        global model version (``core/secure.MaskSession``).
    arena_mesh:
        Optional :class:`jax.sharding.Mesh`.  When given (arena mode only),
        the persistent ``(n_max, P)`` arena is **column-sharded** over the
        mesh's data axis (``launch/mesh.make_controller_mesh`` builds a 1-D
        one over all local devices): uploads scatter once and write
        shard-locally, and every aggregation policy — plain, staleness-
        weighted async, secure sum — reduces per shard with zero collectives.
        Numerics are identical to the single-device arena
        (``tests/test_arena_sharded.py``); see ``docs/ARENA.md``.
    arena_axes:
        Mesh axis name(s) to split ``P`` over (default: the ``"data"`` axis
        if the mesh has one, else every axis).
    flat_uploads:
        If True (default), every registered learner receives the model
        manifest (plus the arena row width and the channel handle) once at
        registration and sends its uploads through the measured uplink
        (``Channel.upload``) as codec-encoded wire envelopes, so
        :meth:`ingest` never flattens a pytree (``upload_fallback_packs``
        counts the times it had to).  False keeps the legacy
        pack-on-arrival path, for parity testing — those uploads still
        cross the measured uplink (the controller stands in for the
        learner's send half), so ``ChannelStats`` reconciles on every path.
    upload_codec:
        Uplink wire format: ``"raw"`` (default, bit-transparent f32 bytes)
        or ``"int8"`` (blockwise quantization, ~3.9x fewer uplink bytes), or
        a codec object (``core/transport.get_upload_codec``).  ``None``
        (default) keeps whatever the channel already uses; when set, it is
        installed on the controller's channel — including an explicitly
        passed ``channel=``, whose previous upload codec it replaces.
    profile_decay:
        EWMA decay for the per-learner seconds-per-step estimate
        (``core/scheduler.LearnerProfile``); 0 reproduces the legacy
        last-sample behaviour.
    aggregation_rule / trim_k:
        The community-model reduction: ``"fedavg"`` (default),
        ``"median"`` (coordinate-wise median) or ``"trimmed_mean"`` (drop
        the ``trim_k`` extremes per coordinate per side).  The robust
        rules run as masked reductions straight off the arena (sharded
        variants when ``arena_mesh`` is set), are weight-blind order
        statistics, exclude custom aggregate functions and ``secure``,
        and are rejected by the staleness-weighted protocols — see the
        support matrix in ``docs/PROTOCOLS.md``.
    admission_control / admission_clip_factor / admission_ewma_decay /
    admission_warmup:
        The upload admission screen (:meth:`_screen_upload`): non-finite
        buffers are rejected before they can touch the store, and — once
        ``admission_warmup`` accepted uploads have seeded an EWMA of
        update norms — outlier norms beyond ``admission_clip_factor``
        times the EWMA are clipped down to the limit.  On by default;
        forced off under ``secure`` (mask-encoded rows have meaningless
        norms).  Counters: ``engine.uploads.rejected.nonfinite``,
        ``engine.uploads.clipped``.
    quarantine_threshold / quarantine_decay:
        Repeat admission offenders are quarantined: each rejected or
        clipped upload adds 1 to a per-learner score that decays by
        ``quarantine_decay`` per round, and learners at or over
        ``quarantine_threshold`` are skipped by cohort selection until
        decay releases them (fail-open when everyone is quarantined).
        The defaults (threshold 2.0, decay 0.75) quarantine on the third
        consecutive offending round (scores 1.0, 1.75, 2.31...) and never
        on a single glitch.  Composes with ``ReputationProtocol`` — offenses
        also feed the reputation EWMA through
        ``LearnerProfile.observe_contribution``.
    journal / journal_sink / journal_capacity:
        The engine's flight recorder (``core/journal.EventJournal``).  Pass
        a pre-built journal (tests inject a deterministic clock) or let the
        controller build one: ``journal_sink`` optionally persists records
        as JSONL (path or file object; written off the engine loop thread by
        a background flusher) and ``journal_capacity`` bounds the in-memory
        ring (0 disables recording).
    checkpoint_every / checkpoint_dir:
        Crash-consistency: every ``checkpoint_every`` completed rounds the
        engine calls :meth:`save_checkpoint` into ``checkpoint_dir`` —
        global model + version + learner profiles + store state + journal
        cursor.  :meth:`restore` on a freshly constructed controller (same
        config, learners registered) resumes mid-workflow bit-identically.
        Both default to off; ``engine.run(checkpoint_every=..., ...)``
        overrides per run.

    All wire/store/dispatch counters live behind one
    :class:`~repro.core.metrics.Telemetry` registry at
    :attr:`Controller.telemetry` (``telemetry.value(name)`` /
    ``telemetry.snapshot()``); the legacy attributes
    (``dispatch_serializations``, ``upload_fallback_packs``,
    ``channel.stats.*``, ``arena.bytes_ingested``...) remain as deprecated
    read shims.  Names: ``docs/OBSERVABILITY.md``.
    """

    def __init__(
        self,
        protocol: ProtocolPolicy | None = None,
        selection: SelectionPolicy | None = None,
        aggregate_fn: AggregateFn | None = None,
        server_optimizer: ServerOptimizer | None = None,
        store: ModelStore | None = None,
        channel: Channel | None = None,
        secure: bool = False,
        max_dispatch_workers: int = 32,
        secure_seed: int = 0,
        store_mode: str = "arena",
        masked_aggregate_fn: Callable | None = None,
        arena_n_max: int = 8,
        arena_row_align: int = 1024,
        arena_mesh: Any = None,
        arena_axes: Any = None,
        arena_dtype: str = "f32",
        sparse_mode: str = "densify",
        flat_uploads: bool = True,
        upload_codec: Any = None,
        profile_decay: float = 0.5,
        journal: EventJournal | None = None,
        journal_sink: Any = None,
        journal_capacity: int = 4096,
        checkpoint_every: int | None = None,
        checkpoint_dir: str | None = None,
        aggregation_rule: str = "fedavg",
        trim_k: int = 1,
        admission_control: bool = True,
        admission_clip_factor: float = 4.0,
        admission_ewma_decay: float = 0.9,
        admission_warmup: int = 8,
        quarantine_threshold: float = 2.0,
        quarantine_decay: float = 0.75,
    ):
        if store_mode not in ("arena", "stack"):
            raise ValueError(f"store_mode must be 'arena' or 'stack', got {store_mode!r}")
        if arena_dtype not in ("f32", "int8"):
            raise ValueError(
                f"arena_dtype must be 'f32' or 'int8', got {arena_dtype!r}"
            )
        if arena_dtype == "int8":
            # The quantized-resident arena supports exactly the weighted-
            # average family (fused dequant-into-aggregate); everything that
            # needs f32 rows declares itself f32-only instead of silently
            # widening the resident state back to 4 bytes/param.
            if store_mode != "arena":
                raise ValueError(
                    "arena_dtype='int8' requires store_mode='arena'; the "
                    "stack store keeps decoded f32 buffers"
                )
            if secure:
                raise ValueError(
                    "arena_dtype='int8' cannot run under secure "
                    "aggregation: mask-encoded fixed-point rows are f32-only"
                )
            if aggregation_rule != "fedavg":
                raise ValueError(
                    f"aggregation_rule={aggregation_rule!r} is f32-only: "
                    "order statistics sort full-precision rows and have no "
                    "fused dequantized form.  Use arena_dtype='f32' for "
                    "robust rules — see the support matrix in docs/ARENA.md"
                )
            if aggregate_fn is not None or masked_aggregate_fn is not None:
                raise ValueError(
                    "arena_dtype='int8' cannot honour a custom aggregate_fn/"
                    "masked_aggregate_fn: custom rules expect an f32 arena "
                    "buffer, not int8 values + scales"
                )
        self.arena_dtype = arena_dtype
        if store is not None and store_mode == "arena":
            # An explicit hash-map store would be silently bypassed by the
            # arena hot path — refuse the contradiction instead.
            raise ValueError(
                "store= is only honoured with store_mode='stack'; the arena "
                "mode keeps uploads in its device-resident ArenaStore"
            )
        self.protocol = protocol or SyncProtocol()
        self.selection = selection or SelectionPolicy()
        if aggregation_rule not in ("fedavg", "median", "trimmed_mean"):
            raise ValueError(
                "aggregation_rule must be 'fedavg', 'median' or "
                f"'trimmed_mean', got {aggregation_rule!r}"
            )
        if not isinstance(trim_k, int) or trim_k < 1:
            raise ValueError(f"trim_k must be an int >= 1, got {trim_k!r}")
        self.aggregation_rule = aggregation_rule
        self.trim_k = int(trim_k)
        if aggregation_rule != "fedavg":
            # Robust rules are order statistics: they have no secure-sum
            # form, no staleness-weighted form, and they replace (rather
            # than compose with) a custom aggregate function.
            if aggregate_fn is not None or masked_aggregate_fn is not None:
                raise ValueError(
                    "aggregation_rule= and a custom aggregate_fn/"
                    "masked_aggregate_fn are mutually exclusive"
                )
            if secure:
                raise ValueError(
                    f"aggregation_rule={aggregation_rule!r} cannot run under "
                    "secure aggregation: the controller only ever sees a "
                    "masked sum, and order statistics need the rows"
                )
            if (self.protocol.weighting() == "staleness"
                    or getattr(self.protocol, "aggregate_scope", None)
                    == "buffer"):
                raise ValueError(
                    f"aggregation_rule={aggregation_rule!r} is not defined "
                    "for staleness-weighted protocols (async / FedBuff): "
                    "the staleness discount has no order-statistic "
                    "analogue.  Use aggregation_rule='fedavg' there — see "
                    "the support matrix in docs/PROTOCOLS.md"
                )
        # A custom masked rule (or the wrapped custom aggregate_fn) opts out
        # of the rule-matched sharded reduction built in set_initial_model.
        self._masked_is_default = (
            aggregate_fn is None and masked_aggregate_fn is None
        )
        if aggregation_rule == "median":
            self.aggregate_fn = lambda stack, w: aggregation.coordinate_median(
                stack
            )
            self.masked_aggregate_fn = aggregation.masked_coordinate_median
        elif aggregation_rule == "trimmed_mean":
            _tk = self.trim_k
            self.aggregate_fn = lambda stack, w: aggregation.trimmed_mean(
                stack, _tk
            )
            self.masked_aggregate_fn = (
                lambda arena, w, m: aggregation.masked_trimmed_mean(
                    arena, w, m, _tk
                )
            )
        elif masked_aggregate_fn is not None:
            self.aggregate_fn = aggregate_fn or aggregation.fedavg
            self.masked_aggregate_fn = masked_aggregate_fn
        elif aggregate_fn is not None:
            self.aggregate_fn = aggregate_fn
            self.masked_aggregate_fn = (
                lambda arena, w, m: aggregate_fn(arena, w * m)
            )
        else:
            self.aggregate_fn = aggregation.fedavg
            self.masked_aggregate_fn = aggregation.masked_weighted_average
        self.server_opt = server_optimizer or make_server_optimizer("fedavg")
        self.store = store or ModelStore()
        self.store_mode = store_mode
        self.arena: ArenaStore | None = None
        self._arena_n_max = arena_n_max
        self._arena_row_align = arena_row_align
        self.arena_mesh = arena_mesh
        self.arena_axes = arena_axes
        if arena_mesh is not None and store_mode != "arena":
            raise ValueError("arena_mesh= requires store_mode='arena'")
        # Built lazily in set_initial_model when the arena is sharded.
        self._sharded_masked_fn: Callable | None = None
        self._sharded_staleness_fn: Callable | None = None
        # Quantized-arena (arena_dtype='int8') sharded reductions — mutually
        # exclusive with the f32 pair above.
        self._sharded_q8_fn: Callable | None = None
        self._sharded_staleness_q8_fn: Callable | None = None
        # Sparse-arena (sparse_mode='direct') scatter-accumulate reductions.
        self._sharded_topk_fn: Callable | None = None
        self._sharded_staleness_topk_fn: Callable | None = None
        self.channel = channel or Channel()
        if upload_codec is not None:
            self.channel.upload_codec = get_upload_codec(upload_codec)
        # Sparse (top-k) uplink: rows hold *deltas* (the learner sparsifies
        # its update against the shipped model, carrying the rest as an
        # error-feedback residual), so every aggregate commits
        # ``global_buffer + aggregated_delta``.  ``sparse_mode`` picks how
        # a sparse upload lands: 'densify' scatters it into the existing
        # dense row (every store/rule keeps working); 'direct' keeps an
        # (n_max, k) index/value arena resident and aggregates through the
        # masked scatter-accumulate (see docs/ARENA.md support matrix).
        self._topk = (
            getattr(self.channel.upload_codec, "codec_id", None) == "topk"
        )
        if sparse_mode not in ("direct", "densify"):
            raise ValueError(
                f"sparse_mode must be 'direct' or 'densify', "
                f"got {sparse_mode!r}"
            )
        self.sparse_mode = sparse_mode
        if self._topk:
            if secure:
                raise ValueError(
                    "upload_codec='topk' cannot run under secure "
                    "aggregation: the controller must densify and re-weight "
                    "sparse deltas, and the masked fixed-point rows admit "
                    "neither"
                )
            if not flat_uploads:
                raise ValueError(
                    "upload_codec='topk' requires flat_uploads=True: the "
                    "error-feedback residual lives learner-side against "
                    "the shipped wire manifest"
                )
            if aggregate_fn is not None or masked_aggregate_fn is not None:
                raise ValueError(
                    "upload_codec='topk' cannot honour a custom "
                    "aggregate_fn/masked_aggregate_fn: sparse rows hold "
                    "deltas, and custom rules expect full-parameter rows"
                )
        if sparse_mode == "direct":
            if not self._topk:
                raise ValueError(
                    "sparse_mode='direct' requires upload_codec='topk'"
                )
            if store_mode != "arena":
                raise ValueError(
                    "sparse_mode='direct' requires store_mode='arena'; the "
                    "stack store keeps dense decoded buffers"
                )
            if aggregation_rule != "fedavg":
                raise ValueError(
                    "sparse_mode='direct' supports only "
                    "aggregation_rule='fedavg'; the robust order-statistic "
                    "rules need dense rows — use sparse_mode='densify' "
                    f"(got {aggregation_rule!r})"
                )
            if arena_dtype != "f32":
                raise ValueError(
                    "sparse_mode='direct' keeps its own (n, k) sparse "
                    "arena; it cannot combine with "
                    f"arena_dtype={arena_dtype!r}"
                )
        # The unified observability surface: the controller adopts its
        # channel's registry, so every channel.* counter and every store/
        # controller instrument is reachable through this one handle.
        self.telemetry: Telemetry = self.channel.telemetry
        self.store.bind_telemetry(self.telemetry)
        self.secure = secure
        self.secure_seed = secure_seed
        self.profile_decay = profile_decay
        self.checkpoint_every = checkpoint_every
        self.checkpoint_dir = checkpoint_dir
        # Admission control: a cheap screen at ingest.  Non-finite buffers
        # are rejected outright; once the EWMA of accepted update norms has
        # warmed up, outlier norms are clipped down to factor * EWMA.
        # Disabled under secure aggregation — the controller only ever sees
        # mask-encoded rows there, whose norms are meaningless.
        self.admission_control = bool(admission_control) and not secure
        self.admission_clip_factor = float(admission_clip_factor)
        self.admission_ewma_decay = float(admission_ewma_decay)
        self.admission_warmup = int(admission_warmup)
        self._adm_ewma: float | None = None
        self._adm_accepted = 0
        # Quarantine: per-learner decaying offense score.  Each rejected or
        # clipped upload adds 1 at the current round; the score decays by
        # quarantine_decay per round since the last offense, and a learner
        # is excluded from cohort selection while score >= threshold —
        # repeat offenders sit out, a single glitch does not.
        self.quarantine_threshold = float(quarantine_threshold)
        self.quarantine_decay = float(quarantine_decay)
        self._offenses: dict[str, tuple[float, int]] = {}
        # Hysteresis: entered at score >= threshold, released only once the
        # score decays below threshold/2 — without it a learner would enter
        # and be released by the very next round's decay tick.
        self._quarantined: set[str] = set()

        self._learners: dict[str, Learner] = {}
        self._learner_profiles: dict[str, LearnerProfile] = {}
        # Churn bookkeeping: lid -> round_id at dropout.  Profiles survive
        # deregistration so a rejoining learner resumes its EWMA histories
        # (reputation decayed over the absence).
        self._deregistered_at: dict[str, int] = {}
        self._c_dropouts = self.telemetry.counter("engine.faults.dropouts")
        self._c_rejoins = self.telemetry.counter("engine.faults.rejoins")
        # Admission / quarantine instrumentation (docs/OBSERVABILITY.md).
        self._c_rejected_nonfinite = self.telemetry.counter(
            "engine.uploads.rejected.nonfinite"
        )
        self._c_clipped = self.telemetry.counter("engine.uploads.clipped")
        # Quantized-arena fast paths (docs/OBSERVABILITY.md): uploads landed
        # in int8 form with no f32 materialization, and fused dequant-into-
        # aggregate reductions fired.
        self._c_quant_direct = self.telemetry.counter(
            "engine.uploads.quantized_direct"
        )
        self._c_fused_agg = self.telemetry.counter(
            "controller.aggregations.fused_q8"
        )
        # Sparse-uplink fast paths (docs/OBSERVABILITY.md): uploads landed
        # in the (n, k) sparse arena with no densification, and masked
        # scatter-accumulate reductions fired.
        self._c_sparse_direct = self.telemetry.counter(
            "engine.uploads.sparse_direct"
        )
        self._c_sparse_agg = self.telemetry.counter(
            "controller.aggregations.sparse_scatter"
        )
        self._c_quarantined = self.telemetry.counter("engine.quarantine.entered")
        self._g_quarantine = self.telemetry.gauge("engine.quarantine.active")
        self._store_lock = threading.Lock()

        self.global_params: Any = None
        self.global_buffer: jax.Array | None = None
        self.manifest: packing.Manifest | None = None
        self._server_state = None
        self.round_id = 0
        self.history: list[RoundTimings] = []
        # model-version state (continuous policy staleness accounting)
        self._model_version = 0
        self._learner_versions: dict[str, int] = {}
        # serialize-once dispatch state: one wire payload per model version
        self.flat_uploads = flat_uploads
        self._wire_lock = threading.Lock()
        self._wire_cache: tuple[tuple, Broadcast] | None = None
        # perf counters asserted by tests/test_dispatch.py: actual global-
        # model serializations triggered by dispatch, and the number of
        # uploads the controller had to flatten itself (0 on the fast path)
        self._c_dispatch_ser = self.telemetry.counter(
            "controller.dispatch_serializations"
        )
        self._c_fallback = self.telemetry.counter(
            "controller.upload_fallback_packs"
        )
        self._g_version = self.telemetry.gauge("controller.model_version")
        # The round engine owns the executor and the event loop; the
        # controller is its plumbing surface.  The journal is the engine's
        # flight recorder (an injected one wins over the sink/capacity knobs).
        if journal is None:
            journal = EventJournal(capacity=journal_capacity, sink=journal_sink)
        self.engine = RoundEngine(
            self, max_dispatch_workers=max_dispatch_workers, journal=journal
        )

    @property
    def dispatch_serializations(self) -> int:
        """Deprecated shim for ``telemetry.value('controller.dispatch_serializations')``."""
        return self._c_dispatch_ser.value

    @property
    def upload_fallback_packs(self) -> int:
        """Deprecated shim for ``telemetry.value('controller.upload_fallback_packs')``."""
        return self._c_fallback.value

    @property
    def journal(self) -> EventJournal:
        """The engine's flight recorder (``core/journal.EventJournal``)."""
        return self.engine.journal

    # ------------------------------------------------------------------ init
    def set_initial_model(self, params: Any) -> None:
        """Driver ships initial model tensors to the controller (Fig. 8).

        The controller's canonical model state is the flat numeric
        ``global_buffer`` + cached ``manifest``; ``global_params`` is
        normalized through one numeric roundtrip so the serialize-once
        broadcast (which reads the buffer) and the legacy per-send path
        (which reads the pytree) are bit-identical from round zero.
        """
        self.manifest = packing.build_manifest(params)
        self.global_buffer = packing.pack_numeric(params)
        self.global_params = packing.unpack_numeric(self.global_buffer, self.manifest)
        self._server_state = self.server_opt.init(self.global_buffer)
        self.invalidate_wire_cache()
        if self.store_mode == "arena":
            direct = self._topk and self.sparse_mode == "direct"
            self.arena = ArenaStore(
                num_params=max(1, int(self.global_buffer.shape[0])),
                n_max=max(self._arena_n_max, len(self._learners)),
                row_align=self._arena_row_align,
                mesh=self.arena_mesh,
                axes=self.arena_axes,
                telemetry=self.telemetry,
                arena_dtype="topk" if direct else self.arena_dtype,
                sparse_k=(
                    self.channel.upload_codec.k if direct else None
                ),
            )
            # Deterministic row order: rows follow *registration* order, not
            # first-upload arrival order, so arena aggregation order — and
            # with it the kill-and-resume parity contract — is reproducible.
            for lid in self._learners:
                self.arena.ensure_row(lid)
            if self.aggregation_rule == "trimmed_mean" and (
                2 * self.trim_k >= self.arena.n_max
            ):
                raise ValueError(
                    f"trim_k={self.trim_k} trims 2*trim_k={2 * self.trim_k} "
                    f"rows but the arena only holds {self.arena.n_max}; "
                    "every cohort would fall back to the untrimmed mean"
                )
            if self.arena.sharded:
                # Per-shard masked reductions over the column-sharded arena
                # (zero collectives; numerically identical to single-device).
                # Coordinate-wise rules all shard the same way, so the
                # reduction is matched to the configured aggregation_rule.
                # A user-supplied masked rule is honoured as-is — it runs on
                # the sharded buffer with whatever layout XLA infers.
                alpha = getattr(self.protocol, "staleness_alpha", 0.5)
                if self.arena.arena_dtype == "topk":
                    # Sparse arena: replicated (n, k) inputs, column-sharded
                    # (P,) output — each shard buckets the global indices
                    # into its own column window and scatters locally, so
                    # the compiled HLO stays collective-free.
                    self._sharded_topk_fn = (
                        aggregation.masked_fedavg_topk_sharded(
                            self.arena.mesh, self.arena.axes,
                            self.arena.padded_params,
                        )
                    )
                    self._sharded_staleness_topk_fn = (
                        aggregation.masked_staleness_topk_sharded(
                            self.arena.mesh, self.arena.axes,
                            self.arena.padded_params, alpha,
                        )
                    )
                elif self.arena_dtype == "int8":
                    # Quantized arena: the fused dequant-into-aggregate pair
                    # (values + scales share the column sharding; zero
                    # collectives).  Robust rules and custom fns were
                    # rejected at construction, so fedavg is the only rule.
                    self._sharded_q8_fn = aggregation.masked_fedavg_q8_sharded(
                        self.arena.mesh, self.arena.axes, self.arena.qgroup
                    )
                    self._sharded_staleness_q8_fn = (
                        aggregation.masked_staleness_q8_sharded(
                            self.arena.mesh, self.arena.axes, alpha,
                            self.arena.qgroup,
                        )
                    )
                elif self._masked_is_default:
                    if self.aggregation_rule == "median":
                        self._sharded_masked_fn = (
                            aggregation.masked_median_sharded(
                                self.arena.mesh, self.arena.axes
                            )
                        )
                    elif self.aggregation_rule == "trimmed_mean":
                        self._sharded_masked_fn = (
                            aggregation.masked_trimmed_mean_sharded(
                                self.arena.mesh, self.arena.axes, self.trim_k
                            )
                        )
                    else:
                        self._sharded_masked_fn = (
                            aggregation.masked_fedavg_sharded(
                                self.arena.mesh, self.arena.axes
                            )
                        )
                if (self.arena_dtype != "int8"
                        and self.arena.arena_dtype != "topk"):
                    self._sharded_staleness_fn = (
                        aggregation.masked_staleness_sharded(
                            self.arena.mesh, self.arena.axes, alpha
                        )
                    )
        for learner in self._learners.values():
            self._ship_manifest(learner)

    def _ship_manifest(self, learner: Learner) -> None:
        """Ship the wire contract (manifest + row width + channel) once.

        This is the flat-upload contract: with the manifest resident the
        learner packs its own uploads (padded to the arena row width) and —
        with the channel handle — sends them through the measured uplink
        (``Channel.upload``), so arrival is a codec decode plus a straight
        arena row write.  No-op until the initial model exists or when
        ``flat_uploads=False``.
        """
        if not self.flat_uploads or self.manifest is None:
            return
        pad_to = self.arena.padded_params if self.arena is not None else None
        learner.accept_manifest(self.manifest, pad_to=pad_to, channel=self.channel)

    def register_learner(self, learner: Learner) -> None:
        """Admit a learner to the federation (paper Fig. 8 join).

        A learner rejoining after :meth:`deregister_learner` keeps its
        accumulated EWMA profile — with the reputation estimate
        multiplicatively decayed over the rounds it was absent
        (churn-aware standing; counted in ``engine.faults.rejoins``).

        Thread contract: membership mutations are **not** synchronized
        with the engine loop — call :meth:`register_learner` /
        :meth:`deregister_learner` only while the engine loop is idle
        (between ``engine.run`` calls, as the stress harness does), or
        from within the loop thread itself.  Calling them from another
        thread while ``RoundEngine.run`` is executing races with arrival
        handling and dispatch.
        """
        lid = learner.learner_id
        rejoining = lid in self._deregistered_at
        self._learners[lid] = learner
        prof = self._learner_profiles.get(lid)
        if prof is None:
            self._learner_profiles[lid] = LearnerProfile(decay=self.profile_decay)
        elif rejoining:
            prof.decay_reputation(self.round_id - self._deregistered_at[lid])
        if rejoining:
            del self._deregistered_at[lid]
            self._c_rejoins.add(1)
        self._learner_versions[lid] = 0
        if self.arena is not None:
            self.arena.ensure_row(lid)
        self._ship_manifest(learner)

    def deregister_learner(self, learner_id: str) -> None:
        """Remove a learner mid-federation (dropout; paper Fig. 8 leave).

        Its store row is invalidated/discarded (a pending contribution
        leaves the aggregation set), its EWMA profile is *kept* so a rejoin
        resumes where it left off, and any upload still in flight lands as
        a tolerated, counted orphan (``engine.uploads.orphaned``) instead
        of crashing the engine loop.  Unknown ids are a no-op.

        Thread contract: this mutates engine-loop-owned state
        (``_learners``, the FedBuff buffer) without synchronization — see
        :meth:`register_learner`: only call it while the engine loop is
        idle (between ``engine.run`` calls) or from the loop thread.
        """
        if learner_id not in self._learners:
            return
        del self._learners[learner_id]
        self._deregistered_at[learner_id] = int(self.round_id)
        if self.arena is not None:
            if learner_id in self.arena._rows:
                self.arena.invalidate(learner_id)
        elif self.store_mode == "stack":
            with self._store_lock:
                self.store.discard(learner_id)
        # A buffered (ingested-but-unaggregated) FedBuff member can no
        # longer contribute: drop it from the pending buffer too.
        if learner_id in self.engine._buffer:
            self.engine._buffer.remove(learner_id)
        self._c_dropouts.add(1)

    @property
    def learner_ids(self) -> list[str]:
        """IDs of every registered learner, in registration order."""
        return list(self._learners)

    # -------------------------------------------------------------- dispatch
    def _broadcast(self) -> Broadcast:
        """The current model's shared wire payload, serialized at most once.

        Cached per (model version, codec): every dispatch within one version
        — train fan-out, eval fan-out, async re-dispatches between community
        updates — reuses the same read-only byte buffer, and the bytes come
        straight off ``global_buffer`` with the cached manifest (no pytree
        flattening, no manifest rebuild).  Aggregation bumps the version,
        which invalidates the cache on the next dispatch.
        """
        key = (self._model_version, id(self.channel.codec))
        with self._wire_lock:
            if self._wire_cache is None or self._wire_cache[0] != key:
                bc = self.channel.broadcast(
                    params=self.global_params,
                    buffer=self.global_buffer,
                    manifest=self.manifest,
                )
                self._c_dispatch_ser.add(1)
                self._wire_cache = (key, bc)
            return self._wire_cache[1]

    def invalidate_wire_cache(self) -> None:
        """Drop the cached broadcast, as if the model had just been re-published.

        The next dispatch pays one full serialization — benchmarks use this
        to measure the cold-cache dispatch cost deterministically.
        """
        with self._wire_lock:
            self._wire_cache = None

    # ------------------------------------------------------------ wire model
    def wire_time_s(self, learner_id: str) -> float:
        """Per-learner round-trip virtual wire estimate: downlink + uplink.

        Downlink is the broadcast envelope (``manifest.total_bytes``);
        uplink is the learner's last measured upload payload (recorded in
        its profile at ingest) or, before the first upload, the channel
        codec's modeled payload size for the padded row width.  The
        semi-sync policy subtracts this from its hyper-period budget so
        bandwidth-capped federations still finish inside the budget
        (``SemiSyncProtocol.size_task``; math in ``docs/ENGINE.md``).
        """
        if self.manifest is None:
            return 0.0
        down = int(self.manifest.total_bytes)
        prof = self._learner_profiles.get(learner_id)
        up = prof.get("upload_bytes") if prof is not None else None
        if up is None:
            n = (
                self.arena.padded_params
                if self.arena is not None
                else int(self.global_buffer.shape[0])
            )
            wire_nbytes = getattr(self.channel.upload_codec, "wire_nbytes", None)
            up = wire_nbytes(n) if wire_nbytes is not None else 4 * n
        return self.channel.round_trip_s(down, int(up), learner_id=learner_id)

    # ---------------------------------------------------------------- ingest
    def _upload_buffer(
        self,
        update: LocalUpdate,
        pad_to: int | None,
        with_norm: bool = False,
    ) -> jax.Array | tuple[jax.Array, jax.Array]:
        """The upload's decoded flat buffer, always off the measured uplink.

        Fast path: the learner already sent its packed row through
        ``Channel.upload`` and the update carries the wire envelope — decode
        it (one ``device_put`` + jitted codec decode).  Legacy paths (a bare
        pre-packed buffer, or ``flat_uploads=False`` where the controller
        must flatten the pytree itself — counted in ``upload_fallback_packs``)
        still cross the same measured half, with the controller standing in
        for the learner's send: every upload on every protocol is encoded,
        byte-accounted, and decoded through the channel's upload codec.

        With ``with_norm=True`` returns ``(buffer, norm)`` where ``norm``
        is the f32 L2 norm as an *unread device scalar*, fused into the
        same jitted decode — so the admission screen's single host sync
        covers an already-computed value instead of launching (and
        blocking on) a separate reduction per upload.
        """
        if update.upload is not None:
            return self.channel.recv_upload(update.upload, with_norm=with_norm)
        buffer = update.buffer
        if buffer is None:
            self._c_fallback.add(1)
            buffer = packing.pack_numeric(update.params, pad_to=pad_to)
        envelope = self.channel.upload(
            buffer, metadata={"learner_id": update.learner_id,
                              "round_id": update.round_id},
        )
        return self.channel.recv_upload(envelope, with_norm=with_norm)

    def _screen_norm(
        self, learner_id: str, norm: float
    ) -> tuple[float | None, dict | None]:
        """The admission decision on an already-materialized norm scalar.

        A single NaN/inf anywhere in the row makes its norm non-finite
        (reject with :class:`UploadRejectedError`; counted in
        ``engine.uploads.rejected.nonfinite``), and once
        ``admission_warmup`` uploads have seeded the EWMA of accepted
        norms, a norm beyond ``admission_clip_factor`` times that EWMA
        must be rescaled down to the limit (counted in
        ``engine.uploads.clipped``).  Accepted (possibly clipped) norms
        feed the EWMA, so the envelope tracks the federation's own update
        scale.

        Returns ``(scale, clip_info)``: ``scale`` is the multiplicative
        clip factor the caller must apply to the row (``None`` when the
        row passes untouched), ``clip_info`` is ``None`` or
        ``{"norm": original, "limit": applied}``.
        """
        if not math.isfinite(norm):
            self._c_rejected_nonfinite.add(1)
            raise UploadRejectedError(learner_id, "nonfinite", norm)
        scale: float | None = None
        clip: dict | None = None
        if (
            self._adm_ewma is not None
            and self._adm_accepted >= self.admission_warmup
        ):
            limit = self.admission_clip_factor * self._adm_ewma
            if norm > limit > 0.0:
                scale = limit / norm
                self._c_clipped.add(1)
                clip = {"norm": norm, "limit": limit}
                norm = limit
        d = self.admission_ewma_decay
        self._adm_ewma = (
            norm if self._adm_ewma is None
            else d * self._adm_ewma + (1.0 - d) * norm
        )
        self._adm_accepted += 1
        return scale, clip

    def _screen_upload(
        self,
        learner_id: str,
        buffer: jax.Array,
        norm: jax.Array | None = None,
    ) -> tuple[jax.Array, dict | None]:
        """The admission screen: reject non-finite rows, clip norm outliers.

        One scalar — the f32 L2 norm of the decoded buffer — covers both
        checks (see :meth:`_screen_norm` for the decision itself).  The
        norm readback is the screen's single blocking host sync per
        upload; pass ``norm`` (an unread device scalar fused into the
        upload decode by ``recv_upload(..., with_norm=True)``) so that
        sync reads back an already-scheduled value instead of launching a
        fresh full-row reduction and waiting on it.

        Returns ``(buffer, clip_info)`` where ``clip_info`` is ``None`` or
        ``{"norm": original, "limit": applied}``.
        """
        if norm is None:
            norm = transport._row_norm(buffer)
        scale, clip = self._screen_norm(learner_id, float(norm))
        if scale is not None:
            buffer = buffer * jnp.asarray(scale, buffer.dtype)
        return buffer, clip

    def ingest(self, update: LocalUpdate) -> dict | None:
        """MarkTaskCompleted plumbing: decode the upload, store it, profile it.

        Called by the engine loop on every ``UploadArrived`` event.  Fast
        path (``flat_uploads``): the learner already packed its params at
        the arena's padded row width and sent them through the measured
        uplink, so arena mode is a codec decode plus a straight donated row
        write — zero pytree flattening, zero host concatenation on arrival.
        Otherwise the controller packs here (the legacy path, counted in
        ``upload_fallback_packs``) and routes the buffer through the same
        measured half.  Stack mode inserts the decoded buffer into the
        hash-map store either way.  The learner's EWMA profile absorbs the
        task's measured seconds-per-step and (fast path) wire payload size.

        With :attr:`admission_control` on, the decoded buffer passes the
        :meth:`_screen_upload` screen first: non-finite rows raise
        :class:`~repro.core.engine.UploadRejectedError` (nothing is stored;
        the engine journals the rejection and treats the learner as
        dropped for the round), and norm outliers are clipped before the
        row write.  The screen's norm is fused into the upload decode
        (``recv_upload(..., with_norm=True)``), so admission costs one
        host readback of an already-scheduled scalar instead of a
        blocking full-row reduction per upload.  Returns the screen's
        clip info (``None`` when the upload was stored untouched) so the
        engine can journal the clip.

        Quantized arenas (``arena_dtype='int8'``) take a *direct landing*
        when the wire codec matches the arena layout (int8 codec, same
        quantization group, row-width payload): the wire's int8 groups and
        f32 scales are split device-side and written straight into the
        arena — no f32 materialization, no requantization.  Norm
        screening happens in quantized form
        (:math:`\\sqrt{\\sum_g s_g^2 \\sum_i q_{g,i}^2}`) and clipping
        rescales the scales vector.  Counted in
        ``engine.uploads.quantized_direct``.
        """
        clip: dict | None = None
        if self.store_mode == "arena":
            if self._sparse_direct_ok(update):
                idx, val, norm = self.channel.recv_upload_sparse(
                    update.upload
                )
                if self.admission_control:
                    scale, clip = self._screen_norm(
                        update.learner_id, float(norm)
                    )
                    if scale is not None:
                        # Clipping a sparse row == rescaling its values
                        # (top-k indices are unique, so the value-vector
                        # norm *is* the row norm).
                        val = val * jnp.float32(scale)
                self.arena.write_sparse(
                    update.learner_id,
                    idx,
                    val,
                    weight=float(update.num_examples),
                    version=float(
                        self._learner_versions.get(update.learner_id, 0)
                    ),
                )
                self._c_sparse_direct.add(1)
            elif (self.arena is not None
                    and self.arena.arena_dtype == "topk"):
                raise ValueError(
                    "sparse_mode='direct' arena can only land registry "
                    "'topk' envelopes packed at the arena row width; got "
                    f"codec={getattr(update.upload, 'codec', None)!r}"
                )
            elif self._quant_direct_ok(update):
                q, scales, norm = self.channel.recv_upload_quantized(
                    update.upload, self.arena.padded_params
                )
                if self.admission_control:
                    scale, clip = self._screen_norm(
                        update.learner_id, float(norm)
                    )
                    if scale is not None:
                        # Clipping a quantized row == rescaling its scales.
                        scales = scales * jnp.float32(scale)
                self.arena.write_quantized(
                    update.learner_id,
                    q,
                    scales,
                    weight=float(update.num_examples),
                    version=float(
                        self._learner_versions.get(update.learner_id, 0)
                    ),
                )
                self._c_quant_direct.add(1)
            else:
                if self.admission_control:
                    buffer, norm = self._upload_buffer(
                        update, pad_to=self.arena.padded_params,
                        with_norm=True,
                    )
                    buffer, clip = self._screen_upload(
                        update.learner_id, buffer, norm=norm
                    )
                else:
                    buffer = self._upload_buffer(
                        update, pad_to=self.arena.padded_params
                    )
                self.arena.write(
                    update.learner_id,
                    buffer,
                    weight=float(update.num_examples),
                    version=float(
                        self._learner_versions.get(update.learner_id, 0)
                    ),
                )
        else:
            if self.admission_control:
                buffer, norm = self._upload_buffer(
                    update, pad_to=None, with_norm=True
                )
                buffer, clip = self._screen_upload(
                    update.learner_id, buffer, norm=norm
                )
            else:
                buffer = self._upload_buffer(update, pad_to=None)
            with self._store_lock:
                self.store.insert(
                    ModelRecord(
                        learner_id=update.learner_id,
                        round_id=update.round_id,
                        buffer=buffer,
                        num_examples=update.num_examples,
                        metadata={
                            **update.metrics,
                            "seconds_per_step": update.seconds_per_step,
                            "model_version": self._learner_versions.get(
                                update.learner_id, 0
                            ),
                        },
                    )
                )
        prof = self._learner_profiles[update.learner_id]
        prof.observe_step_time(update.seconds_per_step)
        if update.upload is not None:
            prof.observe_upload_bytes(update.upload.payload.nbytes)
        return clip

    def _sparse_direct_ok(self, update: LocalUpdate) -> bool:
        """True when the upload can land in the (n, k) sparse arena as-is.

        Requires a ``sparse_mode='direct'`` arena and a wire envelope from
        the registry ``topk`` codec whose payload was packed at the arena's
        padded row width (the ``flat_uploads`` fast path) — the arena row
        then *is* the wire's (index, value) stream, decoded device-side
        with the row norm fused into the same program.
        """
        if self.arena is None or self.arena.arena_dtype != "topk":
            return False
        env = update.upload
        return (
            env is not None
            and env.codec == "topk"
            and int(env.num_elements) == self.arena.padded_params
        )

    def _quant_direct_ok(self, update: LocalUpdate) -> bool:
        """True when the upload can land in the int8 arena without dequant.

        Requires an int8 arena, a wire envelope from the registry ``int8``
        codec whose quantization group matches the arena's ``qgroup``, and
        a payload already packed at the arena's padded row width (the
        ``flat_uploads`` fast path).  Anything else — raw codec, custom
        codec objects, group mismatch, legacy pytree uploads — falls back
        to the f32 decode, and :meth:`ArenaStore.write` requantizes.
        """
        if self.arena is None or self.arena.arena_dtype != "int8":
            return False
        env = update.upload
        return (
            env is not None
            and env.codec == "int8"
            and int(env.codec_params.get("group", 0)) == self.arena.qgroup
            and int(env.num_elements) == self.arena.padded_params
        )

    # ------------------------------------------------------------ quarantine
    def offense_score(self, learner_id: str) -> float:
        """The learner's decayed offense score as of the current round.

        Each rejected or clipped upload adds 1 at the round it happened;
        the stored score decays lazily by ``quarantine_decay`` per round
        elapsed since the last offense (no per-round sweep over the
        federation).
        """
        entry = self._offenses.get(learner_id)
        if entry is None:
            return 0.0
        score, last_round = entry
        delta = max(int(self.round_id) - int(last_round), 0)
        return score * (self.quarantine_decay ** delta)

    def note_offense(self, learner_id: str) -> bool:
        """Record one admission offense (rejected or clipped upload).

        Folds the decayed prior score plus 1 back into the table, stamped
        at the current round.  Returns True when this offense *newly*
        pushed the learner over ``quarantine_threshold`` (the engine
        journals a ``LearnerQuarantined`` event exactly then); counted in
        ``engine.quarantine.entered``, with the live population on the
        ``engine.quarantine.active`` gauge.
        """
        score = self.offense_score(learner_id) + 1.0
        self._offenses[learner_id] = (score, int(self.round_id))
        entered = (
            score >= self.quarantine_threshold
            and learner_id not in self._quarantined
        )
        if entered:
            self._quarantined.add(learner_id)
            self._c_quarantined.add(1)
        self._g_quarantine.set(len(self.quarantined_ids()))
        return entered

    def is_quarantined(self, learner_id: str) -> bool:
        """True while the learner sits inside the quarantine window.

        Entered at ``offense_score >= quarantine_threshold``; released
        (lazily, on this check) once decay drops the score below *half*
        the threshold — the hysteresis that makes the penalty an actual
        multi-round window instead of a single-round blip.  Quarantined
        learners are skipped by cohort selection
        (``RoundEngine._start_round``) — fail-open: if *every* available
        learner is quarantined the filter is waived rather than stalling
        the federation.
        """
        if learner_id not in self._quarantined:
            return False
        if self.offense_score(learner_id) < 0.5 * self.quarantine_threshold:
            self._quarantined.discard(learner_id)
            return False
        return True

    def quarantined_ids(self) -> list[str]:
        """Currently quarantined learner ids, in offense-table order."""
        return [lid for lid in self._offenses if self.is_quarantined(lid)]

    # ------------------------------------------------------------- aggregate
    def _commit(self, new_buffer: jax.Array) -> None:
        """Server-side optimization + global model swap + version bump.

        Sparse (topk) uplinks ship *deltas*, so the aggregate is a delta
        too: fold it onto the current global buffer first — the async-safe
        statement (the controller no longer holds each learner's base
        version), exactly equal to dense FedAvg when every cohort member
        trained from the same broadcast.
        """
        if self._topk:
            new_buffer = self.global_buffer + new_buffer
        self._server_state, new_buffer = self.server_opt.apply(
            self._server_state, self.global_buffer, new_buffer
        )
        new_buffer = jax.block_until_ready(new_buffer)
        self.global_buffer = new_buffer
        self.global_params = packing.unpack_numeric(new_buffer, self.manifest)
        self._model_version += 1
        self._g_version.set(self._model_version)

    def _mask_session_seed(self, epoch: int) -> int:
        """The per-epoch secure mask session (round id / model version key)."""
        from repro.core import secure as secure_mod

        return secure_mod.MaskSession(self.secure_seed, epoch).seed

    def aggregate_round(self, selected: list[str]) -> float:
        """Cohort aggregation for round-based policies (paper T4-T7).

        Arena mode: one masked reduction straight over the persistent device
        buffer — row writes already happened at arrival, so the round's
        critical path is just the reduce.  Stack mode: re-stack the stored
        buffers into an ``(N, P)`` array first (the legacy O(N·P) host copy).
        Secure mode sums mask-encoded fixed-point rows in a per-round mask
        session.  Commits the result; returns the aggregation seconds.
        """
        t0 = time.perf_counter()
        if self.store_mode == "arena":
            new_buffer = self._aggregate_arena(selected)
        else:
            with self._store_lock:
                records = self.store.select_latest(list(selected))
            if not records:
                raise RuntimeError("no local models available to aggregate")

            if self.secure:
                from repro.core import secure as secure_mod

                buffers = [r.buffer for r in records]
                weights = [float(r.num_examples) for r in records]
                new_buffer = secure_mod.secure_fedavg(
                    buffers, weights,
                    base_seed=self._mask_session_seed(self.round_id),
                )
            else:
                stack = jnp.stack([r.buffer for r in records], axis=0)
                weights = jnp.asarray(
                    [float(r.num_examples) for r in records], jnp.float32
                )
                new_buffer = self.aggregate_fn(stack, weights)
        self._commit(new_buffer)
        return time.perf_counter() - t0

    def _aggregate_arena(self, selected: list[str]) -> jax.Array:
        """Masked reduction over the arena restricted to the round's cohort."""
        arena = self.arena
        with arena.lock:
            if self.secure:
                from repro.core import secure as secure_mod

                rows, weights = [], []
                for lid in selected:
                    if lid in arena:
                        rows.append(arena.row_of(lid))
                        weights.append(arena.weight_of(lid))
                if not rows:
                    raise RuntimeError("no local models available to aggregate")
                # Sharded arena: sum the full padded width — padded_params is
                # divisible by n_shards by construction, so the column-sharded
                # int32 accumulator always engages (pairwise pads cancel
                # exactly whatever the width, and padding columns decode to
                # zero, so the [:num_params] slice is bit-identical to the
                # unpadded single-device sum).
                width = arena.padded_params if arena.sharded else arena.num_params
                return secure_mod.secure_fedavg_arena(
                    arena.buffer, rows, weights,
                    num_params=width,
                    base_seed=self._mask_session_seed(self.round_id),
                    out_sharding=arena.row_sharding,
                )[: arena.num_params]
            # Empty-cohort check from the arena's host-side row map: probing
            # the device mask (float(jnp.sum(mask))) would force a blocking
            # device round-trip onto every round's critical path.
            if arena.num_valid(list(selected)) == 0:
                raise RuntimeError("no local models available to aggregate")
            mask = arena.round_mask(list(selected))
            if arena.arena_dtype == "topk":
                # Masked scatter-accumulate straight off the (n, k) sparse
                # arena: the dense (N, P) stack is never built.
                if self._sharded_topk_fn is not None:
                    out = self._sharded_topk_fn(
                        arena.indices, arena.buffer, arena.weights, mask
                    )
                else:
                    out = aggregation.masked_fedavg_topk(
                        arena.indices, arena.buffer, arena.weights, mask,
                        arena.padded_params,
                    )
                self._c_sparse_agg.add(1)
                return out[: arena.num_params]
            if self.arena_dtype == "int8":
                # Fused dequant-into-aggregate: the reduce reads the int8
                # groups + scales directly, never materializing (N, P) f32.
                if self._sharded_q8_fn is not None:
                    out = self._sharded_q8_fn(
                        arena.buffer, arena.scales, arena.weights, mask
                    )
                else:
                    out = aggregation.masked_fedavg_q8(
                        arena.buffer, arena.scales, arena.weights, mask,
                        arena.qgroup,
                    )
                self._c_fused_agg.add(1)
                return out[: arena.num_params]
            # Built only for the rule-matched defaults (_masked_is_default);
            # a custom masked rule always takes the plain call below.
            if self._sharded_masked_fn is not None:
                out = self._sharded_masked_fn(arena.buffer, arena.weights, mask)
            else:
                out = self.masked_aggregate_fn(arena.buffer, arena.weights, mask)
            return out[: arena.num_params]

    def _staleness_q8(
        self, arena: ArenaStore, mask: jax.Array, alpha: float
    ) -> jax.Array:
        """Staleness-damped fused reduce over the quantized arena.

        Same math as ``masked_staleness_average`` with the dequant folded
        into the weighted sum; dispatches the column-sharded variant when
        the arena is sharded.  Counted in
        ``controller.aggregations.fused_q8``.
        """
        if self._sharded_staleness_q8_fn is not None:
            out = self._sharded_staleness_q8_fn(
                arena.buffer, arena.scales, arena.weights, arena.versions,
                jnp.float32(self._model_version), mask,
            )
        else:
            out = aggregation.masked_staleness_q8(
                arena.buffer, arena.scales, arena.weights, arena.versions,
                jnp.float32(self._model_version), mask, alpha,
                arena.qgroup,
            )
        self._c_fused_agg.add(1)
        return out[: arena.num_params]

    def _staleness_topk(
        self, arena: ArenaStore, mask: jax.Array, alpha: float
    ) -> jax.Array:
        """Staleness-damped scatter-accumulate over the sparse arena.

        Same math as ``masked_staleness_average`` restated over (index,
        value) streams; dispatches the column-sharded variant when the
        arena is sharded.  Counted in
        ``controller.aggregations.sparse_scatter``.
        """
        if self._sharded_staleness_topk_fn is not None:
            out = self._sharded_staleness_topk_fn(
                arena.indices, arena.buffer, arena.weights, arena.versions,
                jnp.float32(self._model_version), mask,
            )
        else:
            out = aggregation.masked_staleness_topk(
                arena.indices, arena.buffer, arena.weights, arena.versions,
                jnp.float32(self._model_version), mask,
                arena.padded_params, alpha,
            )
        self._c_sparse_agg.add(1)
        return out[: arena.num_params]

    def aggregate_community(self) -> float:
        """One staleness-weighted community update (the continuous policy).

        The arrival that triggered this update was already written in place
        by :meth:`ingest`, so there is no per-arrival stack rebuild — the
        paper's "community update request" cost is one fused kernel
        regardless of federation size.  With ``secure=True`` the update
        instead sums mask-encoded fixed-point rows weighted by the
        staleness-damped weights, inside a fresh per-epoch mask session
        keyed by the global model version (``core/secure.MaskSession``) —
        the controller still never sees an individual model.  Commits the
        result; returns the aggregation seconds.
        """
        alpha = getattr(self.protocol, "staleness_alpha", 0.5)
        t0 = time.perf_counter()
        if self.store_mode == "arena":
            arena = self.arena
            with arena.lock:
                if self.secure:
                    new_buffer = self._secure_community_arena(alpha)
                elif arena.arena_dtype == "topk":
                    new_buffer = self._staleness_topk(
                        arena, arena.mask, alpha
                    )
                elif self.arena_dtype == "int8":
                    new_buffer = self._staleness_q8(arena, arena.mask, alpha)
                elif self._sharded_staleness_fn is not None:
                    new_buffer = self._sharded_staleness_fn(
                        arena.buffer, arena.weights, arena.versions,
                        jnp.float32(self._model_version), arena.mask,
                    )[: arena.num_params]
                else:
                    new_buffer = aggregation.masked_staleness_average(
                        arena.buffer, arena.weights, arena.versions,
                        jnp.float32(self._model_version), arena.mask, alpha,
                    )[: arena.num_params]
        else:
            with self._store_lock:
                records = self.store.select_latest(None)  # all known models
            if not records:
                raise RuntimeError("no local models available to aggregate")
            if self.secure:
                from repro.core import secure as secure_mod

                weights = [
                    float(r.num_examples)
                    * (1.0 + self._model_version
                       - r.metadata.get("model_version", 0)) ** (-alpha)
                    for r in records
                ]
                new_buffer = secure_mod.secure_fedavg(
                    [r.buffer for r in records], weights,
                    base_seed=self._mask_session_seed(self._model_version),
                )
            else:
                stal = jnp.asarray(
                    [self._model_version - r.metadata.get("model_version", 0)
                     for r in records],
                    jnp.float32,
                )
                n_ex = jnp.asarray(
                    [float(r.num_examples) for r in records], jnp.float32
                )
                stack = jnp.stack([r.buffer for r in records], axis=0)
                w = aggregation.staleness_weights(n_ex, stal, alpha)
                new_buffer = self.aggregate_fn(stack, w)
        self._commit(new_buffer)
        return time.perf_counter() - t0

    def aggregate_buffer(self, members: list[str]) -> float:
        """One FedBuff community update over exactly the buffered members.

        The continuous buffered-async policy
        (``BufferedAsyncProtocol``, ``aggregate_scope == "buffer"``) fires
        this with the K learner ids the engine drained from its arrival
        buffer: the reduce is restricted to those members' stored rows —
        staleness-damped like :meth:`aggregate_community`, but *not* over
        every valid row.  Members are folded in **registration order**
        (not arrival order), so the reduce is deterministic under any
        executor interleaving.  Commits the result; returns the seconds.
        """
        alpha = getattr(self.protocol, "staleness_alpha", 0.5)
        wanted = set(members)
        ordered = [lid for lid in self._learners if lid in wanted]
        t0 = time.perf_counter()
        if not ordered:
            raise RuntimeError("no local models available to aggregate")
        if self.store_mode == "arena":
            arena = self.arena
            with arena.lock:
                if self.secure:
                    new_buffer = self._secure_community_arena(
                        alpha, members=ordered
                    )
                else:
                    if arena.num_valid(ordered) == 0:
                        raise RuntimeError(
                            "no local models available to aggregate"
                        )
                    mask = arena.round_mask(ordered)
                    if arena.arena_dtype == "topk":
                        new_buffer = self._staleness_topk(arena, mask, alpha)
                    elif self.arena_dtype == "int8":
                        new_buffer = self._staleness_q8(arena, mask, alpha)
                    elif self._sharded_staleness_fn is not None:
                        new_buffer = self._sharded_staleness_fn(
                            arena.buffer, arena.weights, arena.versions,
                            jnp.float32(self._model_version), mask,
                        )[: arena.num_params]
                    else:
                        new_buffer = aggregation.masked_staleness_average(
                            arena.buffer, arena.weights, arena.versions,
                            jnp.float32(self._model_version), mask, alpha,
                        )[: arena.num_params]
        else:
            with self._store_lock:
                records = self.store.select_latest(ordered)
            if not records:
                raise RuntimeError("no local models available to aggregate")
            if self.secure:
                from repro.core import secure as secure_mod

                weights = [
                    float(r.num_examples)
                    * (1.0 + self._model_version
                       - r.metadata.get("model_version", 0)) ** (-alpha)
                    for r in records
                ]
                new_buffer = secure_mod.secure_fedavg(
                    [r.buffer for r in records], weights,
                    base_seed=self._mask_session_seed(self._model_version),
                )
            else:
                stal = jnp.asarray(
                    [self._model_version - r.metadata.get("model_version", 0)
                     for r in records],
                    jnp.float32,
                )
                n_ex = jnp.asarray(
                    [float(r.num_examples) for r in records], jnp.float32
                )
                stack = jnp.stack([r.buffer for r in records], axis=0)
                w = aggregation.staleness_weights(n_ex, stal, alpha)
                new_buffer = self.aggregate_fn(stack, w)
        self._commit(new_buffer)
        return time.perf_counter() - t0

    def _secure_community_arena(
        self, alpha: float, members: list[str] | None = None
    ) -> jax.Array:
        """Secure async update off the arena: staleness-damped masked sum.

        Staleness weights are *metadata* (example counts and model-version
        lags — the same inputs clear-text FedAvg weighting uses), so they
        are computed host-side from the arena's mirrors and folded into the
        fixed-point encoding learner-side, exactly like the FedAvg weights
        of the synchronous secure path.  Mask seeds come from the per-epoch
        session (one session per global model version).  ``members``
        restricts the sum to those learners' valid rows (the FedBuff
        buffered path); ``None`` keeps the community-wide default.
        """
        from repro.core import secure as secure_mod

        arena = self.arena
        valid = arena.valid_ids()
        ids = [lid for lid in members if lid in set(valid)] \
            if members is not None else valid
        rows, weights = [], []
        for lid in ids:
            row = arena.row_of(lid)
            stale = float(self._model_version) - arena.version_of(lid)
            rows.append(row)
            weights.append(arena.weight_of(lid) * (1.0 + stale) ** (-alpha))
        if not rows:
            raise RuntimeError("no local models available to aggregate")
        width = arena.padded_params if arena.sharded else arena.num_params
        return secure_mod.secure_fedavg_arena(
            arena.buffer, rows, weights,
            num_params=width,
            base_seed=self._mask_session_seed(self._model_version),
            out_sharding=arena.row_sharding,
        )[: arena.num_params]

    # ------------------------------------------------------------ checkpoint
    def save_checkpoint(self, directory: str | None = None,
                        step: int | None = None) -> str:
        """Persist the full federation state for crash-consistent resume.

        One ``.npz`` via ``repro.checkpoint``: the global model (packed
        buffer + manifest), server-optimizer state, the store contents
        (arena arrays or stack records), and a JSON meta block carrying the
        round/version counters, per-learner versions and EWMA profiles, the
        journal cursor, and a telemetry snapshot.  The journal's file sink
        is flushed first, so the JSONL on disk covers everything up to the
        checkpoint.  Called by ``engine.run(checkpoint_every=k)`` at round
        boundaries; ``directory`` defaults to :attr:`checkpoint_dir`,
        ``step`` to the current :attr:`round_id`.  Returns the file path.
        """
        from repro.checkpoint import checkpoint as ckpt

        directory = directory if directory is not None else self.checkpoint_dir
        if directory is None:
            raise ValueError("save_checkpoint needs a directory "
                             "(or Controller(checkpoint_dir=...))")
        if self.global_params is None:
            raise RuntimeError("set_initial_model() before save_checkpoint()")
        self.journal.flush()
        step = self.round_id if step is None else int(step)
        leaves, _ = jax.tree_util.tree_flatten(self._server_state)
        extras: dict[str, Any] = {
            f"server_state_{i}": leaf for i, leaf in enumerate(leaves)
        }
        meta: dict[str, Any] = {
            "round_id": int(self.round_id),
            "model_version": int(self._model_version),
            "learner_versions": {
                k: int(v) for k, v in self._learner_versions.items()
            },
            "aggregates_fired": int(self.engine.aggregates_fired),
            "profiles": {
                lid: {
                    "decay": prof.decay,
                    "observations": prof.observations,
                    "rep_observations": prof.rep_observations,
                    "data": jsonable(dict(prof)),
                }
                for lid, prof in self._learner_profiles.items()
            },
            "deregistered_at": {
                k: int(v) for k, v in self._deregistered_at.items()
            },
            "late_carry": list(self.engine._late_carry),
            "journal_cursor": int(self.journal.cursor),
            "protocol": type(self.protocol).__name__,
            "store_mode": self.store_mode,
            "secure": bool(self.secure),
            "aggregation_rule": self.aggregation_rule,
            "admission": {
                "ewma": self._adm_ewma,
                "accepted": int(self._adm_accepted),
            },
            "offenses": {
                lid: [float(score), int(rnd)]
                for lid, (score, rnd) in self._offenses.items()
            },
            "quarantined": sorted(self._quarantined),
            "telemetry": self.telemetry.snapshot(),
        }
        if getattr(self.protocol, "continuous", False):
            meta["pending_buffer"] = list(self.engine._buffer)
        if self.engine._pending_dispatch is not None:
            meta["pending_dispatch"] = list(self.engine._pending_dispatch)
        if self.arena is not None:
            st = self.arena.export_state()
            extras["arena_buffer"] = st["buffer"]
            extras["arena_weights"] = st["weights"]
            extras["arena_versions"] = st["versions"]
            extras["arena_valid"] = st["valid"]
            if st.get("scales") is not None:
                extras["arena_scales"] = st["scales"]
            if st.get("indices") is not None:
                extras["arena_indices"] = st["indices"]
            meta["arena_rows"] = {k: int(v) for k, v in st["rows"].items()}
            meta["arena_dtype"] = self.arena_dtype
        elif self.store_mode == "stack":
            records = self.store.export_records()
            meta["stack_records"] = [
                {
                    "learner_id": rec.learner_id,
                    "round_id": int(rec.round_id),
                    "num_examples": int(rec.num_examples),
                    "metadata": jsonable(rec.metadata),
                }
                for rec in records
            ]
            for j, rec in enumerate(records):
                extras[f"stackbuf_{j}"] = rec.buffer
        if self._topk:
            # The learner-side error-feedback residuals are federation
            # state: dropping them at resume silently re-sends mass the
            # carry already accounted for.  The engine checkpoints at
            # round boundaries after draining outstanding tasks, so the
            # residuals are quiescent here.
            meta["sparse_mode"] = self.sparse_mode
            residual_learners = []
            for lid, learner in self._learners.items():
                res = learner.export_residual()
                if res is not None:
                    extras[f"residual__{lid}"] = res
                    residual_learners.append(lid)
            meta["residual_learners"] = residual_learners
        return ckpt.save_checkpoint(
            directory, step, self.global_params,
            extra_arrays=extras, metadata=meta,
        )

    def restore(self, directory: str | None = None,
                step: int | None = None) -> dict:
        """Resume from a checkpoint written by :meth:`save_checkpoint`.

        Call on a freshly constructed controller with the *same*
        configuration (protocol, store mode, secure flag — validated
        against the checkpoint) and the same learners already registered.
        Restores the global model, server-optimizer state, round/version
        counters, learner profiles, store contents and the journal cursor;
        the next ``engine.run`` continues the interrupted workflow and —
        at matching data/batch schedules — produces bit-identical global
        models (``tests/test_checkpoint_resume.py``).  ``step=None`` picks
        the latest checkpoint.  Returns the checkpoint's meta block.
        """
        from repro.checkpoint import checkpoint as ckpt

        directory = directory if directory is not None else self.checkpoint_dir
        if directory is None:
            raise ValueError("restore needs a directory "
                             "(or Controller(checkpoint_dir=...))")
        params, extras, meta = ckpt.restore_checkpoint(directory, step)
        for key, mine in (
            ("protocol", type(self.protocol).__name__),
            ("store_mode", self.store_mode),
            ("secure", bool(self.secure)),
            ("aggregation_rule", self.aggregation_rule),
            ("arena_dtype", self.arena_dtype),
            ("sparse_mode", self.sparse_mode),
        ):
            if key in meta and meta[key] != mine:
                raise ValueError(
                    f"checkpoint was written with {key}={meta[key]!r}; "
                    f"this controller has {key}={mine!r}"
                )
        self.set_initial_model(params)
        # Server-optimizer state: graft the saved leaves onto the structure
        # of the freshly initialized state (same optimizer config ⇒ same
        # treedef), preserving python-scalar leaves as their native type.
        fresh_leaves, treedef = jax.tree_util.tree_flatten(self._server_state)
        restored_leaves = []
        for i, fresh in enumerate(fresh_leaves):
            saved = extras[f"server_state_{i}"]
            if isinstance(fresh, (bool, int, float)) and not hasattr(
                fresh, "dtype"
            ):
                restored_leaves.append(type(fresh)(saved.item()))
            else:
                restored_leaves.append(jnp.asarray(saved))
        self._server_state = jax.tree_util.tree_unflatten(
            treedef, restored_leaves
        )
        self.round_id = int(meta["round_id"])
        self._model_version = int(meta["model_version"])
        self._g_version.set(self._model_version)
        self._learner_versions.update(
            {k: int(v) for k, v in meta.get("learner_versions", {}).items()}
        )
        self.engine.aggregates_fired = int(meta.get("aggregates_fired", 0))
        for lid, saved_prof in meta.get("profiles", {}).items():
            prof = LearnerProfile(decay=float(saved_prof["decay"]))
            prof.observations = int(saved_prof["observations"])
            prof.rep_observations = int(saved_prof.get("rep_observations", 0))
            prof.update(saved_prof.get("data", {}))
            self._learner_profiles[lid] = prof
        self._deregistered_at = {
            k: int(v) for k, v in meta.get("deregistered_at", {}).items()
        }
        adm = meta.get("admission") or {}
        ewma = adm.get("ewma")
        self._adm_ewma = None if ewma is None else float(ewma)
        self._adm_accepted = int(adm.get("accepted", 0))
        self._offenses = {
            lid: (float(score), int(rnd))
            for lid, (score, rnd) in meta.get("offenses", {}).items()
        }
        self._quarantined = set(meta.get("quarantined", []))
        self._g_quarantine.set(len(self.quarantined_ids()))
        self.engine._late_carry = list(meta.get("late_carry", []))
        self.engine._buffer = list(meta.get("pending_buffer", []))
        if "pending_dispatch" in meta:
            self.engine._resume_dispatch = list(meta["pending_dispatch"])
        if self.arena is not None and "arena_rows" in meta:
            self.arena.restore_state(
                buffer=extras["arena_buffer"],
                weights=extras["arena_weights"],
                versions=extras["arena_versions"],
                valid=extras["arena_valid"],
                rows=meta["arena_rows"],
                scales=extras.get("arena_scales"),
                indices=extras.get("arena_indices"),
            )
        elif self.store_mode == "stack" and "stack_records" in meta:
            self.store.restore_records([
                ModelRecord(
                    learner_id=rec["learner_id"],
                    round_id=int(rec["round_id"]),
                    buffer=jnp.asarray(extras[f"stackbuf_{j}"]),
                    num_examples=int(rec["num_examples"]),
                    metadata=dict(rec.get("metadata", {})),
                )
                for j, rec in enumerate(meta["stack_records"])
            ])
        for lid in meta.get("residual_learners", []):
            learner = self._learners.get(lid)
            if learner is not None:
                learner.restore_residual(extras[f"residual__{lid}"])
        self.invalidate_wire_cache()
        self.journal.seek(int(meta.get("journal_cursor", 0)))
        return meta

    # -------------------------------------------------------------- lifecycle
    def shutdown(self) -> None:
        """Stop the engine's dispatch executor (waits for in-flight tasks)."""
        self.engine.shutdown()
