"""The Federation Controller — first-class citizen of the system.

Implements the full controller lifecycle of paper Figs. 1/9/10 with the
re-engineered operations of §3:

* **async train dispatch** — RunTask is fire-and-forget through a thread-pool
  executor; the learner's completion callback (MarkTaskCompleted) inserts the
  local model into the :class:`ModelStore`.  The controller never blocks on a
  single learner while dispatching.
* **serialize-once broadcast dispatch** — the global model is serialized at
  most **once per model version** (``Channel.broadcast`` straight off the
  flat ``global_buffer``, manifest cached — never rebuilt per send) and
  fanned out as shared read-only envelopes, so per-round dispatch cost is
  O(P + N), independent of federation size at fixed payload.
* **measured upload fast path** — learners hold the manifest and the channel
  handle (shipped once at registration) and send the packed ``(P,)`` buffer
  through the channel's uplink half (``Channel.upload``, codec-encoded wire
  envelope with per-send byte/time accounting), so MarkTaskCompleted decodes
  straight into the arena row: zero pytree flattening and zero host
  concatenation on arrival, in both the sync round and the async
  community-update loop — and both wire directions show up in
  ``ChannelStats``.
* **sync eval dispatch** — EvaluateModel keeps the call open (paper Fig. 10).
* **packed aggregation** — local models are packed once at upload
  (``pack_numeric``) and aggregated as a fused ``(N, P)`` reduction
  (``core/aggregation``), optionally through the Pallas kernel or secure path.
* **device-resident arena** (``store_mode="arena"``, the default) — uploads
  are donated in-place row writes into a persistent ``(n_max, P)`` device
  buffer (``core/store.ArenaStore``) and every aggregation is a single masked
  reduction straight over that buffer: the hot path never re-stacks the
  ``(N, P)`` array or round-trips through the host.  ``store_mode="stack"``
  keeps the legacy per-upload-buffer + ``jnp.stack`` path for parity testing
  (``benchmarks/bench_agg.py --compare`` measures the difference).
* **mesh-sharded arena** (``arena_mesh=``) — the same arena column-sharded
  over a device mesh: row writes are ``shard_map``-ed shard-local updates and
  every protocol's reduction runs per shard with zero collectives, so the
  controller scales past one device's HBM without touching protocol code
  (``benchmarks/bench_agg.py --sharded`` measures it; ``docs/ARENA.md``
  documents the layout).
* **per-op timing** — the controller measures exactly the six operations the
  paper's stress test reports: train dispatch, train round, aggregation,
  eval dispatch, eval round, federation round.

Protocols: synchronous, semi-synchronous, asynchronous (``core/scheduler``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, packing
from repro.core.learner import EvalReport, Learner, LocalUpdate
from repro.core.scheduler import AsyncProtocol, SemiSyncProtocol, SyncProtocol, TrainTask
from repro.core.selection import SelectionPolicy, select_learners
from repro.core.server_opt import ServerOptimizer, make_server_optimizer
from repro.core.store import ArenaStore, ModelRecord, ModelStore
from repro.core.transport import Broadcast, Channel, get_upload_codec

__all__ = ["RoundTimings", "Controller"]


@dataclasses.dataclass
class RoundTimings:
    """The six per-operation wall-clock measurements of the paper's Figs 5-7."""

    round_id: int = -1
    train_dispatch_s: float = 0.0
    train_round_s: float = 0.0
    aggregation_s: float = 0.0
    eval_dispatch_s: float = 0.0
    eval_round_s: float = 0.0
    federation_round_s: float = 0.0
    metrics: dict = dataclasses.field(default_factory=dict)

    def as_row(self) -> dict:
        """Flatten to one dict row for the CSV/JSON benchmark output."""
        return {
            "round": self.round_id,
            "train_dispatch_s": self.train_dispatch_s,
            "train_round_s": self.train_round_s,
            "aggregation_s": self.aggregation_s,
            "eval_dispatch_s": self.eval_dispatch_s,
            "eval_round_s": self.eval_round_s,
            "federation_round_s": self.federation_round_s,
        }


AggregateFn = Callable[[jax.Array, jax.Array], jax.Array]


class Controller:
    """The federation controller.

    Parameters
    ----------
    protocol:
        Sync/SemiSync/Async protocol object (``core/scheduler``).
    aggregate_fn:
        ``(stack (N,P), weights (N,)) -> (P,)``.  Defaults to the fused
        FedAvg; swap in the Pallas kernel op or a robust rule.
    store_mode:
        ``"arena"`` (default) aggregates straight off the device-resident
        :class:`ArenaStore`; ``"stack"`` is the legacy re-stack path.
    masked_aggregate_fn:
        ``(arena (N_max,P), weights (N_max,), mask (N_max,)) -> (P,)`` — the
        arena-path rule.  Defaults to the fused masked FedAvg (or, if a
        custom ``aggregate_fn`` was given, to ``aggregate_fn`` with the mask
        folded into the weights — correct for the weighted-average family,
        not for order statistics like the median; pass an explicit masked
        rule for those).
    secure:
        If True, uploads are mask-encoded and the controller only sums
        (``core/secure``) — it never sees an individual model.
    arena_mesh:
        Optional :class:`jax.sharding.Mesh`.  When given (arena mode only),
        the persistent ``(n_max, P)`` arena is **column-sharded** over the
        mesh's data axis (``launch/mesh.make_controller_mesh`` builds a 1-D
        one over all local devices): uploads scatter once and write
        shard-locally, and every aggregation protocol — plain, staleness-
        weighted async, secure sum — reduces per shard with zero collectives.
        Numerics are identical to the single-device arena
        (``tests/test_arena_sharded.py``); see ``docs/ARENA.md``.
    arena_axes:
        Mesh axis name(s) to split ``P`` over (default: the ``"data"`` axis
        if the mesh has one, else every axis).
    flat_uploads:
        If True (default), every registered learner receives the model
        manifest (plus the arena row width and the channel handle) once at
        registration and sends its uploads through the measured uplink
        (``Channel.upload``) as codec-encoded wire envelopes, so
        ``_mark_task_completed`` never flattens a pytree
        (``upload_fallback_packs`` counts the times it had to).  False keeps
        the legacy pack-on-arrival path, for parity testing — those uploads
        still cross the measured uplink (the controller stands in for the
        learner's send half), so ``ChannelStats`` reconciles on every path.
    upload_codec:
        Uplink wire format: ``"raw"`` (default, bit-transparent f32 bytes)
        or ``"int8"`` (blockwise quantization, ~3.9x fewer uplink bytes), or
        a codec object (``core/transport.get_upload_codec``).  ``None``
        (default) keeps whatever the channel already uses; when set, it is
        installed on the controller's channel — including an explicitly
        passed ``channel=``, whose previous upload codec it replaces.
    """

    def __init__(
        self,
        protocol: SyncProtocol | SemiSyncProtocol | AsyncProtocol | None = None,
        selection: SelectionPolicy | None = None,
        aggregate_fn: AggregateFn | None = None,
        server_optimizer: ServerOptimizer | None = None,
        store: ModelStore | None = None,
        channel: Channel | None = None,
        secure: bool = False,
        max_dispatch_workers: int = 32,
        secure_seed: int = 0,
        store_mode: str = "arena",
        masked_aggregate_fn: Callable | None = None,
        arena_n_max: int = 8,
        arena_row_align: int = 1024,
        arena_mesh: Any = None,
        arena_axes: Any = None,
        flat_uploads: bool = True,
        upload_codec: Any = None,
    ):
        if store_mode not in ("arena", "stack"):
            raise ValueError(f"store_mode must be 'arena' or 'stack', got {store_mode!r}")
        if store is not None and store_mode == "arena":
            # An explicit hash-map store would be silently bypassed by the
            # arena hot path — refuse the contradiction instead.
            raise ValueError(
                "store= is only honoured with store_mode='stack'; the arena "
                "mode keeps uploads in its device-resident ArenaStore"
            )
        self.protocol = protocol or SyncProtocol()
        self.selection = selection or SelectionPolicy()
        self.aggregate_fn = aggregate_fn or aggregation.fedavg
        if masked_aggregate_fn is not None:
            self.masked_aggregate_fn = masked_aggregate_fn
        elif aggregate_fn is not None:
            self.masked_aggregate_fn = (
                lambda arena, w, m: aggregate_fn(arena, w * m)
            )
        else:
            self.masked_aggregate_fn = aggregation.masked_weighted_average
        self.server_opt = server_optimizer or make_server_optimizer("fedavg")
        self.store = store or ModelStore()
        self.store_mode = store_mode
        self.arena: ArenaStore | None = None
        self._arena_n_max = arena_n_max
        self._arena_row_align = arena_row_align
        self.arena_mesh = arena_mesh
        self.arena_axes = arena_axes
        if arena_mesh is not None and store_mode != "arena":
            raise ValueError("arena_mesh= requires store_mode='arena'")
        # Built lazily in set_initial_model when the arena is sharded.
        self._sharded_masked_fn: Callable | None = None
        self._sharded_staleness_fn: Callable | None = None
        self.channel = channel or Channel()
        if upload_codec is not None:
            self.channel.upload_codec = get_upload_codec(upload_codec)
        self.secure = secure
        self.secure_seed = secure_seed

        self._learners: dict[str, Learner] = {}
        self._learner_profiles: dict[str, dict] = {}
        self._executor = ThreadPoolExecutor(max_workers=max_dispatch_workers)
        self._store_lock = threading.Lock()

        self.global_params: Any = None
        self.global_buffer: jax.Array | None = None
        self.manifest: packing.Manifest | None = None
        self._server_state = None
        self.round_id = 0
        self.history: list[RoundTimings] = []
        # async protocol state
        self._model_version = 0
        self._learner_versions: dict[str, int] = {}
        # serialize-once dispatch state: one wire payload per model version
        self.flat_uploads = flat_uploads
        self._wire_lock = threading.Lock()
        self._wire_cache: tuple[tuple, Broadcast] | None = None
        # perf counters asserted by tests/test_dispatch.py: actual global-
        # model serializations triggered by dispatch, and the number of
        # uploads the controller had to flatten itself (0 on the fast path)
        self.dispatch_serializations = 0
        self.upload_fallback_packs = 0

    # ------------------------------------------------------------------ init
    def set_initial_model(self, params: Any) -> None:
        """Driver ships initial model tensors to the controller (Fig. 8).

        The controller's canonical model state is the flat numeric
        ``global_buffer`` + cached ``manifest``; ``global_params`` is
        normalized through one numeric roundtrip so the serialize-once
        broadcast (which reads the buffer) and the legacy per-send path
        (which reads the pytree) are bit-identical from round zero.
        """
        self.manifest = packing.build_manifest(params)
        self.global_buffer = packing.pack_numeric(params)
        self.global_params = packing.unpack_numeric(self.global_buffer, self.manifest)
        self._server_state = self.server_opt.init(self.global_buffer)
        with self._wire_lock:
            self._wire_cache = None
        if self.store_mode == "arena":
            self.arena = ArenaStore(
                num_params=max(1, int(self.global_buffer.shape[0])),
                n_max=max(self._arena_n_max, len(self._learners)),
                row_align=self._arena_row_align,
                mesh=self.arena_mesh,
                axes=self.arena_axes,
            )
            if self.arena.sharded:
                # Per-shard masked reductions over the column-sharded arena
                # (zero collectives; numerically identical to single-device).
                # A user-supplied masked rule is honoured as-is — it runs on
                # the sharded buffer with whatever layout XLA infers.
                self._sharded_masked_fn = aggregation.masked_fedavg_sharded(
                    self.arena.mesh, self.arena.axes
                )
                alpha = getattr(self.protocol, "staleness_alpha", 0.5)
                self._sharded_staleness_fn = aggregation.masked_staleness_sharded(
                    self.arena.mesh, self.arena.axes, alpha
                )
        for learner in self._learners.values():
            self._ship_manifest(learner)

    def _ship_manifest(self, learner: Learner) -> None:
        """Ship the wire contract (manifest + row width + channel) once.

        This is the flat-upload contract: with the manifest resident the
        learner packs its own uploads (padded to the arena row width) and —
        with the channel handle — sends them through the measured uplink
        (``Channel.upload``), so arrival is a codec decode plus a straight
        arena row write.  No-op until the initial model exists or when
        ``flat_uploads=False``.
        """
        if not self.flat_uploads or self.manifest is None:
            return
        pad_to = self.arena.padded_params if self.arena is not None else None
        learner.accept_manifest(self.manifest, pad_to=pad_to, channel=self.channel)

    def register_learner(self, learner: Learner) -> None:
        """Admit a learner to the federation (paper Fig. 8 join)."""
        self._learners[learner.learner_id] = learner
        self._learner_profiles[learner.learner_id] = {}
        self._learner_versions[learner.learner_id] = 0
        self._ship_manifest(learner)

    @property
    def learner_ids(self) -> list[str]:
        """IDs of every registered learner, in registration order."""
        return list(self._learners)

    # -------------------------------------------------------------- dispatch
    def _broadcast(self) -> Broadcast:
        """The current model's shared wire payload, serialized at most once.

        Cached per (model version, codec): every dispatch within one version
        — train fan-out, eval fan-out, async re-dispatches between community
        updates — reuses the same read-only byte buffer, and the bytes come
        straight off ``global_buffer`` with the cached manifest (no pytree
        flattening, no manifest rebuild).  Aggregation bumps the version,
        which invalidates the cache on the next dispatch.
        """
        key = (self._model_version, id(self.channel.codec))
        with self._wire_lock:
            if self._wire_cache is None or self._wire_cache[0] != key:
                bc = self.channel.broadcast(
                    params=self.global_params,
                    buffer=self.global_buffer,
                    manifest=self.manifest,
                )
                self.dispatch_serializations += 1
                self._wire_cache = (key, bc)
            return self._wire_cache[1]

    def _dispatch_train(self, selected: Sequence[str]) -> tuple[list[Future], float]:
        """Asynchronous RunTask dispatch: serialize the model **once** for the
        whole cohort, fan out per-recipient envelopes, submit, collect Acks.
        Returns completion futures + dispatch time."""
        t0 = time.perf_counter()
        broadcast = self._broadcast()
        futures = []
        for lid in selected:
            task = self.protocol.make_task(self.round_id, self._learner_profiles[lid])
            envelope = broadcast.to({"task": task})

            def run(lid=lid, task=task, envelope=envelope) -> LocalUpdate:
                learner = self._learners[lid]
                params = self.channel.recv(envelope)
                update = learner.fit(params, task)
                self._mark_task_completed(update)
                return update

            futures.append(self._executor.submit(run))
        dispatch_s = time.perf_counter() - t0
        return futures, dispatch_s

    def _upload_buffer(self, update: LocalUpdate, pad_to: int | None) -> jax.Array:
        """The upload's decoded flat buffer, always off the measured uplink.

        Fast path: the learner already sent its packed row through
        ``Channel.upload`` and the update carries the wire envelope — decode
        it (one ``device_put`` + jitted codec decode).  Legacy paths (a bare
        pre-packed buffer, or ``flat_uploads=False`` where the controller
        must flatten the pytree itself — counted in ``upload_fallback_packs``)
        still cross the same measured half, with the controller standing in
        for the learner's send: every upload on every protocol is encoded,
        byte-accounted, and decoded through the channel's upload codec.
        """
        if update.upload is not None:
            return self.channel.recv_upload(update.upload)
        buffer = update.buffer
        if buffer is None:
            with self._store_lock:  # completions run on concurrent executor threads
                self.upload_fallback_packs += 1
            buffer = packing.pack_numeric(update.params, pad_to=pad_to)
        envelope = self.channel.upload(
            buffer, metadata={"learner_id": update.learner_id,
                              "round_id": update.round_id},
        )
        return self.channel.recv_upload(envelope)

    def _mark_task_completed(self, update: LocalUpdate) -> None:
        """MarkTaskCompleted: decode the upload off the wire, insert in store.

        Fast path (``flat_uploads``): the learner already packed its params
        at the arena's padded row width and sent them through the measured
        uplink, so arena mode is a codec decode plus a straight donated row
        write — zero pytree flattening, zero host concatenation on arrival.
        Otherwise the controller packs here (the legacy path, counted in
        ``upload_fallback_packs``) and routes the buffer through the same
        measured half.  Stack mode inserts the decoded buffer into the
        hash-map store either way.
        """
        if self.store_mode == "arena":
            buffer = self._upload_buffer(update, pad_to=self.arena.padded_params)
            self.arena.write(
                update.learner_id,
                buffer,
                weight=float(update.num_examples),
                version=float(self._learner_versions.get(update.learner_id, 0)),
            )
            with self._store_lock:
                prof = self._learner_profiles[update.learner_id]
                prof["seconds_per_step"] = update.seconds_per_step
            return
        buffer = self._upload_buffer(update, pad_to=None)
        with self._store_lock:
            self.store.insert(
                ModelRecord(
                    learner_id=update.learner_id,
                    round_id=update.round_id,
                    buffer=buffer,
                    num_examples=update.num_examples,
                    metadata={
                        **update.metrics,
                        "seconds_per_step": update.seconds_per_step,
                        "model_version": self._learner_versions.get(update.learner_id, 0),
                    },
                )
            )
            prof = self._learner_profiles[update.learner_id]
            prof["seconds_per_step"] = update.seconds_per_step

    # ------------------------------------------------------------- aggregate
    def _aggregate(self, selected: Sequence[str]) -> tuple[jax.Array, float]:
        """Select + aggregate stored local models (paper T4-T7).

        Arena mode: one masked reduction straight over the persistent device
        buffer — row writes already happened at arrival, so the round's
        critical path is just the reduce.  Stack mode: re-stack the stored
        buffers into an ``(N, P)`` array first (the legacy O(N·P) host copy).
        """
        t0 = time.perf_counter()
        if self.store_mode == "arena":
            new_buffer = self._aggregate_arena(selected)
        else:
            with self._store_lock:
                records = self.store.select_latest(list(selected))
            if not records:
                raise RuntimeError("no local models available to aggregate")

            if self.secure:
                from repro.core import secure as secure_mod

                buffers = [r.buffer for r in records]
                weights = [float(r.num_examples) for r in records]
                new_buffer = secure_mod.secure_fedavg(
                    buffers, weights, base_seed=self.secure_seed + self.round_id
                )
            else:
                stack = jnp.stack([r.buffer for r in records], axis=0)
                weights = jnp.asarray(
                    [float(r.num_examples) for r in records], jnp.float32
                )
                new_buffer = self.aggregate_fn(stack, weights)

        # server-side optimization on the packed buffer
        self._server_state, new_buffer = self.server_opt.apply(
            self._server_state, self.global_buffer, new_buffer
        )
        new_buffer = jax.block_until_ready(new_buffer)
        agg_s = time.perf_counter() - t0

        self.global_buffer = new_buffer
        self.global_params = packing.unpack_numeric(new_buffer, self.manifest)
        self._model_version += 1
        return new_buffer, agg_s

    def _aggregate_arena(self, selected: Sequence[str]) -> jax.Array:
        """Masked reduction over the arena restricted to the round's cohort."""
        arena = self.arena
        with arena.lock:
            if self.secure:
                from repro.core import secure as secure_mod

                rows, weights = [], []
                for lid in selected:
                    if lid in arena:
                        rows.append(arena.row_of(lid))
                        weights.append(arena.weight_of(lid))
                if not rows:
                    raise RuntimeError("no local models available to aggregate")
                # Sharded arena: sum the full padded width — padded_params is
                # divisible by n_shards by construction, so the column-sharded
                # int32 accumulator always engages (pairwise pads cancel
                # exactly whatever the width, and padding columns decode to
                # zero, so the [:num_params] slice is bit-identical to the
                # unpadded single-device sum).
                width = arena.padded_params if arena.sharded else arena.num_params
                return secure_mod.secure_fedavg_arena(
                    arena.buffer, rows, weights,
                    num_params=width,
                    base_seed=self.secure_seed + self.round_id,
                    out_sharding=arena.row_sharding,
                )[: arena.num_params]
            # Empty-cohort check from the arena's host-side row map: probing
            # the device mask (float(jnp.sum(mask))) would force a blocking
            # device round-trip onto every round's critical path.
            if arena.num_valid(list(selected)) == 0:
                raise RuntimeError("no local models available to aggregate")
            mask = arena.round_mask(list(selected))
            if self._sharded_masked_fn is not None and (
                self.masked_aggregate_fn is aggregation.masked_weighted_average
            ):
                out = self._sharded_masked_fn(arena.buffer, arena.weights, mask)
            else:
                out = self.masked_aggregate_fn(arena.buffer, arena.weights, mask)
            return out[: arena.num_params]

    # ------------------------------------------------------------ eval round
    def _evaluate(self, selected: Sequence[str]) -> tuple[list[EvalReport], float, float]:
        """Synchronous EvaluateModel fan-out (paper Fig. 10, T7-T9).

        Shares the post-aggregation model's single serialization with the
        next round's train dispatch (both read the same version's broadcast).
        """
        t0 = time.perf_counter()
        broadcast = self._broadcast()
        futures = []
        for lid in selected:
            envelope = broadcast.to({"eval": True})

            def run(lid=lid, envelope=envelope) -> EvalReport:
                params = self.channel.recv(envelope)
                return self._learners[lid].evaluate(params, self.round_id)

            futures.append(self._executor.submit(run))
        dispatch_s = time.perf_counter() - t0
        reports = [f.result() for f in futures]
        round_s = time.perf_counter() - t0
        return reports, dispatch_s, round_s

    # -------------------------------------------------------- round drivers
    def run_round(self) -> RoundTimings:
        """One synchronous/semi-synchronous federation round (paper T1-T9)."""
        if self.global_params is None:
            raise RuntimeError("set_initial_model() before running rounds")
        timings = RoundTimings(round_id=self.round_id)
        t_round = time.perf_counter()

        selected = select_learners(
            self.selection,
            self.learner_ids,
            self.round_id,
            {lid: l.num_examples for lid, l in self._learners.items()},
        )
        for lid in selected:
            self._learner_versions[lid] = self._model_version

        # training round: async dispatch, barrier on completion callbacks
        t_train = time.perf_counter()
        futures, timings.train_dispatch_s = self._dispatch_train(selected)
        wait(futures)
        for f in futures:
            f.result()  # surface learner exceptions
        timings.train_round_s = time.perf_counter() - t_train

        # aggregation
        _, timings.aggregation_s = self._aggregate(selected)

        # evaluation round
        reports, timings.eval_dispatch_s, timings.eval_round_s = self._evaluate(selected)
        timings.metrics = self._reduce_eval(reports)

        timings.federation_round_s = time.perf_counter() - t_round
        self.history.append(timings)
        self.round_id += 1
        return timings

    def run_async(self, total_updates: int) -> list[RoundTimings]:
        """Asynchronous protocol: aggregate on every arrival, staleness-weighted.

        Every completed local task immediately triggers a community update
        (the paper's asynchronous 'community update request'); dispatch of the
        next task to that learner follows at once.
        """
        if not isinstance(self.protocol, AsyncProtocol):
            raise TypeError("run_async requires AsyncProtocol")
        if self.global_params is None:
            raise RuntimeError("set_initial_model() before running rounds")

        alpha = self.protocol.staleness_alpha
        done = threading.Event()
        completed = 0
        completed_lock = threading.Lock()
        out: list[RoundTimings] = []

        def community_update(update: LocalUpdate) -> None:
            nonlocal completed
            timings = RoundTimings(round_id=self.round_id)
            t0 = time.perf_counter()
            if self.store_mode == "arena":
                # Staleness-weighted masked reduction straight off the arena:
                # the arrival that triggered this update was already written
                # in place by _mark_task_completed, so there is no per-arrival
                # stack rebuild — the paper's "community update request" cost
                # is one fused kernel regardless of federation size.
                arena = self.arena
                with arena.lock:
                    if self._sharded_staleness_fn is not None:
                        new_buffer = self._sharded_staleness_fn(
                            arena.buffer, arena.weights, arena.versions,
                            jnp.float32(self._model_version), arena.mask,
                        )[: arena.num_params]
                    else:
                        new_buffer = aggregation.masked_staleness_average(
                            arena.buffer, arena.weights, arena.versions,
                            jnp.float32(self._model_version), arena.mask, alpha,
                        )[: arena.num_params]
            else:
                with self._store_lock:
                    records = self.store.select_latest(None)  # all known models
                    stal = jnp.asarray(
                        [self._model_version - r.metadata.get("model_version", 0)
                         for r in records],
                        jnp.float32,
                    )
                    n_ex = jnp.asarray(
                        [float(r.num_examples) for r in records], jnp.float32
                    )
                    stack = jnp.stack([r.buffer for r in records], axis=0)
                w = aggregation.staleness_weights(n_ex, stal, alpha)
                new_buffer = self.aggregate_fn(stack, w)
            self._server_state, new_buffer = self.server_opt.apply(
                self._server_state, self.global_buffer, new_buffer
            )
            self.global_buffer = jax.block_until_ready(new_buffer)
            self.global_params = packing.unpack_numeric(new_buffer, self.manifest)
            self._model_version += 1
            timings.aggregation_s = time.perf_counter() - t0
            timings.federation_round_s = timings.aggregation_s
            out.append(timings)
            self.history.append(timings)
            self.round_id += 1
            with completed_lock:
                completed += 1
                if completed >= total_updates:
                    done.set()

        def dispatch_to(lid: str) -> None:
            task = self.protocol.make_task(self.round_id, self._learner_profiles[lid])
            self._learner_versions[lid] = self._model_version
            # Learners dispatched between two community updates share one
            # serialization (the broadcast is cached per model version).
            envelope = self._broadcast().to({"task": task})

            def run() -> None:
                params = self.channel.recv(envelope)
                update = self._learners[lid].fit(params, task)
                self._mark_task_completed(update)
                community_update(update)
                with completed_lock:
                    more = completed < total_updates
                if more and not done.is_set():
                    dispatch_to(lid)

            self._executor.submit(run)

        for lid in self.learner_ids:
            dispatch_to(lid)
        done.wait()
        return out

    # -------------------------------------------------------------- helpers
    @staticmethod
    def _reduce_eval(reports: list[EvalReport]) -> dict:
        if not reports:
            return {}
        keys = reports[0].metrics.keys()
        total = sum(r.num_examples for r in reports)
        return {
            k: sum(r.metrics[k] * r.num_examples for r in reports) / max(total, 1)
            for k in keys
        }

    def shutdown(self) -> None:
        """Stop the dispatch executor (waits for in-flight tasks)."""
        self._executor.shutdown(wait=True)
