"""Simulated transport layer with measured (de)serialization and byte counts.

MetisFL moves models between controller and learners over gRPC as flat byte
buffers.  This repo has no RPC runtime (DESIGN.md §2), so the transport is an
in-process channel that performs the *real* serialization work
(``core/packing.pack_bytes``), counts bytes, and optionally accounts virtual
wire time from a bandwidth/latency model — so benchmarks can separate compute
cost from modeled network cost without sleeping.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core import packing

__all__ = ["ChannelStats", "Channel", "Envelope"]


@dataclasses.dataclass
class ChannelStats:
    """Cumulative transport accounting for one channel."""

    messages: int = 0
    bytes_moved: int = 0
    serialize_s: float = 0.0
    deserialize_s: float = 0.0
    virtual_wire_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class Envelope:
    """One message on the wire: byte buffer + manifest + metadata."""

    buffer: np.ndarray
    manifest: packing.Manifest
    metadata: dict


class Channel:
    """A measured point-to-point channel (controller <-> learner).

    ``bandwidth_gbps``/``latency_ms`` feed the *virtual* wire-time account;
    they never block real execution.  ``quantize_codec`` optionally compresses
    the payload (beyond-paper int8 transport, ``kernels/quantize``).
    """

    def __init__(
        self,
        bandwidth_gbps: float = 10.0,
        latency_ms: float = 0.5,
        quantize_codec: Any | None = None,
    ):
        self.bandwidth_gbps = bandwidth_gbps
        self.latency_ms = latency_ms
        self.codec = quantize_codec
        self.stats = ChannelStats()

    def send(self, params: Any, metadata: dict | None = None) -> Envelope:
        """Serialize a pytree for the wire (the sender half)."""
        t0 = time.perf_counter()
        if self.codec is not None:
            params = self.codec.encode(params)
        buf, manifest = packing.pack_bytes(params)
        dt = time.perf_counter() - t0
        self.stats.messages += 1
        self.stats.bytes_moved += int(buf.nbytes)
        self.stats.serialize_s += dt
        self.stats.virtual_wire_s += (
            self.latency_ms / 1e3 + buf.nbytes * 8 / (self.bandwidth_gbps * 1e9)
        )
        return Envelope(buffer=buf, manifest=manifest, metadata=dict(metadata or {}))

    def recv(self, envelope: Envelope) -> Any:
        """Deserialize at the receiver half."""
        t0 = time.perf_counter()
        params = packing.unpack_bytes(envelope.buffer, envelope.manifest)
        if self.codec is not None:
            params = self.codec.decode(params)
        self.stats.deserialize_s += time.perf_counter() - t0
        return params
