"""Simulated transport layer with measured (de)serialization and byte counts.

MetisFL moves models between controller and learners over gRPC as flat byte
buffers.  This repo has no RPC runtime (DESIGN.md §2), so the transport is an
in-process channel that performs the *real* serialization work
(``core/packing.pack_bytes``), counts bytes, and optionally accounts virtual
wire time from a bandwidth/latency model — so benchmarks can separate compute
cost from modeled network cost without sleeping.

Two send paths exist:

* :meth:`Channel.send` — the legacy point-to-point half: one serialization per
  recipient (kept for parity testing and single-recipient messages).
* :meth:`Channel.broadcast` — the fan-out half: serialize **once** into a
  shared read-only byte buffer, then stamp per-recipient envelopes with
  :meth:`Broadcast.to`.  Each ``to()`` charges that recipient's bytes and
  virtual wire time but never re-serializes, so dispatch cost is
  O(P + N) instead of O(N·P).  When the caller already maintains the flat
  numeric buffer (the controller's ``global_buffer``), the wire bytes come
  straight off it (``packing.pack_bytes_from_numeric``) — no pytree walk at
  all.

All stats mutation is lock-guarded: the controller's async protocol calls
``send``/``recv``/``Broadcast.to`` concurrently from executor threads.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

import numpy as np

from repro.core import packing

__all__ = ["ChannelStats", "Channel", "Envelope", "Broadcast"]


@dataclasses.dataclass
class ChannelStats:
    """Cumulative transport accounting for one channel.

    ``messages``/``bytes_moved``/``virtual_wire_s`` count per *recipient*
    (a broadcast to N learners counts N); ``serializations``/``serialize_s``
    count actual serialization work (the same broadcast counts 1).  Mutated
    only by :class:`Channel` under its stats lock — safe to read from tests
    after joining worker threads.
    """

    messages: int = 0
    bytes_moved: int = 0
    serializations: int = 0
    serialize_s: float = 0.0
    deserialize_s: float = 0.0
    virtual_wire_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class Envelope:
    """One message on the wire: byte buffer + manifest + metadata.

    Envelopes minted by :meth:`Broadcast.to` share one read-only buffer and
    manifest across all recipients; only ``metadata`` is per-recipient.
    """

    buffer: np.ndarray
    manifest: packing.Manifest
    metadata: dict


class Broadcast:
    """One serialized payload fanned out to many recipients.

    Created by :meth:`Channel.broadcast`.  The byte buffer and manifest are
    serialized exactly once and shared read-only; :meth:`to` mints a
    per-recipient :class:`Envelope` and charges that recipient's bytes and
    virtual wire time on the owning channel.  Thread-safe: ``to`` may be
    called concurrently from dispatch executor threads.
    """

    def __init__(
        self,
        channel: "Channel",
        buffer: np.ndarray,
        manifest: packing.Manifest,
        metadata: dict,
    ):
        try:
            buffer.flags.writeable = False  # shared across recipients
        except ValueError:
            pass  # already a read-only view (e.g. of a jax host buffer)
        self._channel = channel
        self.buffer = buffer
        self.manifest = manifest
        self._metadata = metadata
        self._lock = threading.Lock()
        self.recipients = 0

    def to(self, metadata: dict | None = None) -> Envelope:
        """Mint one recipient's envelope: shared bytes, fresh metadata.

        Per-recipient accounting (message count, bytes, virtual wire time)
        happens here; serialization happened once, at broadcast creation.
        """
        md = dict(self._metadata)
        if metadata:
            md.update(metadata)
        self._channel._account_send(int(self.buffer.nbytes))
        with self._lock:
            self.recipients += 1
        return Envelope(buffer=self.buffer, manifest=self.manifest, metadata=md)


class Channel:
    """A measured point-to-point channel (controller <-> learner).

    ``bandwidth_gbps``/``latency_ms`` feed the *virtual* wire-time account;
    they never block real execution.  ``quantize_codec`` optionally compresses
    the payload (beyond-paper int8 transport, ``kernels/quantize``).
    """

    def __init__(
        self,
        bandwidth_gbps: float = 10.0,
        latency_ms: float = 0.5,
        quantize_codec: Any | None = None,
    ):
        self.bandwidth_gbps = bandwidth_gbps
        self.latency_ms = latency_ms
        self.codec = quantize_codec
        self.stats = ChannelStats()
        self._stats_lock = threading.Lock()

    # -- accounting ---------------------------------------------------------
    def _wire_time(self, nbytes: int) -> float:
        return self.latency_ms / 1e3 + nbytes * 8 / (self.bandwidth_gbps * 1e9)

    def _account_send(self, nbytes: int) -> None:
        with self._stats_lock:
            self.stats.messages += 1
            self.stats.bytes_moved += nbytes
            self.stats.virtual_wire_s += self._wire_time(nbytes)

    def _account_serialize(self, dt: float) -> None:
        with self._stats_lock:
            self.stats.serializations += 1
            self.stats.serialize_s += dt

    # -- send halves --------------------------------------------------------
    def send(self, params: Any, metadata: dict | None = None) -> Envelope:
        """Serialize a pytree for one recipient (the legacy per-send half)."""
        t0 = time.perf_counter()
        if self.codec is not None:
            params = self.codec.encode(params)
        buf, manifest = packing.pack_bytes(params)
        self._account_serialize(time.perf_counter() - t0)
        self._account_send(int(buf.nbytes))
        return Envelope(buffer=buf, manifest=manifest, metadata=dict(metadata or {}))

    def broadcast(
        self,
        params: Any = None,
        metadata: dict | None = None,
        *,
        buffer: Any = None,
        manifest: packing.Manifest | None = None,
    ) -> Broadcast:
        """Serialize **once** for a fan-out; recipients pay only wire time.

        With ``buffer=``/``manifest=`` (the controller's flat numeric
        ``global_buffer`` plus its cached manifest) and no codec, the wire
        bytes come straight off the flat buffer
        (``packing.pack_bytes_from_numeric``) — zero pytree flattening.
        Otherwise falls back to ``pack_bytes(params)`` (the codec, when set,
        is applied to ``params``) — still exactly one serialization.

        Per-recipient byte/wire-time accounting happens at each
        :meth:`Broadcast.to`; this call accounts only the serialization.
        """
        t0 = time.perf_counter()
        if buffer is not None and manifest is not None and self.codec is None:
            wire = packing.pack_bytes_from_numeric(buffer, manifest)
            m = manifest
        else:
            src = params if self.codec is None else self.codec.encode(params)
            wire, m = packing.pack_bytes(src)
        self._account_serialize(time.perf_counter() - t0)
        return Broadcast(self, wire, m, dict(metadata or {}))

    # -- receive ------------------------------------------------------------
    def recv(self, envelope: Envelope) -> Any:
        """Deserialize at the receiver half."""
        t0 = time.perf_counter()
        params = packing.unpack_bytes(envelope.buffer, envelope.manifest)
        if self.codec is not None:
            params = self.codec.decode(params)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.stats.deserialize_s += dt
        return params
