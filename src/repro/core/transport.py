"""Simulated transport layer with measured (de)serialization and byte counts.

MetisFL moves models between controller and learners over gRPC as flat byte
buffers.  This repo has no RPC runtime (DESIGN.md §2), so the transport is an
in-process channel that performs the *real* serialization work
(``core/packing.pack_bytes``), counts bytes, and optionally accounts virtual
wire time from a bandwidth/latency model — so benchmarks can separate compute
cost from modeled network cost without sleeping.

The channel is **full duplex** — both wire directions are measured:

* :meth:`Channel.send` — the legacy point-to-point downlink half: one
  serialization per recipient (kept for parity testing and single-recipient
  messages).
* :meth:`Channel.broadcast` — the downlink fan-out half: serialize **once**
  into a shared read-only byte buffer, then stamp per-recipient envelopes
  with :meth:`Broadcast.to`.  Each ``to()`` charges that recipient's bytes
  and virtual wire time but never re-serializes, so dispatch cost is
  O(P + N) instead of O(N·P).  When the caller already maintains the flat
  numeric buffer (the controller's ``global_buffer``), the wire bytes come
  straight off it (``packing.pack_bytes_from_numeric``) — no pytree walk at
  all.
* :meth:`Channel.upload` / :meth:`Channel.recv_upload` — the **uplink** half.
  A learner's flat ``(P,)`` update buffer is encoded through a pluggable
  upload codec (``raw`` passthrough — 4 bytes/param; ``int8`` blockwise
  quantization via ``kernels/quantize`` — ~3.9x fewer wire bytes) into an
  :class:`UploadEnvelope`, with per-send byte/time accounting; the controller
  decodes it back to a device-resident row with one ``device_put`` plus a
  jitted bitcast/dequant program, ready for a straight arena row write.
  Uplink is the dominant wire direction (N uploads vs 1 broadcast per round),
  so this is where the codec pays off.

All stats mutation is lock-guarded: the controller's async protocol calls
``send``/``recv``/``upload``/``recv_upload``/``Broadcast.to`` concurrently
from executor threads.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.metrics import Telemetry

__all__ = [
    "ChannelStats", "Channel", "Envelope", "Broadcast",
    "UploadEnvelope", "RawUploadCodec", "Int8UploadCodec",
    "TopkUploadCodec", "UPLOAD_CODECS", "get_upload_codec",
]


#: The channel's telemetry counter names (registered as ``channel.<field>``).
_STAT_FIELDS = (
    "messages", "bytes_moved", "serializations", "serialize_s",
    "deserialize_s", "virtual_wire_s", "upload_messages", "upload_bytes",
    "upload_meta_bytes", "upload_serializations", "upload_serialize_s",
    "upload_deserialize_s", "upload_virtual_wire_s",
)


class ChannelStats:
    """Transport accounting for one channel — a **view** over its telemetry.

    Deprecated read shim: every field that used to be a dataclass attribute
    is now a property reading the ``channel.<field>`` counter from the
    channel's :class:`~repro.core.metrics.Telemetry` registry, so existing
    call sites (``ch.stats.upload_bytes``) keep working while the registry
    (``controller.telemetry`` / ``channel.telemetry``) is the documented
    surface.

    Downlink (controller → learners): ``messages``/``bytes_moved``/
    ``virtual_wire_s`` count per *recipient* (a broadcast to N learners
    counts N); ``serializations``/``serialize_s`` count actual serialization
    work (the same broadcast counts 1).

    Uplink (learners → controller): ``upload_messages``/``upload_bytes``/
    ``upload_virtual_wire_s`` count one per :meth:`Channel.upload`
    (``upload_bytes`` is the codec *payload*; the envelope's serialized
    header — codec id, element count, metadata, codec params — is counted
    separately in ``upload_meta_bytes``, and virtual wire time covers
    both, so the accounting is envelope-exact even for variable-length
    sparse payloads);
    ``upload_serializations``/``upload_serialize_s`` count the codec encode
    work and ``upload_deserialize_s`` the controller-side decode.  Every
    upload is its own serialization (no fan-in sharing), so
    ``upload_messages == upload_serializations`` always.

    Counters are mutated only by :class:`Channel` under its stats lock —
    safe to read from tests after joining worker threads.
    """

    def __init__(self, telemetry: Telemetry | None = None):
        self._telemetry = telemetry if telemetry is not None else Telemetry()

    @property
    def total_bytes(self) -> int:
        """Bytes moved across both wire directions (downlink + uplink)."""
        return self.bytes_moved + self.upload_bytes

    @property
    def total_virtual_wire_s(self) -> float:
        """Modeled wire time across both directions."""
        return self.virtual_wire_s + self.upload_virtual_wire_s

    def __repr__(self) -> str:
        fields = ", ".join(f"{f}={getattr(self, f)!r}" for f in _STAT_FIELDS)
        return f"ChannelStats({fields})"


def _stats_view_property(field: str) -> property:
    """Build one deprecated ChannelStats read property over ``channel.<field>``."""

    def _get(self: ChannelStats):
        return self._telemetry.value(f"channel.{field}", 0)

    _get.__name__ = field
    _get.__doc__ = (
        f"Deprecated shim for ``telemetry.value('channel.{field}')``."
    )
    return property(_get)


for _field in _STAT_FIELDS:
    setattr(ChannelStats, _field, _stats_view_property(_field))
del _field


# ---------------------------------------------------------------------------
# Upload codecs (uplink wire formats)
# ---------------------------------------------------------------------------


class RawUploadCodec:
    """Passthrough upload codec: f32 row bytes on the wire (4 bytes/param).

    Bit-transparent: ``decode(encode(x)) == x`` for any float32 buffer, so
    protocols that assert bit-identical parity run through it unchanged.
    """

    codec_id = "raw"

    def wire_params(self) -> dict:
        """Codec parameters a receiver needs to decode (none for raw)."""
        return {}

    def wire_nbytes(self, num_elements: int) -> int:
        """Modeled wire payload size for a buffer of ``num_elements``."""
        return 4 * int(num_elements)

    def encode(self, buffer: Any) -> np.ndarray:
        """Flat ``(P,)`` numeric buffer → its f32 wire bytes (one copy)."""
        return packing.pack_row_bytes(buffer, jnp.float32)

    def decode(self, payload: np.ndarray, num_elements: int) -> jax.Array:
        """Wire bytes → device-resident f32 ``(P,)`` row (one transfer)."""
        return packing.unpack_row_bytes(payload, num_elements, "float32")

    def decode_with_norm(
        self, payload: np.ndarray, num_elements: int
    ) -> tuple[jax.Array, jax.Array]:
        """Decode + L2 norm in one jitted device program (no host sync).

        The admission-screen fast path: the norm comes back as a device
        scalar enqueued behind the decode, so the controller's only host
        sync per upload is reading the already-materialized float.
        """
        if int(np.size(payload)) != 4 * int(num_elements):
            raise ValueError(
                f"row payload holds {int(np.size(payload))} bytes, expected "
                f"{4 * int(num_elements)} for {num_elements} float32 elements"
            )
        dev = jnp.asarray(np.ascontiguousarray(payload))
        return _raw_decode_norm(dev, int(num_elements))


@functools.partial(jax.jit, static_argnames=("num_elements",))
def _raw_decode_norm(wire: jax.Array, num_elements: int):
    """One jitted program: bitcast the raw f32 wire bytes + its L2 norm."""
    row = jax.lax.bitcast_convert_type(
        wire.reshape(num_elements, 4), jnp.float32
    ).reshape(num_elements)
    return row, jnp.linalg.norm(row)


@jax.jit
def _row_norm(row: jax.Array) -> jax.Array:
    """Device-side L2 norm of a decoded row (fallback for custom codecs)."""
    return jnp.linalg.norm(row.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("n_q", "n_scales", "n_groups"))
def _split_quant_wire(wire: jax.Array, n_q: int, n_scales: int, n_groups: int):
    """Device-side split of one int8 upload payload into (q int8, scales f32).

    Compiled once per wire layout and cached — together with the jitted
    ``kernels/ops.dequantize`` this makes the controller's int8 ingest a
    single ``device_put`` plus device-only bitcasts and the dequant kernel,
    mirroring the downlink's one-transfer ``unpack_bytes`` design.

    The wire carries only the ``n_scales = ceil(n/group)`` informative
    scales (``kernels/quantize.wire_layout`` trims pure-padding groups); the
    remaining ``n_groups - n_scales`` trailing groups are re-synthesized
    here as exactly 1.0 — the quantize kernel's zero-amax fallback — so the
    round-trip stays bit-identical to an untrimmed wire.
    """
    q = jax.lax.bitcast_convert_type(jax.lax.slice(wire, (0,), (n_q,)), jnp.int8)
    sb = jax.lax.slice(wire, (n_q,), (n_q + 4 * n_scales,))
    scales = jax.lax.bitcast_convert_type(sb.reshape(n_scales, 4), jnp.float32)
    scales = scales.reshape(n_scales)
    if n_groups > n_scales:
        pad = jnp.ones((n_groups - n_scales,), jnp.float32)
        scales = jnp.concatenate([scales, pad])
    return q, scales


@functools.partial(
    jax.jit,
    static_argnames=("n_q", "n_scales", "num_elements", "group", "block_rows"),
)
def _int8_decode_norm(wire, n_q, n_scales, num_elements, group, block_rows):
    """One jitted program: split + re-pad + dequantize + L2 norm.

    The int8 statement of :func:`_raw_decode_norm`: the whole decode and the
    admission norm compile into a single cached executable per wire layout,
    so ingest enqueues one device program and never blocks.
    """
    from repro.kernels import ops as kops
    from repro.kernels import quantize as quant

    q, scales = _split_quant_wire(wire, n_q, n_scales, n_q // group)
    row = quant.dequantize_pallas(
        q, scales, group, block_rows, interpret=kops.INTERPRET
    )[:num_elements]
    return row, jnp.linalg.norm(row)


@functools.partial(
    jax.jit, static_argnames=("n_q", "n_scales", "out_params", "group")
)
def _decode_quant_resident(wire, n_q, n_scales, out_params, group):
    """Land one int8 upload in quantized form: (q int8, scales f32, norm).

    The quantized-resident arena's ingest program: split the wire, re-pad
    the trimmed scales, slice to the arena row width — **no f32 (P,) row is
    ever materialized**.  The admission norm is computed from the quantized
    form directly, ``sqrt(Σ_g scale_g² · Σ_i q_{g,i}²)``, which equals the
    L2 norm of the dequantized row exactly (dequantization is a per-group
    scalar multiply), so screening decisions match the f32 path bit-for-bit
    up to f32 summation order.
    """
    q, scales = _split_quant_wire(wire, n_q, n_scales, n_q // group)
    q = jax.lax.slice(q, (0,), (out_params,))
    scales = jax.lax.slice(scales, (0,), (out_params // group,))
    qf = q.astype(jnp.float32).reshape(out_params // group, group)
    norm = jnp.sqrt(jnp.sum(scales * scales * jnp.sum(qf * qf, axis=1)))
    return q, scales, norm


class Int8UploadCodec:
    """Blockwise-int8 upload codec (``kernels/quantize``): ~3.9x fewer bytes.

    Encode runs the jitted Pallas quantize kernel over the learner's flat
    ``(P,)`` buffer (symmetric per-group scales, group a multiple of 128 so
    VPU lanes stay full) and concatenates ``int8`` values + ``f32`` scales
    into one wire payload.  The kernel block height adapts to the buffer
    (``kernels/quantize.effective_block_rows``): buffers under one tile pad
    zero rows and larger buffers pad at most ~6.25% of their rows, so the
    compression ratio is ≈3.94x at block-aligned sizes and never drops below
    ~3.6x once P reaches one group — there is no size band where the pad to
    the next whole tile halves the saving.  Decode is one ``device_put`` of the
    payload, a jitted bitcast split, and the Pallas dequant kernel — the
    decoded f32 row is ready for a straight arena row write with zero
    host-side numeric work.  Lossy to the int8 step (~0.4% relative); use
    ``raw`` where bit-identity matters.
    """

    codec_id = "int8"

    def __init__(self, group: int | None = None, block_rows: int | None = None):
        from repro.kernels import quantize as quant

        self.group = int(group or quant.DEFAULT_GROUP)
        self.block_rows = int(block_rows or quant.DEFAULT_BLOCK_ROWS)

    def wire_params(self) -> dict:
        """Codec parameters the receiver needs to derive the wire layout."""
        return {"group": self.group, "block_rows": self.block_rows}

    def wire_nbytes(self, num_elements: int) -> int:
        """Modeled wire payload size: int8 values + f32 scales."""
        from repro.kernels import quantize as quant

        return quant.wire_layout(int(num_elements), self.group, self.block_rows)[2]

    def encode(self, buffer: Any) -> np.ndarray:
        """Quantize a flat ``(P,)`` buffer into int8 values + f32 scales.

        Only the ``ceil(P/group)`` informative scales go on the wire
        (``wire_layout``); trailing pure-padding groups carry ``q == 0``
        with scale exactly 1.0, which the decoder re-synthesizes from ``P``
        alone, so trimming them is lossless *and* byte-exact.
        """
        from repro.kernels import ops, quantize as quant

        flat = jnp.asarray(buffer, jnp.float32).reshape(-1)
        q, scales = ops.quantize(
            flat, group=self.group,
            block_rows=quant.effective_block_rows(
                flat.shape[0], self.group, self.block_rows
            ),
        )
        n_scales = quant.wire_layout(
            int(flat.shape[0]), self.group, self.block_rows
        )[1]
        qb = np.asarray(q).view(np.uint8).reshape(-1)
        sb = np.asarray(scales)[:n_scales].view(np.uint8).reshape(-1)
        out = np.empty((qb.size + sb.size,), np.uint8)
        out[: qb.size] = qb
        out[qb.size:] = sb
        return out

    def _checked_layout(
        self, payload: np.ndarray, num_elements: int
    ) -> tuple[int, int]:
        """Validate payload size against the wire layout; return (n_q, n_scales)."""
        from repro.kernels import quantize as quant

        n_q, n_scales, nbytes = quant.wire_layout(
            num_elements, self.group, self.block_rows
        )
        if int(payload.size) != nbytes:
            raise ValueError(
                f"int8 payload holds {int(payload.size)} bytes, expected "
                f"{nbytes} for {num_elements} elements"
            )
        return n_q, n_scales

    def decode(self, payload: np.ndarray, num_elements: int) -> jax.Array:
        """Dequantize an int8 payload back to the f32 ``(P,)`` row."""
        from repro.kernels import ops, quantize as quant

        n_q, n_scales = self._checked_layout(payload, num_elements)
        dev = jnp.asarray(np.ascontiguousarray(payload))
        q, scales = _split_quant_wire(dev, n_q, n_scales, n_q // self.group)
        return ops.dequantize(
            q, scales, num_elements, group=self.group,
            block_rows=quant.effective_block_rows(
                num_elements, self.group, self.block_rows
            ),
        )

    def decode_with_norm(
        self, payload: np.ndarray, num_elements: int
    ) -> tuple[jax.Array, jax.Array]:
        """Decode + L2 norm in one jitted device program (no host sync).

        Same contract as :meth:`RawUploadCodec.decode_with_norm`: one
        ``device_put``, one cached executable, norm as a device scalar.
        """
        from repro.kernels import quantize as quant

        n_q, n_scales = self._checked_layout(payload, num_elements)
        dev = jnp.asarray(np.ascontiguousarray(payload))
        return _int8_decode_norm(
            dev, n_q, n_scales, int(num_elements), self.group,
            quant.effective_block_rows(
                int(num_elements), self.group, self.block_rows
            ),
        )

    def decode_quantized(
        self, payload: np.ndarray, num_elements: int, out_params: int
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Decode an int8 payload straight into arena-resident quantized form.

        Returns ``(q int8 (out_params,), scales f32 (out_params//group,),
        norm)`` from one jitted program — no intermediate f32 ``(P,)`` row.
        ``out_params`` (the arena's padded row width) must be a multiple of
        ``group`` and at most the payload's padded element count.
        """
        n_q, n_scales = self._checked_layout(payload, num_elements)
        out_params = int(out_params)
        if out_params % self.group or out_params > n_q:
            raise ValueError(
                f"out_params={out_params} must be a multiple of "
                f"group={self.group} and <= the payload's {n_q} padded "
                "elements"
            )
        dev = jnp.asarray(np.ascontiguousarray(payload))
        return _decode_quant_resident(dev, n_q, n_scales, out_params, self.group)


@functools.partial(
    jax.jit, static_argnames=("k_eff", "n_scales", "group", "value_dtype")
)
def _split_topk_wire(wire, k_eff, n_scales, group, value_dtype):
    """Device-side split of one topk payload into (idx int32, val f32, norm).

    One cached executable per wire layout: bitcast the int32 index block,
    bitcast (f32 values) or bitcast + dequantize (int8-grouped values) the
    value block, and fuse the sparse L2 norm.  Top-k indices are unique
    within one upload, so ``‖val‖₂`` **is** the L2 norm of the densified
    row — the admission screen reads the same scalar the dense codecs
    produce, without ever materializing the ``(P,)`` row.
    """
    from repro.kernels import topk as topk_kernels

    idx = jax.lax.bitcast_convert_type(
        jax.lax.slice(wire, (0,), (4 * k_eff,)).reshape(k_eff, 4), jnp.int32
    ).reshape(k_eff)
    if value_dtype == "f32":
        vb = jax.lax.slice(wire, (4 * k_eff,), (8 * k_eff,))
        val = jax.lax.bitcast_convert_type(
            vb.reshape(k_eff, 4), jnp.float32
        ).reshape(k_eff)
    else:
        q = jax.lax.bitcast_convert_type(
            jax.lax.slice(wire, (4 * k_eff,), (5 * k_eff,)), jnp.int8
        )
        sb = jax.lax.slice(wire, (5 * k_eff,), (5 * k_eff + 4 * n_scales,))
        scales = jax.lax.bitcast_convert_type(
            sb.reshape(n_scales, 4), jnp.float32
        ).reshape(n_scales)
        val = topk_kernels.dequantize_values(q, scales, group)
    return idx, val, jnp.linalg.norm(val)


@functools.partial(
    jax.jit,
    static_argnames=("k_eff", "n_scales", "group", "value_dtype",
                     "num_elements"),
)
def _topk_decode_norm(wire, k_eff, n_scales, group, value_dtype, num_elements):
    """One jitted program: split + densify into a ``(P,)`` delta row + norm.

    The densify fallback for consumers that need a dense row (the
    ``densify`` sparse mode, the stack store, median/trimmed_mean);
    the direct sparse path never calls this.
    """
    idx, val, norm = _split_topk_wire(wire, k_eff, n_scales, group, value_dtype)
    row = jnp.zeros((num_elements,), jnp.float32).at[idx].add(val)
    return row, norm


class TopkUploadCodec:
    """Magnitude top-k upload codec (``kernels/topk``): the 10-100x regime.

    Encodes the ``k`` largest-|x| coordinates of the learner's flat ``(P,)``
    **delta** buffer as ``(indices:int32, values:f32|int8-grouped)`` — at
    ``k = P/64`` with f32 values the payload is ``P/8`` bytes, 32x below
    raw and ~8x below int8.  Lossy per upload by construction; the learner's
    error-feedback residual (``core/learner.py``) carries the unsent mass
    forward, so the scheme is unbiased over rounds.  ``k`` clamps per
    buffer to ``[1, P]`` (tiny layers ship everything they have) while the
    envelope's ``codec_params`` stay constant — ``k_eff`` is re-derived
    from ``num_elements`` on the decode side, so variable-length payloads
    need no extra wire state.

    Unlike ``raw``/``int8`` this codec moves *deltas*, not parameters: the
    decoded row is the learner's sparsified update against the model it
    received, and the controller adds the aggregated delta onto the global
    buffer at commit.
    """

    codec_id = "topk"

    def __init__(
        self, k: int = 64, value_dtype: str = "f32",
        group: int | None = None,
    ):
        from repro.kernels import topk as topk_kernels

        self.k = int(k)
        if self.k < 1:
            raise ValueError(f"topk codec needs k >= 1, got {k!r}")
        if value_dtype not in topk_kernels.VALUE_DTYPES:
            raise ValueError(
                f"value_dtype must be one of {topk_kernels.VALUE_DTYPES}, "
                f"got {value_dtype!r}"
            )
        self.value_dtype = str(value_dtype)
        self.group = int(group or topk_kernels.DEFAULT_VALUE_GROUP)
        if self.group < 1:
            raise ValueError(f"topk codec needs group >= 1, got {group!r}")

    def wire_params(self) -> dict:
        """Codec parameters the receiver needs to derive the wire layout."""
        return {
            "k": self.k, "value_dtype": self.value_dtype, "group": self.group,
        }

    def wire_nbytes(self, num_elements: int) -> int:
        """Modeled wire payload size: int32 indices + (f32|int8+scale) values."""
        from repro.kernels import topk as topk_kernels

        return topk_kernels.wire_layout_topk(
            int(num_elements), self.k, self.value_dtype, self.group
        )[2]

    def encode(self, buffer: Any) -> np.ndarray:
        """Select top-k by magnitude and pack ``(indices, values)`` bytes."""
        from repro.kernels import topk as topk_kernels

        flat = jnp.asarray(buffer, jnp.float32).reshape(-1)
        k_eff = topk_kernels.effective_k(int(flat.shape[0]), self.k)
        idx, val = topk_kernels.topk_select(flat, k_eff)
        parts = [np.asarray(idx).view(np.uint8).reshape(-1)]
        if self.value_dtype == "f32":
            parts.append(np.asarray(val).view(np.uint8).reshape(-1))
        else:
            q, scales = topk_kernels.quantize_values(val, self.group)
            parts.append(np.asarray(q).view(np.uint8).reshape(-1))
            parts.append(np.asarray(scales).view(np.uint8).reshape(-1))
        return np.concatenate(parts)

    def _checked_layout(
        self, payload: np.ndarray, num_elements: int
    ) -> tuple[int, int]:
        """Validate payload size against the layout; return (k_eff, n_scales)."""
        from repro.kernels import topk as topk_kernels

        k_eff, n_scales, nbytes = topk_kernels.wire_layout_topk(
            int(num_elements), self.k, self.value_dtype, self.group
        )
        if int(payload.size) != nbytes:
            raise ValueError(
                f"topk payload holds {int(payload.size)} bytes, expected "
                f"{nbytes} for {num_elements} elements at k={self.k}"
            )
        return k_eff, n_scales

    def unpack_coords(
        self, payload: np.ndarray, num_elements: int
    ) -> tuple[jax.Array, jax.Array]:
        """Wire bytes → ``(indices int32, values f32)`` device pair.

        The learner-side half of the error-feedback subtraction: values
        come back *dequantized*, i.e. exactly what the controller will
        see, so ``residual -= sent`` carries the quantization error too.
        """
        k_eff, n_scales = self._checked_layout(payload, num_elements)
        dev = jnp.asarray(np.ascontiguousarray(payload))
        idx, val, _ = _split_topk_wire(
            dev, k_eff, n_scales, self.group, self.value_dtype
        )
        return idx, val

    def decode(self, payload: np.ndarray, num_elements: int) -> jax.Array:
        """Densify a sparse payload into the f32 ``(P,)`` delta row."""
        return self.decode_with_norm(payload, num_elements)[0]

    def decode_with_norm(
        self, payload: np.ndarray, num_elements: int
    ) -> tuple[jax.Array, jax.Array]:
        """Densify + L2 norm in one jitted device program (no host sync)."""
        k_eff, n_scales = self._checked_layout(payload, num_elements)
        dev = jnp.asarray(np.ascontiguousarray(payload))
        return _topk_decode_norm(
            dev, k_eff, n_scales, self.group, self.value_dtype,
            int(num_elements),
        )

    def decode_sparse(
        self, payload: np.ndarray, num_elements: int
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Decode to sparse ``(indices, values, norm)`` — no densification.

        The direct sparse arena's ingest half: one ``device_put`` plus one
        cached split program; the norm is the sparse L2 (== the dense
        row's norm, indices being unique) as an unread device scalar.
        """
        k_eff, n_scales = self._checked_layout(payload, num_elements)
        dev = jnp.asarray(np.ascontiguousarray(payload))
        return _split_topk_wire(
            dev, k_eff, n_scales, self.group, self.value_dtype
        )


UPLOAD_CODECS = {
    "raw": RawUploadCodec, "int8": Int8UploadCodec, "topk": TopkUploadCodec,
}


def _codec_params(codec: Any) -> dict:
    """The codec's self-describing wire parameters ({} if it declares none)."""
    wire_params = getattr(codec, "wire_params", None)
    return wire_params() if wire_params is not None else {}


def get_upload_codec(spec: Any) -> Any:
    """Resolve an upload codec: a registry id (``"raw"``/``"int8"``), an
    already-constructed codec object, or ``None`` (→ raw).

    A codec object must declare a string ``codec_id`` (stamped on every
    envelope).  Note that envelopes of codecs *outside* the registry can only
    be decoded by a channel configured with an equivalent codec — see
    :class:`UploadEnvelope` for the exact contract.
    """
    if spec is None:
        return RawUploadCodec()
    if isinstance(spec, str):
        try:
            return UPLOAD_CODECS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown upload codec {spec!r}; known: {sorted(UPLOAD_CODECS)}"
            ) from None
    if not isinstance(getattr(spec, "codec_id", None), str):
        raise ValueError(
            "an upload codec object must define a string `codec_id` "
            f"attribute; got {type(spec).__name__}"
        )
    return spec


@dataclasses.dataclass(frozen=True)
class UploadEnvelope:
    """One learner→controller message on the wire.

    ``payload`` is the codec's byte buffer (read-only); ``codec`` names the
    encoding and ``codec_params`` carries its layout parameters (e.g. the
    int8 group/block sizes); ``num_elements`` is the logical ``(P,)`` length
    the payload decodes to (codec-internal padding is derivable from it).
    Envelopes of **registry** codecs (``UPLOAD_CODECS``: raw, int8) are fully
    self-describing — any channel decodes them with no out-of-band state.  An
    envelope minted by a custom codec *object* decodes only on a channel
    whose configured codec has the same ``codec_id`` and wire params (the
    registry cannot reconstruct a class it does not know).
    """

    codec: str
    payload: np.ndarray
    num_elements: int
    metadata: dict
    codec_params: dict = dataclasses.field(default_factory=dict)

    @property
    def meta_nbytes(self) -> int:
        """Serialized size of the envelope header (everything but payload).

        Canonical JSON (sorted keys, no whitespace) over the codec id,
        element count, metadata and codec params — the bytes a real RPC
        framing would spend on the envelope around the payload.  Counted
        in ``channel.upload_meta_bytes`` so uplink accounting reconciles
        envelope-exactly even when payload sizes vary per upload.
        """
        return len(json.dumps(
            {
                "codec": self.codec,
                "num_elements": int(self.num_elements),
                "metadata": self.metadata,
                "codec_params": self.codec_params,
            },
            sort_keys=True, separators=(",", ":"), default=str,
        ).encode("utf-8"))

    @property
    def wire_nbytes(self) -> int:
        """Total uplink bytes this envelope occupies: payload + header."""
        return int(self.payload.nbytes) + self.meta_nbytes


@dataclasses.dataclass(frozen=True)
class Envelope:
    """One message on the wire: byte buffer + manifest + metadata.

    Envelopes minted by :meth:`Broadcast.to` share one read-only buffer and
    manifest across all recipients; only ``metadata`` is per-recipient.
    """

    buffer: np.ndarray
    manifest: packing.Manifest
    metadata: dict


class Broadcast:
    """One serialized payload fanned out to many recipients.

    Created by :meth:`Channel.broadcast`.  The byte buffer and manifest are
    serialized exactly once and shared read-only; :meth:`to` mints a
    per-recipient :class:`Envelope` and charges that recipient's bytes and
    virtual wire time on the owning channel.  Thread-safe: ``to`` may be
    called concurrently from dispatch executor threads.
    """

    def __init__(
        self,
        channel: "Channel",
        buffer: np.ndarray,
        manifest: packing.Manifest,
        metadata: dict,
    ):
        try:
            buffer.flags.writeable = False  # shared across recipients
        except ValueError:
            pass  # already a read-only view (e.g. of a jax host buffer)
        self._channel = channel
        self.buffer = buffer
        self.manifest = manifest
        self._metadata = metadata
        self._lock = threading.Lock()
        self.recipients = 0

    def to(self, metadata: dict | None = None) -> Envelope:
        """Mint one recipient's envelope: shared bytes, fresh metadata.

        Per-recipient accounting (message count, bytes, virtual wire time)
        happens here; serialization happened once, at broadcast creation.
        """
        md = dict(self._metadata)
        if metadata:
            md.update(metadata)
        self._channel._account_send(
            int(self.buffer.nbytes), md.get("learner_id")
        )
        with self._lock:
            self.recipients += 1
        return Envelope(buffer=self.buffer, manifest=self.manifest, metadata=md)


class Channel:
    """A measured full-duplex channel (controller <-> learner).

    ``bandwidth_gbps``/``latency_ms`` feed the *virtual* wire-time account;
    they never block real execution.  ``quantize_codec`` optionally compresses
    the downlink pytree payload (beyond-paper int8 transport,
    ``kernels/quantize``); ``upload_codec`` selects the uplink wire format for
    flat ``(P,)`` update buffers (``"raw"`` default, ``"int8"`` blockwise
    quantization, or a codec object).

    All wire accounting lives as ``channel.*`` counters in ``telemetry``
    (the channel's own :class:`~repro.core.metrics.Telemetry` registry by
    default; the controller adopts it as ``controller.telemetry``).
    ``stats`` is the deprecated :class:`ChannelStats` read view over the
    same counters.
    """

    def __init__(
        self,
        bandwidth_gbps: float = 10.0,
        latency_ms: float = 0.5,
        quantize_codec: Any | None = None,
        upload_codec: Any = "raw",
        telemetry: Telemetry | None = None,
    ):
        self.bandwidth_gbps = bandwidth_gbps
        self.latency_ms = latency_ms
        self.learner_bandwidth_gbps: dict[str, float] = {}
        self.codec = quantize_codec
        self.upload_codec = get_upload_codec(upload_codec)
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._c = {
            f: self.telemetry.counter(f"channel.{f}") for f in _STAT_FIELDS
        }
        self.stats = ChannelStats(self.telemetry)
        self._stats_lock = threading.Lock()

    def set_learner_bandwidth(self, learner_id: str, gbps: float) -> None:
        """Cap one learner's modeled bandwidth (both wire halves).

        Sends and uploads stamped with that ``learner_id`` charge virtual
        wire time against the per-learner cap instead of the channel-wide
        ``bandwidth_gbps``; the stress harness uses this to model
        heterogeneous last-mile links.  Idempotent; purely virtual.
        """
        if gbps <= 0:
            raise ValueError(f"bandwidth cap must be positive, got {gbps}")
        self.learner_bandwidth_gbps[learner_id] = float(gbps)

    # -- accounting ---------------------------------------------------------
    def _wire_time(self, nbytes: int, learner_id: str | None = None) -> float:
        gbps = self.learner_bandwidth_gbps.get(learner_id, self.bandwidth_gbps)
        return self.latency_ms / 1e3 + nbytes * 8 / (gbps * 1e9)

    def round_trip_s(
        self, down_nbytes: int, up_nbytes: int,
        learner_id: str | None = None,
    ) -> float:
        """Modeled round-trip wire time for one dispatch + one upload.

        The per-learner estimate the wire-cost-aware semi-sync sizing
        consumes (``Controller.wire_time_s``): the downlink broadcast
        envelope and the uplink codec payload each pay the channel's
        latency plus their serialization time at the modeled bandwidth.
        Purely virtual — it never sleeps, exactly like the per-send
        ``ChannelStats`` accounting it mirrors.
        """
        return (self._wire_time(int(down_nbytes), learner_id)
                + self._wire_time(int(up_nbytes), learner_id))

    def _account_send(self, nbytes: int, learner_id: str | None = None) -> None:
        with self._stats_lock:
            self._c["messages"].add(1)
            self._c["bytes_moved"].add(nbytes)
            self._c["virtual_wire_s"].add(self._wire_time(nbytes, learner_id))

    def _account_serialize(self, dt: float) -> None:
        with self._stats_lock:
            self._c["serializations"].add(1)
            self._c["serialize_s"].add(dt)

    # -- send halves --------------------------------------------------------
    def send(self, params: Any, metadata: dict | None = None) -> Envelope:
        """Serialize a pytree for one recipient (the legacy per-send half)."""
        t0 = time.perf_counter()
        if self.codec is not None:
            params = self.codec.encode(params)
        buf, manifest = packing.pack_bytes(params)
        self._account_serialize(time.perf_counter() - t0)
        self._account_send(int(buf.nbytes))
        return Envelope(buffer=buf, manifest=manifest, metadata=dict(metadata or {}))

    def broadcast(
        self,
        params: Any = None,
        metadata: dict | None = None,
        *,
        buffer: Any = None,
        manifest: packing.Manifest | None = None,
    ) -> Broadcast:
        """Serialize **once** for a fan-out; recipients pay only wire time.

        With ``buffer=``/``manifest=`` (the controller's flat numeric
        ``global_buffer`` plus its cached manifest) and no codec, the wire
        bytes come straight off the flat buffer
        (``packing.pack_bytes_from_numeric``) — zero pytree flattening.
        Otherwise falls back to ``pack_bytes(params)`` (the codec, when set,
        is applied to ``params``) — still exactly one serialization.

        Per-recipient byte/wire-time accounting happens at each
        :meth:`Broadcast.to`; this call accounts only the serialization.
        """
        t0 = time.perf_counter()
        if buffer is not None and manifest is not None and self.codec is None:
            wire = packing.pack_bytes_from_numeric(buffer, manifest)
            m = manifest
        else:
            src = params if self.codec is None else self.codec.encode(params)
            wire, m = packing.pack_bytes(src)
        self._account_serialize(time.perf_counter() - t0)
        return Broadcast(self, wire, m, dict(metadata or {}))

    # -- receive ------------------------------------------------------------
    def recv(self, envelope: Envelope) -> Any:
        """Deserialize at the receiver half."""
        t0 = time.perf_counter()
        params = packing.unpack_bytes(envelope.buffer, envelope.manifest)
        if self.codec is not None:
            params = self.codec.decode(params)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self._c["deserialize_s"].add(dt)
        return params

    # -- upload half (learner -> controller) --------------------------------
    def _resolve_upload_codec(self, envelope: UploadEnvelope) -> Any:
        # The channel's own codec decodes its own envelopes; anything else is
        # reconstructed from the envelope's self-describing codec id + params.
        own = self.upload_codec
        if (envelope.codec == own.codec_id
                and envelope.codec_params == _codec_params(own)):
            return own
        try:
            cls = UPLOAD_CODECS[envelope.codec]
        except KeyError:
            raise ValueError(
                f"cannot decode upload codec {envelope.codec!r}; "
                f"known: {sorted(UPLOAD_CODECS)}"
            ) from None
        return cls(**envelope.codec_params)

    def upload(
        self, buffer: Any, metadata: dict | None = None, codec: Any = None
    ) -> UploadEnvelope:
        """Learner half of the uplink: encode one flat ``(P,)`` update buffer.

        The buffer is encoded through the channel's upload codec (or an
        explicit ``codec=`` override) into a wire payload; encode time is
        accounted as upload serialization work and the envelope's bytes and
        virtual wire time are charged per send, under the stats lock (the
        async protocol uploads concurrently from executor threads).
        Accounting is **envelope-exact**: ``upload_bytes`` counts this
        payload's actual size (variable-length codecs like ``topk`` differ
        per upload when k clamps at tiny buffers) and ``upload_meta_bytes``
        the serialized envelope header; virtual wire time covers both.
        """
        c = self.upload_codec if codec is None else get_upload_codec(codec)
        n = int(np.shape(buffer)[0])
        t0 = time.perf_counter()
        payload = c.encode(buffer)
        dt = time.perf_counter() - t0
        payload.flags.writeable = False  # wire bytes are immutable
        envelope = UploadEnvelope(
            codec=c.codec_id, payload=payload, num_elements=n,
            metadata=dict(metadata or {}), codec_params=_codec_params(c),
        )
        nbytes = int(payload.nbytes)
        meta_nbytes = envelope.meta_nbytes
        with self._stats_lock:
            self._c["upload_serializations"].add(1)
            self._c["upload_serialize_s"].add(dt)
            self._c["upload_messages"].add(1)
            self._c["upload_bytes"].add(nbytes)
            self._c["upload_meta_bytes"].add(meta_nbytes)
            self._c["upload_virtual_wire_s"].add(
                self._wire_time(
                    nbytes + meta_nbytes, (metadata or {}).get("learner_id")
                )
            )
        return envelope

    def recv_upload(
        self, envelope: UploadEnvelope, with_norm: bool = False
    ) -> jax.Array | tuple[jax.Array, jax.Array]:
        """Controller half of the uplink: decode wire bytes to a device row.

        One ``device_put`` of the payload plus a jitted decode program cached
        per wire layout (bitcast for ``raw``, bitcast split + Pallas dequant
        for ``int8``) — the returned f32 ``(P,)`` row feeds a straight arena
        row write with zero host-side numeric work.

        With ``with_norm=True`` returns ``(row, norm)`` where ``norm`` is the
        row's L2 norm as a **device scalar** fused into (or enqueued behind)
        the decode program — the admission screen's non-blocking readback.
        Registry codecs fuse it into the decode executable; a custom codec
        without ``decode_with_norm`` pays one extra enqueued jit, still with
        zero host syncs.
        """
        c = self._resolve_upload_codec(envelope)
        t0 = time.perf_counter()
        if with_norm:
            fused = getattr(c, "decode_with_norm", None)
            if fused is not None:
                row, norm = fused(envelope.payload, envelope.num_elements)
            else:
                row = c.decode(envelope.payload, envelope.num_elements)
                norm = _row_norm(row)
        else:
            row = c.decode(envelope.payload, envelope.num_elements)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self._c["upload_deserialize_s"].add(dt)
        return (row, norm) if with_norm else row

    def recv_upload_quantized(
        self, envelope: UploadEnvelope, out_params: int
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Decode an int8 upload straight into arena-resident quantized form.

        Returns ``(q int8 (out_params,), scales f32 (out_params//group,),
        norm)`` — the quantized-resident arena's ingest half: one
        ``device_put`` plus one jitted split/slice/norm program, with **no**
        intermediate f32 ``(P,)`` materialization and the admission norm as
        a device scalar.  Only valid for envelopes whose codec decodes to
        the int8 wire format; accounted as upload deserialization work like
        :meth:`recv_upload`.
        """
        c = self._resolve_upload_codec(envelope)
        decode_q = getattr(c, "decode_quantized", None)
        if decode_q is None:
            raise ValueError(
                f"codec {envelope.codec!r} cannot land quantized rows; "
                "use recv_upload for f32 decode"
            )
        t0 = time.perf_counter()
        q, scales, norm = decode_q(
            envelope.payload, envelope.num_elements, out_params
        )
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self._c["upload_deserialize_s"].add(dt)
        return q, scales, norm

    def recv_upload_sparse(
        self, envelope: UploadEnvelope
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Decode a topk upload in sparse form — densification never happens.

        Returns ``(indices int32 (k,), values f32 (k,), norm)`` — the
        direct sparse arena's ingest half: one ``device_put`` plus one
        cached split program, with the admission norm fused as a device
        scalar (top-k indices are unique, so the sparse L2 equals the
        dense row's norm — the same single-host-readback contract as
        :meth:`recv_upload` with ``with_norm=True``).  Only valid for
        envelopes whose codec declares ``decode_sparse``; accounted as
        upload deserialization work like :meth:`recv_upload`.
        """
        c = self._resolve_upload_codec(envelope)
        decode_s = getattr(c, "decode_sparse", None)
        if decode_s is None:
            raise ValueError(
                f"codec {envelope.codec!r} cannot land sparse rows; "
                "use recv_upload for dense decode"
            )
        t0 = time.perf_counter()
        idx, val, norm = decode_s(envelope.payload, envelope.num_elements)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self._c["upload_deserialize_s"].add(dt)
        return idx, val, norm
