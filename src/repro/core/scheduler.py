"""Federation round protocols: synchronous, semi-synchronous, asynchronous.

MetisFL is the only system in the paper's Table 1 supporting all three
communication protocols.  The protocol decides (a) how many local steps each
selected learner runs before uploading, and (b) when the controller
aggregates:

* **synchronous** — every selected learner runs the same number of local
  epochs/steps; the controller aggregates when *all* uploads arrive
  (paper's stress-test setting, FedAvg).
* **semi-synchronous** (Stripelis et al. 2022b) — learners train for a fixed
  wall-clock hyper-period; fast learners do more steps.  The controller still
  aggregates a full cohort, but stragglers never stall the round because the
  *time* budget, not the step budget, is fixed.
* **asynchronous** — the controller aggregates on *every* arrival, weighting
  by staleness (``core/aggregation.staleness_weights``); there is no round
  barrier.
"""

from __future__ import annotations

import dataclasses

__all__ = ["SyncProtocol", "SemiSyncProtocol", "AsyncProtocol", "TrainTask"]


@dataclasses.dataclass(frozen=True)
class TrainTask:
    """The unit the controller dispatches to a learner (paper's RunTask)."""

    round_id: int
    local_steps: int
    batch_size: int
    learning_rate: float
    # FedProx proximal coefficient; 0 disables the proximal term.
    prox_mu: float = 0.0
    metadata: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class SyncProtocol:
    """Synchronous rounds: same step budget for every selected learner,
    aggregate when the whole cohort has uploaded (paper's FedAvg setting)."""

    local_steps: int = 1
    batch_size: int = 100
    learning_rate: float = 0.01

    def make_task(self, round_id: int, learner_profile: dict | None = None) -> TrainTask:
        """Build the fixed-step TrainTask for this round."""
        return TrainTask(
            round_id=round_id,
            local_steps=self.local_steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
        )


@dataclasses.dataclass(frozen=True)
class SemiSyncProtocol:
    """Fixed hyper-period: per-learner step count derived from measured speed.

    ``hyperperiod_s`` is the wall-clock training budget per round.  The
    controller keeps a moving estimate of each learner's seconds-per-step
    (from MarkTaskCompleted metadata) and assigns
    ``steps_i = max(1, floor(hyperperiod / spstep_i))``.
    """

    hyperperiod_s: float = 1.0
    batch_size: int = 100
    learning_rate: float = 0.01
    default_steps: int = 1

    def make_task(self, round_id: int, learner_profile: dict | None = None) -> TrainTask:
        """Size the task from the learner's measured seconds-per-step."""
        steps = self.default_steps
        if learner_profile and learner_profile.get("seconds_per_step", 0) > 0:
            steps = max(1, int(self.hyperperiod_s / learner_profile["seconds_per_step"]))
        return TrainTask(
            round_id=round_id,
            local_steps=steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            metadata={"semi_sync": True},
        )


@dataclasses.dataclass(frozen=True)
class AsyncProtocol:
    """Asynchronous protocol: no round barrier — the controller aggregates on
    every arrival, staleness-damped by ``staleness_alpha``
    (``core/aggregation.staleness_weights``; semantics in docs/PROTOCOLS.md)."""

    local_steps: int = 1
    batch_size: int = 100
    learning_rate: float = 0.01
    staleness_alpha: float = 0.5

    def make_task(self, round_id: int, learner_profile: dict | None = None) -> TrainTask:
        """Build the TrainTask for the learner's next async leg."""
        return TrainTask(
            round_id=round_id,
            local_steps=self.local_steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            metadata={"async": True},
        )
