"""Federation protocols as pluggable policies for the round engine.

MetisFL is the only system in the paper's Table 1 supporting all three
communication protocols.  In this reproduction a protocol is not a hard-coded
loop: it is a **policy object** the event-driven round engine
(``core/engine.py``) consults at four decision points:

* :meth:`ProtocolPolicy.select_cohort` — who receives a task this round;
* :meth:`ProtocolPolicy.size_task` — how much local work each selected
  learner is assigned (wire-cost aware for semi-sync: the hyper-period
  budget covers *train + round-trip wire* time);
* :meth:`ProtocolPolicy.should_aggregate` — when the engine fires an
  aggregation (`AggregateFired`): on the full cohort for round-based
  protocols, on **every** arrival for the asynchronous one;
* :meth:`ProtocolPolicy.weighting` — how arena rows are weighted at the
  reduce (plain FedAvg vs staleness-damped).

The three concrete policies:

* **synchronous** (:class:`SyncProtocol`) — every selected learner runs the
  same number of local steps; aggregate when *all* uploads arrive (paper's
  stress-test setting, FedAvg).
* **semi-synchronous** (:class:`SemiSyncProtocol`, Stripelis et al. 2022b) —
  learners train for a fixed wall-clock hyper-period; fast learners do more
  steps.  With ``wire_aware=True`` (default) the per-learner step budget
  additionally subtracts that learner's modeled round-trip wire time, so
  bandwidth-capped federations still finish inside the hyper-period.
* **asynchronous** (:class:`AsyncProtocol`) — the engine aggregates on
  *every* arrival, weighting by staleness
  (``core/aggregation.staleness_weights``); there is no round barrier.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core.selection import SelectionPolicy, select_learners

__all__ = [
    "TrainTask",
    "LearnerProfile",
    "ProtocolPolicy",
    "SyncProtocol",
    "SemiSyncProtocol",
    "AsyncProtocol",
    "BufferedAsyncProtocol",
    "DeadlineCohortProtocol",
    "ReputationProtocol",
]


@dataclasses.dataclass(frozen=True)
class TrainTask:
    """The unit the controller dispatches to a learner (paper's RunTask)."""

    round_id: int
    local_steps: int
    batch_size: int
    learning_rate: float
    # FedProx proximal coefficient; 0 disables the proximal term.
    prox_mu: float = 0.0
    metadata: dict = dataclasses.field(default_factory=dict)


class LearnerProfile(dict):
    """Per-learner execution profile with an EWMA seconds-per-step estimate.

    A plain ``dict`` (so policy code and tests read it like the legacy
    profile: ``profile["seconds_per_step"]``, ``profile.get(...)``) whose
    step-time entry is maintained as an exponentially weighted moving
    average instead of the last sample, so semi-sync task sizing does not
    thrash on noisy step timings:

    ``est_new = decay * est_old + (1 - decay) * observation``

    ``decay=0`` reproduces the legacy last-sample behaviour; larger decay
    means smoother (and slower-adapting) estimates.  ``upload_bytes``
    records the learner's most recent wire payload size, feeding the
    per-learner round-trip wire-time estimate
    (``Controller.wire_time_s``).
    """

    def __init__(self, decay: float = 0.5):
        super().__init__()
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        self.decay = float(decay)
        self.observations = 0
        self.rep_observations = 0

    def observe_step_time(self, seconds_per_step: float) -> float:
        """Fold one measured seconds-per-step sample into the EWMA."""
        obs = float(seconds_per_step)
        if self.observations == 0:
            est = obs
        else:
            est = self.decay * float(self["seconds_per_step"]) + (1.0 - self.decay) * obs
        self["seconds_per_step"] = est
        self.observations += 1
        return est

    def observe_upload_bytes(self, nbytes: int) -> None:
        """Record the learner's latest measured uplink payload size."""
        self["upload_bytes"] = int(nbytes)

    def observe_contribution(self, score: float) -> float:
        """Fold one contribution observation into the reputation EWMA.

        ``score`` is 1.0 for a useful upload, 0.0 for a lost/orphaned one
        (anything in between is allowed).  Same recurrence as
        :meth:`observe_step_time` — ``decay=0`` keeps the last sample — but
        tracked under its own observation counter so step-time and
        reputation histories stay independent.
        """
        obs = float(score)
        if self.rep_observations == 0:
            est = obs
        else:
            est = self.decay * float(self["reputation"]) + (1.0 - self.decay) * obs
        self["reputation"] = est
        self.rep_observations += 1
        return est

    def reputation(self, default: float = 1.0) -> float:
        """Current reputation estimate (``default`` when never observed)."""
        return float(self.get("reputation", default))

    def decay_reputation(self, rounds_absent: int, rate: float = 0.9) -> float:
        """Multiplicatively decay reputation over ``rounds_absent`` rounds.

        Churn-aware: a learner that dropped out and rejoins after *k* rounds
        returns with ``reputation * rate**k``, so long absences cost standing
        without zeroing the history.  No-op for learners never observed.
        """
        rounds_absent = int(rounds_absent)
        if rounds_absent > 0 and "reputation" in self:
            self["reputation"] = float(self["reputation"]) * float(rate) ** rounds_absent
        return self.reputation()


class ProtocolPolicy:
    """The pluggable policy interface the round engine drives protocols by.

    The engine (``core/engine.py``) owns *one* arrival-driven loop; every
    protocol-specific decision is delegated to these four hooks plus the
    :attr:`continuous` flag.  Subclasses override what differs; the defaults
    implement the round-based (synchronous-family) behaviour.
    """

    #: Round-based policies (False) barrier on a cohort and evaluate after
    #: each aggregate; continuous policies (True) aggregate per arrival and
    #: immediately re-dispatch the arriving learner.
    continuous: bool = False

    #: Policies that rank or predict from learner state set this True; the
    #: engine then passes ``profiles=``/``wire_s=`` keyword arguments to
    #: :meth:`select_cohort`.  Kept opt-in so existing subclasses overriding
    #: ``select_cohort`` with the legacy signature keep working unchanged.
    needs_profiles: bool = False

    def select_cohort(
        self,
        selection: SelectionPolicy,
        learner_ids: Sequence[str],
        round_id: int,
        num_examples: dict[str, int] | None = None,
    ) -> list[str]:
        """Pick this round's cohort (defaults to the selection policy)."""
        return select_learners(selection, list(learner_ids), round_id, num_examples)

    def size_task(
        self, round_id: int, learner_profile: dict | None = None, wire_s: float = 0.0
    ) -> TrainTask:
        """Size one learner's task; ``wire_s`` is its modeled round-trip wire time."""
        raise NotImplementedError

    def should_aggregate(self, arrived: int, cohort_size: int) -> bool:
        """True when the engine should fire an aggregation event."""
        return arrived >= cohort_size

    def weighting(self) -> str:
        """Arena row weighting at the reduce: ``"fedavg"`` or ``"staleness"``."""
        return "fedavg"

    def make_task(self, round_id: int, learner_profile: dict | None = None) -> TrainTask:
        """Legacy alias for :meth:`size_task` with no wire-time input."""
        return self.size_task(round_id, learner_profile)


@dataclasses.dataclass(frozen=True)
class SyncProtocol(ProtocolPolicy):
    """Synchronous rounds: same step budget for every selected learner,
    aggregate when the whole cohort has uploaded (paper's FedAvg setting)."""

    local_steps: int = 1
    batch_size: int = 100
    learning_rate: float = 0.01
    prox_mu: float = 0.0

    def size_task(
        self, round_id: int, learner_profile: dict | None = None, wire_s: float = 0.0
    ) -> TrainTask:
        """Build the fixed-step TrainTask for this round."""
        return TrainTask(
            round_id=round_id,
            local_steps=self.local_steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            prox_mu=self.prox_mu,
        )


@dataclasses.dataclass(frozen=True)
class SemiSyncProtocol(ProtocolPolicy):
    """Fixed hyper-period: per-learner step count derived from measured speed.

    ``hyperperiod_s`` is the wall-clock budget per round.  The controller
    keeps an EWMA estimate of each learner's seconds-per-step
    (:class:`LearnerProfile`) and the policy assigns

    ``steps_i = max(1, floor((hyperperiod_s - wire_i) / spstep_i))``

    where ``wire_i`` is learner *i*'s modeled round-trip wire time (downlink
    broadcast + uplink upload, from the channel's bandwidth/latency model —
    see ``Controller.wire_time_s``).  Subtracting it makes the budget cover
    *train + wire*: under a bandwidth cap a naively sized task would finish
    training exactly at the hyper-period and then blow the budget by the
    upload time.  ``wire_aware=False`` keeps the legacy train-only sizing
    (the ``benchmarks/bench_round.py --schedule`` comparison arm).
    """

    hyperperiod_s: float = 1.0
    batch_size: int = 100
    learning_rate: float = 0.01
    default_steps: int = 1
    prox_mu: float = 0.0
    wire_aware: bool = True

    def size_task(
        self, round_id: int, learner_profile: dict | None = None, wire_s: float = 0.0
    ) -> TrainTask:
        """Size the task from measured seconds-per-step minus wire time."""
        steps = self.default_steps
        sps = (learner_profile or {}).get("seconds_per_step", 0)
        if sps and sps > 0:
            budget = self.hyperperiod_s - (wire_s if self.wire_aware else 0.0)
            steps = max(1, int(budget / sps))
        return TrainTask(
            round_id=round_id,
            local_steps=steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            prox_mu=self.prox_mu,
            metadata={"semi_sync": True, "wire_s": wire_s},
        )


@dataclasses.dataclass(frozen=True)
class AsyncProtocol(ProtocolPolicy):
    """Asynchronous policy: no round barrier — the engine aggregates on every
    arrival, staleness-damped by ``staleness_alpha``
    (``core/aggregation.staleness_weights``; semantics in docs/PROTOCOLS.md),
    and immediately re-dispatches the arriving learner."""

    local_steps: int = 1
    batch_size: int = 100
    learning_rate: float = 0.01
    staleness_alpha: float = 0.5
    prox_mu: float = 0.0
    continuous = True

    def select_cohort(
        self,
        selection: SelectionPolicy,
        learner_ids: Sequence[str],
        round_id: int,
        num_examples: dict[str, int] | None = None,
    ) -> list[str]:
        """Every registered learner participates (no per-round cohort)."""
        return list(learner_ids)

    def should_aggregate(self, arrived: int, cohort_size: int) -> bool:
        """Every arrival triggers a community update."""
        return arrived >= 1

    def weighting(self) -> str:
        """Rows are example-count weights damped by staleness."""
        return "staleness"

    def size_task(
        self, round_id: int, learner_profile: dict | None = None, wire_s: float = 0.0
    ) -> TrainTask:
        """Build the TrainTask for the learner's next async leg."""
        return TrainTask(
            round_id=round_id,
            local_steps=self.local_steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            prox_mu=self.prox_mu,
            metadata={"async": True},
        )


@dataclasses.dataclass(frozen=True)
class BufferedAsyncProtocol(ProtocolPolicy):
    """FedBuff-style buffered asynchrony: aggregate every K arrivals.

    Like :class:`AsyncProtocol` there is no round barrier — every learner is
    always training and is re-dispatched after contributing — but instead of
    a community update per arrival, the engine buffers arrivals and fires one
    staleness-weighted aggregate over exactly the buffered members once the
    buffer holds ``buffer_k`` of them (``aggregate_scope = "buffer"`` routes
    the engine to ``Controller.aggregate_buffer``).  With fewer than
    ``buffer_k`` registered learners the threshold clamps to the live fleet
    size so shrinking (churned) federations keep making progress.
    """

    buffer_k: int = 8
    local_steps: int = 1
    batch_size: int = 100
    learning_rate: float = 0.01
    staleness_alpha: float = 0.5
    prox_mu: float = 0.0
    continuous = True
    #: Aggregate over the buffered members only, not every valid arena row.
    aggregate_scope = "buffer"

    def select_cohort(
        self,
        selection: SelectionPolicy,
        learner_ids: Sequence[str],
        round_id: int,
        num_examples: dict[str, int] | None = None,
    ) -> list[str]:
        """Every registered learner trains concurrently (no cohort)."""
        return list(learner_ids)

    def should_aggregate(self, arrived: int, cohort_size: int) -> bool:
        """Fire once the buffer holds K arrivals (clamped to the fleet size)."""
        if self.buffer_k < 1:
            raise ValueError(f"buffer_k must be >= 1, got {self.buffer_k}")
        return arrived >= max(1, min(self.buffer_k, cohort_size))

    def weighting(self) -> str:
        """Buffered rows are example-count weights damped by staleness."""
        return "staleness"

    def size_task(
        self, round_id: int, learner_profile: dict | None = None, wire_s: float = 0.0
    ) -> TrainTask:
        """Build the TrainTask for the learner's next buffered-async leg."""
        return TrainTask(
            round_id=round_id,
            local_steps=self.local_steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            prox_mu=self.prox_mu,
            metadata={"buffered_async": True, "buffer_k": self.buffer_k},
        )


@dataclasses.dataclass(frozen=True)
class DeadlineCohortProtocol(ProtocolPolicy):
    """Deadline-predicted cohorts: dispatch only learners expected on time.

    A round-based policy that forms each cohort from the learners whose
    predicted completion time — EWMA seconds-per-step × ``local_steps`` plus
    the modeled round-trip wire time — lands inside ``deadline_s``.
    Unprofiled learners are optimistically assumed on time; if *nobody*
    qualifies the single fastest-predicted learner is dispatched so the
    federation never stalls.  With ``enforce_wall_clock=True`` the engine
    additionally arms a wall-clock timer per round and, at the deadline,
    aggregates whatever has arrived; stragglers land as *late* uploads that
    are folded into the next round's aggregate
    (``engine.faults.uploads_late``).  Harnesses that need byte-identical
    journals set ``enforce_wall_clock=False`` (prediction only — no timers).
    """

    deadline_s: float = 1.0
    local_steps: int = 1
    batch_size: int = 100
    learning_rate: float = 0.01
    prox_mu: float = 0.0
    enforce_wall_clock: bool = True
    needs_profiles = True

    def select_cohort(
        self,
        selection: SelectionPolicy,
        learner_ids: Sequence[str],
        round_id: int,
        num_examples: dict[str, int] | None = None,
        profiles: Mapping[str, Mapping] | None = None,
        wire_s: Mapping[str, float] | None = None,
    ) -> list[str]:
        """Keep the base selection's learners predicted to beat the deadline."""
        base = select_learners(selection, list(learner_ids), round_id, num_examples)
        profiles = profiles or {}
        wire_s = wire_s or {}
        on_time: list[str] = []
        predicted: list[tuple[float, str]] = []
        for lid in base:
            sps = (profiles.get(lid) or {}).get("seconds_per_step", 0.0)
            eta = float(wire_s.get(lid, 0.0))
            if sps and sps > 0:
                eta += self.local_steps * float(sps)
            else:
                eta = 0.0  # unprofiled: optimistically on time
            predicted.append((eta, lid))
            if eta <= self.deadline_s:
                on_time.append(lid)
        if on_time:
            return on_time
        # Never stall: take the single fastest-predicted learner (ties break
        # lexicographically, keeping cohort formation deterministic).
        return [min(predicted)[1]] if predicted else []

    def size_task(
        self, round_id: int, learner_profile: dict | None = None, wire_s: float = 0.0
    ) -> TrainTask:
        """Build the fixed-step TrainTask carrying the round deadline."""
        return TrainTask(
            round_id=round_id,
            local_steps=self.local_steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            prox_mu=self.prox_mu,
            metadata={"deadline_s": self.deadline_s},
        )


@dataclasses.dataclass(frozen=True)
class ReputationProtocol(ProtocolPolicy):
    """Reputation-weighted selection: dispatch the highest-contributing slice.

    Round-based FedAvg whose cohort is the top ``fraction`` of the base
    selection ranked by the :class:`LearnerProfile` reputation EWMA
    (contributions observed by the controller: 1.0 per useful upload, 0.0
    per lost/orphaned one, multiplicative decay over dropout absences).
    Unobserved learners rank at the default reputation 1.0 — new joiners are
    not starved — and the sort is stable, so equal reputations preserve the
    base selection order (``fraction=1.0`` degenerates to plain sync).
    ``min_learners`` floors the cohort so aggregation always has quorum.
    """

    fraction: float = 0.5
    min_learners: int = 1
    local_steps: int = 1
    batch_size: int = 100
    learning_rate: float = 0.01
    prox_mu: float = 0.0
    needs_profiles = True

    def select_cohort(
        self,
        selection: SelectionPolicy,
        learner_ids: Sequence[str],
        round_id: int,
        num_examples: dict[str, int] | None = None,
        profiles: Mapping[str, Mapping] | None = None,
        wire_s: Mapping[str, float] | None = None,
    ) -> list[str]:
        """Stable-rank the base selection by reputation, keep the top slice."""
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")
        base = select_learners(selection, list(learner_ids), round_id, num_examples)
        profiles = profiles or {}

        def _rep(lid: str) -> float:
            prof = profiles.get(lid)
            if prof is None:
                return 1.0
            rep = getattr(prof, "reputation", None)
            if callable(rep):
                return float(rep())
            return float(prof.get("reputation", 1.0))

        ranked = sorted(base, key=lambda lid: -_rep(lid))
        if not ranked:
            return ranked
        k = max(int(self.min_learners), math.ceil(self.fraction * len(ranked)))
        return ranked[: min(len(ranked), max(1, k))]

    def size_task(
        self, round_id: int, learner_profile: dict | None = None, wire_s: float = 0.0
    ) -> TrainTask:
        """Build the fixed-step TrainTask for the selected learner."""
        return TrainTask(
            round_id=round_id,
            local_steps=self.local_steps,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            prox_mu=self.prox_mu,
            metadata={"reputation": True},
        )
