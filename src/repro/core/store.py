"""In-memory model stores for the federation controller.

MetisFL's controller keeps every learner's latest local model in an in-memory
hash map (the paper assumes all local models fit in memory and treats
insert/select as O(1); §5 sketches future on-disk/distributed stores).  Two
backings implement that store:

* :class:`ModelStore` — the hash-map store with per-learner lineage,
  capacity-bounded eviction, and aggregate byte accounting.  Each upload is a
  standalone buffer; aggregation re-stacks them into an ``(N, P)`` array every
  round (the legacy path, kept for parity testing).

* :class:`ArenaStore` — the device-resident aggregation arena.  One persistent
  ``(n_max, P)`` device buffer plus ``weights``/``versions`` vectors and a
  validity mask; every learner owns a row, uploads are donated in-place row
  writes, and aggregation is a single masked reduction straight over the arena
  — the controller hot path never re-packs or re-stacks anything.

  Passing ``mesh=`` puts the arena in **sharded mode**: the buffer is laid out
  column-sharded over the mesh (``P`` split over the data axis, rows
  replication-free), row writes run through a ``shard_map``-ed donated
  ``dynamic_update_slice`` so each device only ever touches its own
  ``(n_max, P/n_shards)`` shard, and the masked reduction happens per shard
  with **zero collectives** — nothing is gathered until the final model
  unpack.  See ``docs/ARENA.md``.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time
from collections import OrderedDict
from typing import Any, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import Telemetry
from repro.core.packing import round_up

__all__ = ["ModelRecord", "ModelStore", "ArenaStore"]


@dataclasses.dataclass
class ModelRecord:
    """One stored local model plus its aggregation metadata."""

    learner_id: str
    round_id: int
    buffer: Any  # packed numeric buffer (jax.Array) or byte buffer
    num_examples: int  # aggregation weight source (FedAvg)
    metadata: dict = dataclasses.field(default_factory=dict)
    timestamp: float = dataclasses.field(default_factory=time.monotonic)

    @property
    def nbytes(self) -> int:
        """Resident bytes of the stored buffer (eviction accounting)."""
        b = self.buffer
        if hasattr(b, "nbytes"):
            return int(b.nbytes)
        return int(np.asarray(b).nbytes)


class ModelStore:
    """Hash-map model store with per-learner lineage and eviction.

    ``lineage_length`` bounds how many historical models per learner are kept
    (1 = paper's behaviour: latest only).  ``capacity_bytes`` optionally bounds
    total resident bytes; the oldest records across learners are evicted first
    (never the latest record of a learner — the controller must always be able
    to aggregate every registered learner).
    """

    def __init__(
        self,
        lineage_length: int = 1,
        capacity_bytes: int | None = None,
        telemetry: Telemetry | None = None,
    ):
        if lineage_length < 1:
            raise ValueError("lineage_length must be >= 1")
        self._lineage_length = lineage_length
        self._capacity_bytes = capacity_bytes
        self._records: OrderedDict[str, list[ModelRecord]] = OrderedDict()
        self._telemetry = telemetry if telemetry is not None else Telemetry()
        self._register_counters()

    def _register_counters(self) -> None:
        self._c_inserts = self._telemetry.counter("store.model.total_inserts")
        self._c_bytes = self._telemetry.counter("store.model.bytes_ingested")

    def bind_telemetry(self, telemetry: Telemetry) -> None:
        """Re-register this store's counters in a shared registry.

        The controller calls this on a user-supplied store so every counter
        lives behind the one ``controller.telemetry`` handle; current values
        carry over.
        """
        if telemetry is self._telemetry:
            return
        inserts, nbytes = self._c_inserts.value, self._c_bytes.value
        self._telemetry = telemetry
        self._register_counters()
        self._c_inserts.add(inserts)
        self._c_bytes.add(nbytes)

    @property
    def total_inserts(self) -> int:
        """Deprecated shim for ``telemetry.value('store.model.total_inserts')``."""
        return self._c_inserts.value

    @property
    def bytes_ingested(self) -> int:
        """Deprecated shim for ``telemetry.value('store.model.bytes_ingested')``."""
        return self._c_bytes.value

    # -- insertion ---------------------------------------------------------
    def insert(self, record: ModelRecord) -> None:
        """Append to the learner's lineage, trimming history and evicting."""
        lineage = self._records.setdefault(record.learner_id, [])
        lineage.append(record)
        self._c_inserts.add(1)
        # Cumulative ingest accounting (never decremented by eviction):
        # reconciles against the channel's uplink counters in tests.
        self._c_bytes.add(record.nbytes)
        if len(lineage) > self._lineage_length:
            del lineage[: len(lineage) - self._lineage_length]
        self._maybe_evict()

    def _maybe_evict(self) -> None:
        if self._capacity_bytes is None:
            return
        while self.resident_bytes() > self._capacity_bytes:
            victim: ModelRecord | None = None
            for lineage in self._records.values():
                # candidates: everything but the newest record per learner
                for rec in lineage[:-1]:
                    if victim is None or rec.timestamp < victim.timestamp:
                        victim = rec
            if victim is None:
                break  # only latest-per-learner remain; never evict those
            self._records[victim.learner_id].remove(victim)

    # -- selection ---------------------------------------------------------
    def latest(self, learner_id: str) -> ModelRecord:
        """The learner's most recent record (KeyError if never uploaded)."""
        return self._records[learner_id][-1]

    def lineage(self, learner_id: str) -> list[ModelRecord]:
        """Oldest-to-newest stored history for one learner (may be empty)."""
        return list(self._records.get(learner_id, []))

    def discard(self, learner_id: str) -> None:
        """Drop a learner's entire stored lineage (no-op if unknown)."""
        self._records.pop(learner_id, None)

    def select_latest(self, learner_ids: list[str] | None = None) -> list[ModelRecord]:
        """The controller's 'model selection' step before aggregation."""
        ids = learner_ids if learner_ids is not None else list(self._records)
        return [self.latest(i) for i in ids if i in self._records]

    def __contains__(self, learner_id: str) -> bool:
        return learner_id in self._records

    def __iter__(self) -> Iterator[str]:
        return iter(self._records)

    def __len__(self) -> int:
        return len(self._records)

    # -- accounting ---------------------------------------------------------
    def resident_bytes(self) -> int:
        """Total bytes across every stored record (drives eviction)."""
        return sum(rec.nbytes for lin in self._records.values() for rec in lin)

    def num_records(self) -> int:
        """Total stored records across all learners and lineages."""
        return sum(len(lin) for lin in self._records.values())

    # -- checkpointing ------------------------------------------------------
    def export_records(self) -> list[ModelRecord]:
        """Every stored record in insertion order (checkpoint save)."""
        return [rec for lin in self._records.values() for rec in lin]

    def restore_records(self, records: Sequence[ModelRecord]) -> None:
        """Replace the store's contents (checkpoint restore).

        Rebuilds lineages in the given order without touching the cumulative
        ingest counters — restore is not new wire traffic.
        """
        self._records.clear()
        for rec in records:
            self._records.setdefault(rec.learner_id, []).append(rec)


# ---------------------------------------------------------------------------
# Device-resident aggregation arena
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_row(arena: jax.Array, row: jax.Array, buf: jax.Array) -> jax.Array:
    """Donated in-place row write: arena[row, :len(buf)] = buf.

    Donation lets XLA update the persistent buffer without allocating a new
    ``(n_max, P)`` array — the arena's whole point.  ``row`` is a traced
    scalar so every learner's write hits the same compiled executable.
    """
    return jax.lax.dynamic_update_slice(arena, buf[None, :], (row, 0))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _set_row_meta(
    weights: jax.Array, versions: jax.Array, mask: jax.Array,
    row: jax.Array, weight: jax.Array, version: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    return (
        weights.at[row].set(weight),
        versions.at[row].set(version),
        mask.at[row].set(1.0),
    )


def _grown_impl(old: jax.Array, n_new: int) -> jax.Array:
    new = jnp.zeros((n_new,) + old.shape[1:], old.dtype)
    return new.at[: old.shape[0]].set(old)


_grown = jax.jit(_grown_impl, static_argnames=("n_new",))


def _make_sharded_writer(mesh, axes):
    """Build the sharded-arena row writer: a donated ``shard_map``-ed
    ``dynamic_update_slice``.

    Each device holds an ``(n_max, shard_width)`` column shard of the arena
    and the matching ``(shard_width,)`` slice of the incoming upload; the
    write is purely local (the row index is replicated, the column offset is
    0 in every shard's coordinates), so the compiled program contains no
    collectives and — thanks to donation — no ``(n_max, P)`` re-allocation.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    def _write(arena, row, buf):
        return jax.lax.dynamic_update_slice(arena, buf[None, :], (row, 0))

    sm = shard_map(
        _write,
        mesh=mesh,
        in_specs=(P(None, axes), P(), P(axes)),
        out_specs=P(None, axes),
        check_vma=False,
    )
    return jax.jit(sm, donate_argnums=(0,))


class ArenaStore:
    """Device-resident aggregation arena — the controller hot-path store.

    Owns one persistent ``(n_max, padded_params)`` device buffer plus
    ``weights (n_max,)`` (FedAvg example counts), ``versions (n_max,)`` (the
    global-model version each row trained from, for staleness weighting) and a
    float validity ``mask (n_max,)``.  Every learner is assigned a row on
    first upload and *reuses* it on every subsequent upload (a donated
    ``dynamic_update_slice`` — no host round-trip, no re-stack); aggregation
    is a single masked reduction straight over ``buffer``
    (``core/aggregation.masked_weighted_average`` or the Pallas
    ``kernels.ops.masked_fedavg``), sliced to ``num_params``.

    Rows are padded to ``row_align`` elements so the Pallas kernel's VMEM
    tiles stay lane-aligned without per-call padding; the padding columns are
    zero and never escape (aggregation output is sliced to ``num_params``).

    When more learners register than ``n_max`` rows exist, the arena grows
    geometrically (one O(n·P) copy per doubling, amortized O(1) per learner).

    **Sharded mode** (``mesh=`` given): the buffer is created with a
    ``P(None, axes)`` :class:`~jax.sharding.NamedSharding` — columns split
    over the mesh's data axis, rows replication-free — so each device owns a
    ``(n_max, shard_width)`` shard.  Row writes route through a
    ``shard_map``-ed donated ``dynamic_update_slice`` (each device updates
    only its shard; zero collectives) and ``padded_params`` is rounded up to
    ``row_align * n_shards`` so every shard stays lane-aligned for the Pallas
    kernel.  The tiny metadata vectors stay host-driven exactly as in the
    single-device mode.  Growth preserves the sharding (the grown buffer is
    re-laid-out with the same spec; the copy is shard-local).

    Thread-safety: all mutation happens under an internal re-entrant lock.
    Because writes *donate* the previous array object, callers must not hold
    references to ``buffer``/``weights``/``versions``/``mask`` across a
    concurrent write — aggregate inside ``with arena.lock:``.
    """

    def __init__(
        self,
        num_params: int,
        n_max: int = 8,
        row_align: int = 1024,
        dtype: Any = jnp.float32,
        mesh: Any = None,
        axes: Any = None,
        telemetry: Telemetry | None = None,
        arena_dtype: str = "f32",
        qgroup: int | None = None,
        sparse_k: int | None = None,
    ):
        if num_params < 1:
            raise ValueError("num_params must be >= 1")
        if arena_dtype not in ("f32", "int8", "topk"):
            raise ValueError(
                f"arena_dtype must be 'f32', 'int8' or 'topk', "
                f"got {arena_dtype!r}"
            )
        self.num_params = int(num_params)
        self.dtype = jnp.dtype(dtype)
        self.arena_dtype = arena_dtype
        self.lock = threading.RLock()
        self.mesh = mesh
        if mesh is not None:
            from repro.models.sharding import arena_specs

            buf_s, row_s, repl_s = arena_specs(mesh, axes)
            self.axes = row_s.spec[0]
            self.buffer_sharding, self.row_sharding = buf_s, row_s
            self.n_shards = int(
                np.prod([mesh.shape[a] for a in self.axes], dtype=np.int64)
            )
            self._writer = _make_sharded_writer(mesh, self.axes)
            # One jitted grow program per store (cached across growth events;
            # jit re-specializes per (shape, n_new) but never rebuilds the
            # wrapper, unlike a fresh jax.jit per call).
            self._grower = jax.jit(
                _grown_impl, static_argnames=("n_new",), out_shardings=buf_s
            )
            self.padded_params = round_up(self.num_params, row_align * self.n_shards)
        else:
            self.axes = None
            self.buffer_sharding = self.row_sharding = None
            self.n_shards = 1
            self._writer = None
            self._grower = _grown
            self.padded_params = round_up(self.num_params, row_align)
        if arena_dtype == "int8":
            from repro.kernels.quantize import DEFAULT_GROUP

            self.qgroup = int(qgroup or DEFAULT_GROUP)
            if self.shard_width % self.qgroup:
                raise ValueError(
                    f"int8 arena needs the per-shard row width "
                    f"{self.shard_width} divisible by the quant group "
                    f"{self.qgroup}; raise row_align or shrink the group"
                )
            self.buffer_dtype = jnp.dtype(jnp.int8)
        else:
            self.qgroup = int(qgroup) if qgroup else None
            self.buffer_dtype = self.dtype
        if arena_dtype == "topk":
            if sparse_k is None:
                raise ValueError("arena_dtype='topk' needs sparse_k")
            # Rows hold (sparse_k,) coordinate streams against the padded
            # row width, so k clamps to it exactly like the wire codec.
            self.sparse_k = max(1, min(int(sparse_k), self.padded_params))
        else:
            self.sparse_k = None
        n = max(1, int(n_max))
        self._rows: dict[str, int] = {}
        self._valid = np.zeros((n,), bool)
        self._weights_host = np.zeros((n,), np.float32)
        self._versions_host = np.zeros((n,), np.float32)
        if arena_dtype == "topk":
            # Sparse arena: (n, k) f32 values + (n, k) int32 indices, both
            # deliberately **unsharded** even under a mesh — N·k is small by
            # construction and the sharded scatter-accumulate consumes them
            # replicated (only its (P,) output is column-sharded).
            self.buffer = jnp.zeros((n, self.sparse_k), jnp.float32)
            self.indices = jnp.zeros((n, self.sparse_k), jnp.int32)
        else:
            self.buffer = self._zeros(
                (n, self.padded_params), self.buffer_dtype,
                self.buffer_sharding,
            )
            self.indices = None
        # Per-row per-group f32 dequantization scales of the int8 arena: the
        # quantized row is column-aligned with its scales, so both shard with
        # the same column specs (the scale width padded_params/qgroup stays a
        # multiple of n_shards because shard_width % qgroup == 0).
        self.scales = (
            self._zeros((n, self.padded_params // self.qgroup), jnp.float32,
                        self.buffer_sharding)
            if arena_dtype == "int8" else None
        )
        self.weights = jnp.zeros((n,), jnp.float32)
        self.versions = jnp.zeros((n,), jnp.float32)
        self.mask = jnp.zeros((n,), jnp.float32)
        self._telemetry = telemetry if telemetry is not None else Telemetry()
        self._c_writes = self._telemetry.counter("store.arena.total_writes")
        self._c_bytes = self._telemetry.counter("store.arena.bytes_ingested")
        self._c_grows = self._telemetry.counter("store.arena.grow_events")
        self._g_resident = self._telemetry.gauge("store.arena.bytes_resident")
        self._g_resident.set(self.resident_bytes())

    @property
    def total_writes(self) -> int:
        """Deprecated shim for ``telemetry.value('store.arena.total_writes')``."""
        return self._c_writes.value

    @property
    def bytes_ingested(self) -> int:
        """Deprecated shim for ``telemetry.value('store.arena.bytes_ingested')``."""
        return self._c_bytes.value

    @property
    def grow_events(self) -> int:
        """Deprecated shim for ``telemetry.value('store.arena.grow_events')``."""
        return self._c_grows.value

    @staticmethod
    def _zeros(shape, dtype, sharding):
        """Allocate zeros, directly laid out per ``sharding`` when given."""
        if sharding is None:
            return jnp.zeros(shape, dtype)
        return jax.jit(lambda: jnp.zeros(shape, dtype), out_shardings=sharding)()

    # -- capacity -----------------------------------------------------------
    @property
    def n_max(self) -> int:
        """Current row capacity (grows geometrically on demand)."""
        return self.buffer.shape[0]

    @property
    def sharded(self) -> bool:
        """True when the arena buffer is column-sharded over a device mesh."""
        return self.mesh is not None

    @property
    def shard_width(self) -> int:
        """Per-device column width: ``padded_params / n_shards``."""
        return self.padded_params // self.n_shards

    def _grow(self, n_new: int) -> None:
        if self.arena_dtype == "topk":
            # The sparse arrays are unsharded regardless of mesh, so they
            # grow through the plain jitted grower.
            self.buffer = _grown(self.buffer, n_new)
            self.indices = _grown(self.indices, n_new)
        else:
            self.buffer = self._grower(self.buffer, n_new=n_new)
        if self.scales is not None:
            self.scales = self._grower(self.scales, n_new=n_new)
        self.weights = _grown(self.weights, n_new)
        self.versions = _grown(self.versions, n_new)
        self.mask = _grown(self.mask, n_new)
        pad = n_new - len(self._valid)
        self._valid = np.concatenate([self._valid, np.zeros((pad,), bool)])
        self._weights_host = np.concatenate(
            [self._weights_host, np.zeros((pad,), np.float32)]
        )
        self._versions_host = np.concatenate(
            [self._versions_host, np.zeros((pad,), np.float32)]
        )
        self._c_grows.add(1)
        self._g_resident.set(self.resident_bytes())

    def _assign_row(self, learner_id: str) -> int:
        row = self._rows.get(learner_id)
        if row is None:
            row = len(self._rows)
            if row >= self.n_max:
                self._grow(max(2 * self.n_max, row + 1))
            self._rows[learner_id] = row
        return row

    def ensure_row(self, learner_id: str) -> int:
        """Assign (or return) the learner's arena row without writing it.

        The controller calls this at registration so row order follows
        *registration* order, not first-upload arrival order — making
        arena-mode aggregation order deterministic across runs (the
        kill-and-resume parity contract; see ``docs/OBSERVABILITY.md``).
        The row stays invalid until the first :meth:`write`.
        """
        with self.lock:
            return self._assign_row(learner_id)

    # -- writes -------------------------------------------------------------
    def write(
        self, learner_id: str, buffer: jax.Array, weight: float, version: float = 0.0
    ) -> int:
        """Insert/overwrite a learner's packed update in its arena row.

        The (donated) row write is the entire MarkTaskCompleted store cost:
        O(P) device bytes, zero allocation, no host copy.  Returns the row.
        """
        if self.arena_dtype == "topk":
            raise ValueError(
                "a sparse (arena_dtype='topk') arena has no dense rows; "
                "use write_sparse"
            )
        buf = jnp.ravel(jnp.asarray(buffer)).astype(self.dtype)
        if buf.shape[0] not in (self.num_params, self.padded_params):
            raise ValueError(
                f"buffer has {buf.shape[0]} params, arena rows hold "
                f"{self.num_params} (or {self.padded_params} pre-padded)"
            )
        if self.arena_dtype == "int8":
            # Quantize the f32 upload into the resident layout on device,
            # then land it through the quantized write path.  The padded
            # columns quantize to q=0/scale=1.0 exactly (zero-amax fallback).
            from repro.kernels import ops, quantize as quant

            if buf.shape[0] != self.padded_params:
                buf = jnp.pad(buf, (0, self.padded_params - buf.shape[0]))
            q, s = ops.quantize(
                buf, group=self.qgroup,
                block_rows=quant.effective_block_rows(
                    self.padded_params, self.qgroup
                ),
            )
            return self.write_quantized(
                learner_id, q[: self.padded_params],
                s[: self.padded_params // self.qgroup], weight, version,
            )
        if self.sharded:
            if buf.shape[0] != self.padded_params:
                buf = jnp.pad(buf, (0, self.padded_params - buf.shape[0]))
            # Scatter the upload across the mesh once, then write shard-local.
            buf = jax.device_put(buf, self.row_sharding)
        with self.lock:
            row = self._assign_row(learner_id)
            if self.sharded:
                self.buffer = self._writer(self.buffer, jnp.int32(row), buf)
            else:
                self.buffer = _write_row(self.buffer, jnp.int32(row), buf)
            self.weights, self.versions, self.mask = _set_row_meta(
                self.weights, self.versions, self.mask,
                jnp.int32(row), jnp.float32(weight), jnp.float32(version),
            )
            self._valid[row] = True
            self._weights_host[row] = weight
            self._versions_host[row] = version
            self._c_writes.add(1)
            # Cumulative decoded-row ingest bytes: reconciles against the
            # channel's uplink message count in the dispatch tests.
            self._c_bytes.add(int(buf.nbytes))
            return row

    def write_quantized(
        self, learner_id: str, q: jax.Array, scales: jax.Array,
        weight: float, version: float = 0.0,
    ) -> int:
        """Land an already-quantized row (int8 values + f32 group scales).

        The quantized-resident ingest hot path: an int8 upload decoded by
        ``Channel.recv_upload_quantized`` writes straight into the arena with
        **no** intermediate f32 ``(P,)`` materialization — two donated row
        writes (values + scales), same metadata bookkeeping as :meth:`write`.
        Only valid on an ``arena_dtype="int8"`` arena.
        """
        if self.arena_dtype != "int8":
            raise ValueError(
                "write_quantized requires ArenaStore(arena_dtype='int8'); "
                f"this arena is {self.arena_dtype!r}"
            )
        q = jnp.ravel(jnp.asarray(q))
        if q.dtype != jnp.int8:
            raise ValueError(f"quantized row must be int8, got {q.dtype}")
        n_groups = self.padded_params // self.qgroup
        if q.shape[0] != self.padded_params or scales.shape != (n_groups,):
            raise ValueError(
                f"quantized row holds {q.shape[0]} values / "
                f"{scales.shape} scales; this arena wants "
                f"({self.padded_params},) / ({n_groups},)"
            )
        scales = jnp.asarray(scales, jnp.float32)
        if self.sharded:
            q = jax.device_put(q, self.row_sharding)
            scales = jax.device_put(scales, self.row_sharding)
        with self.lock:
            row = self._assign_row(learner_id)
            writer = self._writer if self.sharded else _write_row
            # The same jitted writer serves both arrays: jit re-specializes
            # per (shape, dtype), so values and scales each get a cached
            # executable.
            self.buffer = writer(self.buffer, jnp.int32(row), q)
            self.scales = writer(self.scales, jnp.int32(row), scales)
            self.weights, self.versions, self.mask = _set_row_meta(
                self.weights, self.versions, self.mask,
                jnp.int32(row), jnp.float32(weight), jnp.float32(version),
            )
            self._valid[row] = True
            self._weights_host[row] = weight
            self._versions_host[row] = version
            self._c_writes.add(1)
            self._c_bytes.add(int(q.nbytes) + int(scales.nbytes))
            return row

    def write_sparse(
        self, learner_id: str, indices: jax.Array, values: jax.Array,
        weight: float, version: float = 0.0,
    ) -> int:
        """Land a sparse ``(indices, values)`` upload in its arena row.

        The direct sparse ingest hot path: a topk upload decoded by
        ``Channel.recv_upload_sparse`` writes straight into the ``(n, k)``
        index/value arena — two donated row writes, no densification, same
        metadata bookkeeping as :meth:`write`.  Rows hold *deltas* against
        the model version recorded per row.  Only valid on an
        ``arena_dtype="topk"`` arena.
        """
        if self.arena_dtype != "topk":
            raise ValueError(
                "write_sparse requires ArenaStore(arena_dtype='topk'); "
                f"this arena is {self.arena_dtype!r}"
            )
        idx = jnp.ravel(jnp.asarray(indices))
        val = jnp.ravel(jnp.asarray(values)).astype(jnp.float32)
        if idx.dtype != jnp.int32:
            raise ValueError(f"sparse indices must be int32, got {idx.dtype}")
        if idx.shape != (self.sparse_k,) or val.shape != (self.sparse_k,):
            raise ValueError(
                f"sparse row holds {idx.shape[0]} indices / "
                f"{val.shape[0]} values; this arena wants "
                f"({self.sparse_k},) each"
            )
        with self.lock:
            row = self._assign_row(learner_id)
            # The same jitted writer serves both arrays: jit re-specializes
            # per (shape, dtype), so indices and values each get a cached
            # executable.
            self.indices = _write_row(self.indices, jnp.int32(row), idx)
            self.buffer = _write_row(self.buffer, jnp.int32(row), val)
            self.weights, self.versions, self.mask = _set_row_meta(
                self.weights, self.versions, self.mask,
                jnp.int32(row), jnp.float32(weight), jnp.float32(version),
            )
            self._valid[row] = True
            self._weights_host[row] = weight
            self._versions_host[row] = version
            self._c_writes.add(1)
            self._c_bytes.add(int(idx.nbytes) + int(val.nbytes))
            return row

    def invalidate(self, learner_id: str) -> None:
        """Drop a learner's contribution (row is kept for reuse)."""
        with self.lock:
            row = self._rows.get(learner_id)
            if row is None or not self._valid[row]:
                return
            self._valid[row] = False
            self.mask = self.mask.at[row].set(0.0)

    # -- selection ----------------------------------------------------------
    def row_of(self, learner_id: str) -> int | None:
        """The learner's assigned arena row (None before first upload)."""
        return self._rows.get(learner_id)

    def weight_of(self, learner_id: str) -> float:
        """Host-mirrored aggregation weight of a learner's current upload."""
        with self.lock:
            row = self._rows[learner_id]
            return float(self._weights_host[row])

    def version_of(self, learner_id: str) -> float:
        """Host-mirrored model version a learner's current upload trained from.

        Mirrors the device ``versions`` vector so staleness weights can be
        derived host-side (the secure async path needs them *before* the
        fixed-point masking) without a device round-trip.
        """
        with self.lock:
            row = self._rows[learner_id]
            return float(self._versions_host[row])

    def row_view(self, learner_id: str) -> jax.Array:
        """Device view of one learner's un-padded packed buffer (always f32).

        On a quantized arena the row is dequantized on the fly (one small
        device program) so callers keep the f32 contract; the resident state
        stays int8.
        """
        with self.lock:
            row = self._rows[learner_id]
            if not self._valid[row]:
                raise KeyError(f"{learner_id} has no valid model in the arena")
            if self.arena_dtype == "int8":
                q = self.buffer[row]
                s = self.scales[row]
                x = (q.astype(jnp.float32)
                     .reshape(-1, self.qgroup) * s[:, None]).reshape(-1)
                return x[: self.num_params]
            if self.arena_dtype == "topk":
                from repro.kernels import topk as topk_kernels

                x = topk_kernels.densify(
                    self.indices[row], self.buffer[row], self.padded_params
                )
                return x[: self.num_params]
            return self.buffer[row, : self.num_params]

    def round_mask(self, learner_ids: Sequence[str] | None = None) -> jax.Array:
        """Validity mask restricted to a selection (the round's cohort).

        ``None`` selects every valid row (async protocol).  The mask is the
        only per-round host→device transfer of the arena path: ``n_max``
        floats, independent of model size.
        """
        with self.lock:
            if learner_ids is None:
                return self.mask
            sel = np.zeros((self.n_max,), np.float32)
            for lid in learner_ids:
                row = self._rows.get(lid)
                if row is not None and self._valid[row]:
                    sel[row] = 1.0
            return jnp.asarray(sel)

    def valid_ids(self) -> list[str]:
        """Learners whose arena row currently holds a valid upload."""
        with self.lock:
            return [lid for lid, row in self._rows.items() if self._valid[row]]

    def num_valid(self, learner_ids: Sequence[str] | None = None) -> int:
        """How many of the given learners hold a valid upload (host-side).

        ``None`` counts every valid row.  Answered entirely from the arena's
        host-side row map — no device read, no sync.  This is how the
        controller detects an empty cohort before aggregating: the previous
        ``float(jnp.sum(mask))`` probe forced a device round-trip onto every
        round's critical path.
        """
        with self.lock:
            if learner_ids is None:
                return int(self._valid.sum())
            count = 0
            for lid in learner_ids:
                row = self._rows.get(lid)
                if row is not None and self._valid[row]:
                    count += 1
            return count

    # -- accounting ---------------------------------------------------------
    def __contains__(self, learner_id: str) -> bool:
        with self.lock:
            row = self._rows.get(learner_id)
            return row is not None and bool(self._valid[row])

    def __len__(self) -> int:
        with self.lock:
            return int(self._valid.sum())

    def resident_bytes(self) -> int:
        """Global device bytes held by the arena (buffer + scales + metadata).

        Also published as the ``store.arena.bytes_resident`` gauge after
        every capacity change — the observable half of the int8 arena's ~4x
        resident shrink (int8 values + f32 scales ≈ ``(1 + 4/group)``
        bytes/param vs 4 for f32) and of the sparse arena's k-proportional
        footprint (8 bytes per kept coordinate instead of 4 per parameter).
        """
        scales = self.scales.nbytes if self.scales is not None else 0
        indices = self.indices.nbytes if self.indices is not None else 0
        return int(
            self.buffer.nbytes + scales + indices + self.weights.nbytes
            + self.versions.nbytes + self.mask.nbytes
        )

    # -- checkpointing ------------------------------------------------------
    def export_state(self) -> dict:
        """Host-side copy of the arena's full state (checkpoint save).

        Returns ``buffer`` (the full ``(n_max, padded_params)`` array —
        f32, or int8 for a quantized arena — gathered if sharded), the host
        ``weights``/``versions``/``valid`` mirrors, and the ``rows``
        learner→row map.  A quantized arena additionally returns ``scales``
        (the ``(n_max, padded_params/group)`` f32 array).  Both the f32 and
        the int8+scales round-trips through ``.npz`` are bit-exact, so a
        restored arena aggregates bit-identically.
        """
        with self.lock:
            state = {
                "buffer": np.asarray(jax.device_get(self.buffer)),
                "weights": self._weights_host.copy(),
                "versions": self._versions_host.copy(),
                "valid": self._valid.copy(),
                "rows": dict(self._rows),
            }
            if self.scales is not None:
                state["scales"] = np.asarray(jax.device_get(self.scales))
            if self.indices is not None:
                state["indices"] = np.asarray(jax.device_get(self.indices))
            return state

    def restore_state(
        self,
        buffer: np.ndarray,
        weights: np.ndarray,
        versions: np.ndarray,
        valid: np.ndarray,
        rows: dict[str, int],
        scales: np.ndarray | None = None,
        indices: np.ndarray | None = None,
    ) -> None:
        """Reload a checkpointed arena state (inverse of :meth:`export_state`).

        The arena must have been constructed with the same ``num_params``
        and row alignment (``padded_params`` must match).  Capacity adapts:
        the restored state is padded (or the arena grown) to cover both the
        saved rows and any already-assigned ones.  A quantized arena
        requires ``scales`` (the checkpointed scale matrix); a sparse arena
        requires ``indices`` and the same ``sparse_k`` — restoring across
        arena layouts is a mismatch the caller surfaces via the checkpoint
        fingerprint.
        """
        host_dt = np.int8 if self.arena_dtype == "int8" else np.float32
        row_width = (
            self.sparse_k if self.arena_dtype == "topk" else self.padded_params
        )
        buffer = np.asarray(buffer, host_dt)
        if buffer.ndim != 2 or buffer.shape[1] != row_width:
            raise ValueError(
                f"checkpointed arena rows hold {buffer.shape[-1]} params, "
                f"this arena holds {row_width}"
            )
        if self.arena_dtype == "topk":
            if indices is None:
                raise ValueError(
                    "restoring a sparse arena needs the checkpointed indices"
                )
            indices = np.asarray(indices, np.int32)
            if indices.shape != buffer.shape:
                raise ValueError(
                    f"checkpointed sparse indices have shape {indices.shape}, "
                    f"values have {buffer.shape}"
                )
        if self.arena_dtype == "int8":
            if scales is None:
                raise ValueError(
                    "restoring an int8 arena needs the checkpointed scales"
                )
            scales = np.asarray(scales, np.float32)
            n_groups = self.padded_params // self.qgroup
            if scales.ndim != 2 or scales.shape[1] != n_groups:
                raise ValueError(
                    f"checkpointed scales hold {scales.shape[-1]} groups, "
                    f"this arena wants {n_groups}"
                )
        with self.lock:
            n = max(self.n_max, buffer.shape[0], len(rows))
            full = np.zeros((n, row_width), host_dt)
            full[: buffer.shape[0]] = buffer
            self._valid = np.zeros((n,), bool)
            self._valid[: len(valid)] = np.asarray(valid, bool)
            self._weights_host = np.zeros((n,), np.float32)
            self._weights_host[: len(weights)] = np.asarray(weights, np.float32)
            self._versions_host = np.zeros((n,), np.float32)
            self._versions_host[: len(versions)] = np.asarray(
                versions, np.float32
            )
            self._rows = {str(k): int(v) for k, v in rows.items()}
            if self.buffer_sharding is not None and self.arena_dtype != "topk":
                self.buffer = jax.device_put(full, self.buffer_sharding)
            else:
                self.buffer = jnp.asarray(full)
            if self.arena_dtype == "topk":
                full_i = np.zeros((n, row_width), np.int32)
                full_i[: indices.shape[0]] = indices
                self.indices = jnp.asarray(full_i)
            if self.arena_dtype == "int8":
                full_s = np.zeros(
                    (n, self.padded_params // self.qgroup), np.float32
                )
                full_s[: scales.shape[0]] = scales
                if self.buffer_sharding is not None:
                    self.scales = jax.device_put(full_s, self.buffer_sharding)
                else:
                    self.scales = jnp.asarray(full_s)
            self.weights = jnp.asarray(self._weights_host)
            self.versions = jnp.asarray(self._versions_host)
            self.mask = jnp.asarray(self._valid.astype(np.float32))
            self._g_resident.set(self.resident_bytes())
