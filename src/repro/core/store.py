"""In-memory model store for the federation controller.

MetisFL's controller keeps every learner's latest local model in an in-memory
hash map (the paper assumes all local models fit in memory and treats
insert/select as O(1); §5 sketches future on-disk/distributed stores).  This
module implements that store with the extra bookkeeping a production
controller needs: per-learner lineage, capacity-bounded eviction, and
aggregate byte accounting.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Iterator

import numpy as np

__all__ = ["ModelRecord", "ModelStore"]


@dataclasses.dataclass
class ModelRecord:
    learner_id: str
    round_id: int
    buffer: Any  # packed numeric buffer (jax.Array) or byte buffer
    num_examples: int  # aggregation weight source (FedAvg)
    metadata: dict = dataclasses.field(default_factory=dict)
    timestamp: float = dataclasses.field(default_factory=time.monotonic)

    @property
    def nbytes(self) -> int:
        b = self.buffer
        if hasattr(b, "nbytes"):
            return int(b.nbytes)
        return int(np.asarray(b).nbytes)


class ModelStore:
    """Hash-map model store with per-learner lineage and eviction.

    ``lineage_length`` bounds how many historical models per learner are kept
    (1 = paper's behaviour: latest only).  ``capacity_bytes`` optionally bounds
    total resident bytes; the oldest records across learners are evicted first
    (never the latest record of a learner — the controller must always be able
    to aggregate every registered learner).
    """

    def __init__(self, lineage_length: int = 1, capacity_bytes: int | None = None):
        if lineage_length < 1:
            raise ValueError("lineage_length must be >= 1")
        self._lineage_length = lineage_length
        self._capacity_bytes = capacity_bytes
        self._records: OrderedDict[str, list[ModelRecord]] = OrderedDict()
        self.total_inserts = 0

    # -- insertion ---------------------------------------------------------
    def insert(self, record: ModelRecord) -> None:
        lineage = self._records.setdefault(record.learner_id, [])
        lineage.append(record)
        self.total_inserts += 1
        if len(lineage) > self._lineage_length:
            del lineage[: len(lineage) - self._lineage_length]
        self._maybe_evict()

    def _maybe_evict(self) -> None:
        if self._capacity_bytes is None:
            return
        while self.resident_bytes() > self._capacity_bytes:
            victim: ModelRecord | None = None
            for lineage in self._records.values():
                # candidates: everything but the newest record per learner
                for rec in lineage[:-1]:
                    if victim is None or rec.timestamp < victim.timestamp:
                        victim = rec
            if victim is None:
                break  # only latest-per-learner remain; never evict those
            self._records[victim.learner_id].remove(victim)

    # -- selection ---------------------------------------------------------
    def latest(self, learner_id: str) -> ModelRecord:
        return self._records[learner_id][-1]

    def lineage(self, learner_id: str) -> list[ModelRecord]:
        return list(self._records.get(learner_id, []))

    def select_latest(self, learner_ids: list[str] | None = None) -> list[ModelRecord]:
        """The controller's 'model selection' step before aggregation."""
        ids = learner_ids if learner_ids is not None else list(self._records)
        return [self.latest(i) for i in ids if i in self._records]

    def __contains__(self, learner_id: str) -> bool:
        return learner_id in self._records

    def __iter__(self) -> Iterator[str]:
        return iter(self._records)

    def __len__(self) -> int:
        return len(self._records)

    # -- accounting ---------------------------------------------------------
    def resident_bytes(self) -> int:
        return sum(rec.nbytes for lin in self._records.values() for rec in lin)

    def num_records(self) -> int:
        return sum(len(lin) for lin in self._records.values())
