"""The event-driven round engine: one arrival-driven loop for every protocol.

MetisFL's core claim is that the controller *manages the execution of FL
workflows* as a first-class citizen.  This module is where that management
lives: instead of one hard-coded loop per protocol, a single
:meth:`RoundEngine.run` loop consumes **typed events** and delegates every
protocol-specific decision to the pluggable :class:`~repro.core.scheduler.
ProtocolPolicy` hooks (``select_cohort`` / ``size_task`` /
``should_aggregate`` / ``weighting``).  The controller shrinks to model-state
+ transport + store plumbing (``core/controller.py``); the engine owns the
dispatch executor and the control flow.

Event grammar (one loop, four workflows):

* :class:`Dispatched` — a task left the controller for a learner (logged at
  dispatch; the wire payload is the shared serialize-once broadcast).
* :class:`UploadArrived` — a learner's ``LocalUpdate`` came off the measured
  uplink.  Posted from executor threads via the thread-safe
  :meth:`RoundEngine.post`; the loop ingests it (arena/store write + EWMA
  profile update) and asks ``policy.should_aggregate``.
* :class:`AggregateFired` — the policy said aggregate: full-cohort FedAvg
  for round-based policies, staleness-damped community update (optionally
  through a per-epoch secure mask session) for the continuous one.
* :class:`Evaluated` — the post-aggregation eval fan-out reduced its
  reports (round-based policies only).

Arrival order is whatever the executor produces — the loop is the only
consumer, so all state mutation is serialized without protocol code ever
touching a lock.  ``tests/test_engine.py`` hammers ``post`` from 16 threads
posting ``UploadArrived`` out of order to pin that contract.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import TYPE_CHECKING, Any

from repro.core.journal import EventJournal
from repro.core.learner import EvalReport, LocalUpdate
from repro.core.metrics import Telemetry
from repro.core.scheduler import TrainTask

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from repro.core.controller import Controller

__all__ = [
    "RoundTimings",
    "Dispatched",
    "UploadArrived",
    "UploadRejected",
    "UploadClipped",
    "LearnerQuarantined",
    "UploadRejectedError",
    "AggregateFired",
    "DeadlineExpired",
    "Evaluated",
    "EngineStopped",
    "RoundEngine",
]


class UploadRejectedError(Exception):
    """Raised by ``Controller.ingest`` when admission control rejects an upload.

    The engine loop catches it and treats the arrival like a lost upload
    (quorum shrinks, reputation penalized, typed journal record) — the
    buffer never touches the arena or the store.
    """

    def __init__(self, learner_id: str, reason: str, norm: float):
        super().__init__(f"upload from {learner_id!r} rejected: {reason} "
                         f"(norm={norm!r})")
        self.learner_id = learner_id
        self.reason = reason
        self.norm = norm


@dataclasses.dataclass
class RoundTimings:
    """The six per-operation wall-clock measurements of the paper's Figs 5-7."""

    round_id: int = -1
    train_dispatch_s: float = 0.0
    train_round_s: float = 0.0
    aggregation_s: float = 0.0
    eval_dispatch_s: float = 0.0
    eval_round_s: float = 0.0
    federation_round_s: float = 0.0
    metrics: dict = dataclasses.field(default_factory=dict)

    def as_row(self) -> dict:
        """Flatten to one dict row for the CSV/JSON benchmark output."""
        return {
            "round": self.round_id,
            "train_dispatch_s": self.train_dispatch_s,
            "train_round_s": self.train_round_s,
            "aggregation_s": self.aggregation_s,
            "eval_dispatch_s": self.eval_dispatch_s,
            "eval_round_s": self.eval_round_s,
            "federation_round_s": self.federation_round_s,
        }


# ---------------------------------------------------------------------------
# Typed events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Dispatched:
    """A TrainTask left for a learner (RunTask fire-and-forget)."""

    round_id: int
    learner_id: str
    task: TrainTask


@dataclasses.dataclass(frozen=True)
class UploadArrived:
    """A learner's completed LocalUpdate arrived off the measured uplink.

    ``error`` carries a learner-side exception instead of an update; the
    engine loop re-raises it on the caller's thread (the paper's
    MarkTaskCompleted failure surface).
    """

    update: LocalUpdate | None
    error: BaseException | None = None
    #: True for the engine-requeued second delivery of a fault-injected
    #: duplicated upload; duplicates do not consume an outstanding slot.
    duplicate: bool = False

    @property
    def learner_id(self) -> str | None:
        """The arriving learner (None for a failed task with no update)."""
        return self.update.learner_id if self.update is not None else None


@dataclasses.dataclass(frozen=True)
class UploadRejected:
    """Admission control refused an arrived upload (it never reached a store).

    ``reason`` is the screen that fired (``"nonfinite"``); ``norm`` is the
    L2 norm the screen measured (NaN/inf for the non-finite screen).  The
    journal serializes this as its own typed record, so ``replay()`` can
    say *why* a learner's row is missing from the round's reduce.
    """

    round_id: int
    learner_id: str
    reason: str
    norm: float


@dataclasses.dataclass(frozen=True)
class UploadClipped:
    """Admission control norm-clipped an outlier upload before ingest.

    The row *was* ingested, rescaled from ``norm`` down to ``limit`` (the
    clip ceiling derived from the EWMA of accepted update norms).
    """

    round_id: int
    learner_id: str
    norm: float
    limit: float


@dataclasses.dataclass(frozen=True)
class LearnerQuarantined:
    """A repeat offender crossed the quarantine threshold.

    The learner is excluded from cohort selection until its decaying
    offense score (``score`` at entry) falls back below the threshold.
    """

    round_id: int
    learner_id: str
    score: float


@dataclasses.dataclass(frozen=True)
class AggregateFired:
    """The policy decided to aggregate (cohort complete / every arrival)."""

    round_id: int
    n_arrived: int
    trigger: str | None = None  # the arriving learner, for continuous re-dispatch
    #: Buffered-async (FedBuff) only: the exact learner ids folded into this
    #: community update (None for round-based / plain-async aggregates).
    members: tuple | None = None


@dataclasses.dataclass(frozen=True)
class DeadlineExpired:
    """A round's wall-clock deadline elapsed (DeadlineCohortProtocol).

    Posted by the per-round timer; the loop fires a *partial* aggregate over
    whatever arrived, and stragglers fold into the next round as late
    uploads.  Ignored (logged only) when the round already aggregated.
    """

    round_id: int


@dataclasses.dataclass(frozen=True)
class Evaluated:
    """The post-aggregation eval fan-out reduced its reports."""

    round_id: int
    metrics: dict


@dataclasses.dataclass(frozen=True)
class EngineStopped:
    """A ``run()`` call ended — the journal's flush-on-stop marker.

    ``completed`` counts the rounds / community updates that finished in
    that call; ``error`` carries the repr of an escaping exception (None on
    a clean return).  Recording this event synchronously flushes the
    journal's file sink, so when ``run()`` returns the JSONL on disk is
    complete.
    """

    completed: int
    error: str | None = None


@dataclasses.dataclass
class _RoundState:
    """Book-keeping for the in-flight round (cohort, arrivals, timings)."""

    round_id: int
    cohort: list[str]
    timings: RoundTimings
    t_round: float  # round start (includes cohort selection)
    t_train: float = 0.0  # dispatch start (the T1 mark train_round_s runs from)
    arrived: int = 0
    # Cohort members whose upload landed (dispatch order preserved by
    # iterating `cohort` at aggregation, so stack-mode reduces stay
    # deterministic); `dropped` holds members that can no longer arrive
    # (deregistered mid-round / upload lost) — the quorum shrinks to match.
    arrived_ids: set = dataclasses.field(default_factory=set)
    dropped: set = dataclasses.field(default_factory=set)
    aggregated: bool = False
    deadline_timer: Any = None


def reduce_eval(reports: list[EvalReport]) -> dict:
    """Example-weighted mean of per-learner eval metrics."""
    if not reports:
        return {}
    keys = reports[0].metrics.keys()
    total = sum(r.num_examples for r in reports)
    return {
        k: sum(r.metrics[k] * r.num_examples for r in reports) / max(total, 1)
        for k in keys
    }


class RoundEngine:
    """One arrival-driven loop driving every federation workflow.

    The engine owns the dispatch :class:`ThreadPoolExecutor` and the event
    queue; the :class:`~repro.core.controller.Controller` owns model state,
    transport and stores.  ``run(rounds=N)`` drives round-based policies
    (sync / semi-sync, secure or not); ``run(total_updates=N)`` drives the
    continuous (async) policy, secure or not — same loop, same events, the
    policy hooks decide everything protocol-specific.

    Thread contract: :meth:`post` is the only entry point for worker
    threads; every event is *processed* on the single thread inside
    :meth:`run`, so ingest, aggregation and round bookkeeping are serialized
    by construction.  ``event_log`` (bounded) records the typed event
    objects in processing order for tests; ``journal`` (the
    :class:`~repro.core.journal.EventJournal` flight recorder) records their
    serialized form alongside, with optional JSONL persistence and a
    ``replay()`` API — see ``docs/OBSERVABILITY.md``.
    """

    def __init__(
        self,
        controller: "Controller",
        max_dispatch_workers: int = 32,
        journal: EventJournal | None = None,
    ):
        self.controller = controller
        self._executor = ThreadPoolExecutor(max_workers=max_dispatch_workers)
        self._events: queue.Queue = queue.Queue()
        self.event_log: collections.deque = collections.deque(maxlen=4096)
        self.journal = journal if journal is not None else EventJournal()
        self.telemetry: Telemetry = (
            getattr(controller, "telemetry", None) or Telemetry()
        )
        self._h_round_s = self.telemetry.histogram("engine.round_s")
        self._h_aggregate_s = self.telemetry.histogram("engine.aggregate_s")
        self._g_round = self.telemetry.gauge("engine.round_id")
        self._c_orphaned = self.telemetry.counter("engine.uploads.orphaned")
        self._c_lost = self.telemetry.counter("engine.faults.uploads_lost")
        self._c_dup = self.telemetry.counter("engine.faults.uploads_duplicated")
        self._c_late = self.telemetry.counter("engine.faults.uploads_late")
        self._c_deadline = self.telemetry.counter("engine.faults.deadline_fires")
        self.aggregates_fired = 0  # lifetime AggregateFired count
        self._outstanding = 0  # dispatched-but-not-arrived tasks (loop thread only)
        # Continuous-policy state that outlives a single run() call (and is
        # checkpointed): the FedBuff arrival buffer, stragglers owed to the
        # next round-based aggregate, and the dispatch list a restored
        # checkpoint owes its first round.
        self._buffer: list[str] = []
        self._late_carry: list[str] = []
        self._resume_dispatch: list[str] | None = None
        self._pending_dispatch: list[str] | None = None  # set around save_checkpoint
        # Continuous-mode learners whose upload was lost while a
        # pre-checkpoint drain was absorbing arrivals (fire=False, so the
        # usual immediate retry leg must not run): they are owed a
        # re-dispatch once the checkpoint completes, and are folded into
        # the checkpointed pending-dispatch list so a restored run owes
        # them too.  Without this they would silently leave the rotation
        # and a buffer_k == fleet-size policy could never fill its buffer.
        self._retry_pending: list[str] = []
        # Loop-thread mirror of channel.upload_bytes: advanced as arrivals
        # are *processed*, so aggregate records carry a deterministic
        # cumulative uplink total (the raw counter is bumped by executor
        # workers mid-flight — reading it at fire time would be racy).
        self._up_bytes_seen = 0

    # -- event plumbing -----------------------------------------------------
    def post(self, event: Any) -> None:
        """Thread-safe: enqueue an event for the engine loop (arrival order)."""
        self._events.put(event)

    def _log(self, event: Any, **context: Any) -> None:
        # Processing order == log order: only the loop thread appends.  The
        # journal gets the same event plus engine-attached context (byte
        # sizes, staleness, model version) in its serialized record.
        self.event_log.append(event)
        self.journal.record(event, **context)

    # -- dispatch -----------------------------------------------------------
    def _submit(self, lid: str, task: TrainTask, envelope: Any) -> None:
        """Fire-and-forget one task: recv + fit on a worker, post the arrival."""
        c = self.controller
        # Captured now, not looked up at execution time: a learner
        # deregistered while its task is in flight still finishes the fit
        # and its arrival takes the orphaned-upload path, instead of a
        # KeyError surfacing from the worker.
        learner = c._learners[lid]

        def work() -> None:
            try:
                params = c.channel.recv(envelope)
                update = learner.fit(params, task)
                self.post(UploadArrived(update=update))
            except BaseException as exc:  # surfaced on the loop thread
                self.post(UploadArrived(update=None, error=exc))

        self._executor.submit(work)
        # Counted only after a successful submit: a rejected submission
        # (executor shut down) must not leave the loop waiting forever.
        self._outstanding += 1

    def _dispatch_one(self, lid: str, broadcast: Any) -> TrainTask:
        """Size (wire-cost aware) and dispatch one learner's task."""
        c = self.controller
        c._learner_versions[lid] = c._model_version
        task = c.protocol.size_task(
            c.round_id, c._learner_profiles[lid], wire_s=c.wire_time_s(lid)
        )
        envelope = broadcast.to({"task": task, "learner_id": lid})
        self._submit(lid, task, envelope)
        self._log(
            Dispatched(round_id=c.round_id, learner_id=lid, task=task),
            model_version=c._model_version,
            down_bytes=int(envelope.buffer.nbytes),
        )
        return task

    def _start_round(self) -> _RoundState:
        """Select the cohort and fan its tasks out (paper T1-T3)."""
        c = self.controller
        continuous = bool(getattr(c.protocol, "continuous", False))
        state = _RoundState(
            round_id=c.round_id,
            cohort=[],
            timings=RoundTimings(round_id=c.round_id),
            t_round=time.perf_counter(),
        )
        # Quarantined repeat offenders (rejected/clipped uploads, tracked by
        # the controller's decaying offense score) sit out cohort selection
        # entirely — the policy never sees them.  Fail-open: if *every*
        # learner is quarantined the filter is skipped, so a poisoned fleet
        # degrades to the pre-quarantine behaviour instead of deadlocking.
        available = c.learner_ids
        eligible = [lid for lid in available if not c.is_quarantined(lid)]
        if eligible:
            available = eligible
        kwargs: dict[str, Any] = {}
        if getattr(c.protocol, "needs_profiles", False):
            # Ranking/predicting policies additionally see the EWMA profiles
            # and each learner's modeled round-trip wire time.
            kwargs["profiles"] = c._learner_profiles
            kwargs["wire_s"] = {lid: c.wire_time_s(lid) for lid in available}
        state.cohort = c.protocol.select_cohort(
            c.selection,
            available,
            c.round_id,
            {lid: c._learners[lid].num_examples for lid in available},
            **kwargs,
        )
        if continuous:
            if self._resume_dispatch is not None:
                # A restored checkpoint owes exactly the dispatches that were
                # about to leave when the state was saved.
                state.cohort = [
                    lid for lid in self._resume_dispatch if lid in c._learners
                ]
                self._resume_dispatch = None
            else:
                # Learners already sitting in the FedBuff buffer have an
                # ingested-but-unaggregated row; re-dispatching them would
                # overwrite it before it is reduced.
                buffered = set(self._buffer)
                state.cohort = [lid for lid in state.cohort if lid not in buffered]
        if not state.cohort and not self._buffer:
            # An empty cohort would leave the loop waiting on arrivals that
            # can never come — fail loudly instead (mirrors the aggregation
            # path's empty-cohort error).
            raise RuntimeError("no learners selected for dispatch")
        state.t_train = time.perf_counter()
        broadcast = c._broadcast() if state.cohort else None
        for lid in state.cohort:
            self._dispatch_one(lid, broadcast)
        state.timings.train_dispatch_s = time.perf_counter() - state.t_train
        deadline = getattr(c.protocol, "deadline_s", None)
        if (not continuous and deadline is not None
                and getattr(c.protocol, "enforce_wall_clock", False)):
            timer = threading.Timer(
                float(deadline),
                lambda rid=state.round_id: self.post(DeadlineExpired(round_id=rid)),
            )
            timer.daemon = True
            timer.start()
            state.deadline_timer = timer
        return state

    # -- evaluation ---------------------------------------------------------
    def _evaluate(self, state: _RoundState) -> None:
        """Synchronous EvaluateModel fan-out (paper Fig. 10, T7-T9).

        Shares the post-aggregation model's single serialization with the
        next round's train dispatch (both read the same version's broadcast).
        """
        c = self.controller
        t0 = time.perf_counter()
        broadcast = c._broadcast()
        futures = []
        # Members that deregistered mid-round are skipped, not fatal.
        for lid in [x for x in state.cohort if x in c._learners]:
            envelope = broadcast.to({"eval": True})

            def run(lid=lid, envelope=envelope) -> EvalReport:
                params = c.channel.recv(envelope)
                return c._learners[lid].evaluate(params, c.round_id)

            futures.append(self._executor.submit(run))
        state.timings.eval_dispatch_s = time.perf_counter() - t0
        reports = [f.result() for f in futures]
        state.timings.eval_round_s = time.perf_counter() - t0
        state.timings.metrics = reduce_eval(reports)
        self._log(Evaluated(round_id=state.round_id, metrics=state.timings.metrics))

    # -- the loop -----------------------------------------------------------
    def run(
        self,
        rounds: int | None = None,
        total_updates: int | None = None,
        checkpoint_every: int | None = None,
        checkpoint_dir: str | None = None,
    ) -> list[RoundTimings]:
        """Drive the federation: ``rounds=`` for round-based policies,
        ``total_updates=`` for the continuous (async) one.

        Returns one :class:`RoundTimings` per completed round / community
        update (continuous runs may append a few extra entries: tasks still
        in flight when the target is reached are drained and — matching the
        paper's per-arrival semantics — still aggregated).

        ``checkpoint_every=k`` persists the full federation state
        (``Controller.save_checkpoint``: global model + version + learner
        profiles + store contents + journal cursor) every k completed
        rounds, *before* the next round's dispatch — so a killed run
        restores at a round boundary and replays forward bit-identically
        (``tests/test_checkpoint_resume.py``).  Both knobs default to the
        controller's ``checkpoint_every``/``checkpoint_dir`` configuration.
        """
        c = self.controller
        if c.global_params is None:
            raise RuntimeError("set_initial_model() before running rounds")
        if checkpoint_every is None:
            checkpoint_every = getattr(c, "checkpoint_every", None)
        if checkpoint_dir is None:
            checkpoint_dir = getattr(c, "checkpoint_dir", None)
        ckpt_every = int(checkpoint_every or 0)
        continuous = bool(getattr(c.protocol, "continuous", False))
        if continuous:
            if total_updates is None:
                raise TypeError("continuous (async) policies need total_updates=")
            target = int(total_updates)
        else:
            if rounds is None:
                raise TypeError("round-based policies need rounds=")
            if total_updates is not None:
                raise TypeError("total_updates= requires a continuous (async) policy")
            target = int(rounds)
        if target <= 0:
            return []

        out: list[RoundTimings] = []
        completed = 0
        state: _RoundState | None = None

        def drain_outstanding() -> None:
            # Absorb every in-flight arrival into engine state (buffer /
            # arrived set / late carry) WITHOUT firing aggregates, so the
            # state written by a checkpoint is quiescent: nothing the golden
            # run will later fold in depends on an unsaved model version.
            while self._outstanding > 0:
                ev = self._events.get()
                if isinstance(ev, UploadArrived):
                    handle_upload(ev, fire=False)
                else:
                    self._log(ev)

        def maybe_checkpoint(pending: list[str] | None = None) -> None:
            # At a round boundary, before the next dispatch: the saved state
            # has no partial-round arrivals to reconcile on restore.
            if ckpt_every and checkpoint_dir and c.round_id % ckpt_every == 0:
                drain_outstanding()
                pend = list(pending) if pending is not None else None
                if self._retry_pending:
                    # The drain may have absorbed lost uploads: those
                    # learners' retry legs are part of the dispatches a
                    # restored run owes, alongside the buffer members.
                    pend = pend if pend is not None else []
                    pend += [x for x in self._retry_pending if x not in pend]
                self._pending_dispatch = pend
                try:
                    c.save_checkpoint(checkpoint_dir)
                finally:
                    self._pending_dispatch = None

        def fire_round(trigger: str | None, partial: bool = False) -> None:
            # Round-based aggregate: reduce what arrived (plus carried-over
            # stragglers), evaluate, advance the round.
            nonlocal state, completed
            if state.deadline_timer is not None:
                state.deadline_timer.cancel()
                state.deadline_timer = None
            ctx: dict[str, Any] = dict(
                weighting=c.protocol.weighting(),
                model_version=c._model_version,
                bytes_down=self.telemetry.value("channel.bytes_moved"),
                bytes_up=self._up_bytes_seen,
            )
            if partial:
                ctx["partial"] = True
            self._log(
                AggregateFired(
                    round_id=state.round_id,
                    n_arrived=state.arrived,
                    trigger=trigger,
                ),
                **ctx,
            )
            self.aggregates_fired += 1
            state.timings.train_round_s = time.perf_counter() - state.t_train
            state.timings.aggregation_s = self._aggregate(state)
            state.aggregated = True
            self._evaluate(state)
            state.timings.federation_round_s = time.perf_counter() - state.t_round
            out.append(state.timings)
            c.history.append(state.timings)
            c.round_id += 1
            completed += 1
            self._observe_round(state.timings)
            maybe_checkpoint()
            if completed < target:
                state = self._start_round()

        def check_round_progress(trigger: str | None) -> None:
            # Quorum check for round-based policies after any arrival /
            # dropout: the effective cohort excludes members that can no
            # longer deliver, so a shrunken round still completes.
            if state.aggregated:
                return
            effective = len(state.cohort) - len(state.dropped)
            if effective <= 0:
                if state.arrived > 0:
                    fire_round(trigger, partial=True)
                elif self._outstanding == 0 and self._events.empty():
                    raise RuntimeError(
                        "every learner in the cohort dropped out mid-round"
                    )
                return
            if c.protocol.should_aggregate(state.arrived, effective):
                fire_round(trigger)

        def pump_continuous() -> None:
            # Continuous aggregate pump: fire while the buffer satisfies the
            # policy (a post-checkpoint drain may have refilled it).  Plain
            # async keeps its aggregate-per-arrival semantics (buffer of 1);
            # FedBuff drains K members into one staleness-weighted update.
            nonlocal completed
            while self._buffer and c.protocol.should_aggregate(
                len(self._buffer), max(1, len(c._learners))
            ):
                members = tuple(self._buffer)
                self._buffer.clear()
                self._log(
                    AggregateFired(
                        round_id=state.round_id,
                        n_arrived=len(members),
                        trigger=members[-1],
                        members=members,
                    ),
                    weighting=c.protocol.weighting(),
                    model_version=c._model_version,
                    bytes_down=self.telemetry.value("channel.bytes_moved"),
                    bytes_up=self._up_bytes_seen,
                )
                self.aggregates_fired += 1
                timings = RoundTimings(round_id=c.round_id)
                timings.aggregation_s = self._aggregate(state, members)
                timings.federation_round_s = timings.aggregation_s
                out.append(timings)
                c.history.append(timings)
                c.round_id += 1
                completed += 1
                self._observe_round(timings)
                # The members get the fresh model at once (shared broadcast
                # per model version); checkpointed first so a restored run
                # owes exactly these dispatches.
                redisp = [lid for lid in members if lid in c._learners]
                maybe_checkpoint(pending=redisp)
                if completed < target:
                    # Lost-during-drain learners rejoin the rotation with
                    # the buffer members, all off one shared broadcast.
                    redisp += [
                        lid for lid in self._retry_pending
                        if lid in c._learners and lid not in redisp
                    ]
                    b = c._broadcast()
                    for lid in redisp:
                        self._dispatch_one(lid, b)
                self._retry_pending = []

        def handle_upload(event: UploadArrived, fire: bool = True) -> None:
            nonlocal completed
            if not event.duplicate:
                self._outstanding -= 1
            if event.error is not None:
                self._log(event)
                raise event.error
            lid = event.learner_id
            up = event.update.upload
            staleness = c._model_version - c._learner_versions.get(lid, 0)
            up_bytes = int(up.payload.nbytes) if up is not None else None
            fault = up.metadata.get("fault") if up is not None else None
            if up_bytes is not None and not event.duplicate:
                # A duplicate delivery re-uses the envelope: one wire send.
                self._up_bytes_seen += up_bytes
            if lid not in c._learners:
                # Orphaned: the learner deregistered (dropped out) while its
                # task was in flight.  Tolerated and counted, never fatal.
                self._c_orphaned.add(1)
                self._log(event, staleness=staleness, up_bytes=up_bytes,
                          orphaned=True)
                prof = c._learner_profiles.get(lid)
                if prof is not None:
                    prof.observe_contribution(0.0)
                if not continuous and not state.aggregated:
                    if lid in state.cohort and lid not in state.arrived_ids:
                        state.dropped.add(lid)
                    if fire:
                        check_round_progress(lid)
                return
            if fault == "lost":
                # The uplink dropped the payload: nothing to ingest.
                self._c_lost.add(1)
                self._log(event, staleness=staleness, up_bytes=up_bytes,
                          lost=True)
                prof = c._learner_profiles.get(lid)
                if prof is not None:
                    prof.observe_contribution(0.0)
                if continuous:
                    if fire:
                        if completed < target:
                            self._dispatch_one(lid, c._broadcast())  # retry a leg
                    elif lid not in self._retry_pending:
                        # Lost during the pre-checkpoint drain: dispatching
                        # now would un-quiesce the state being saved, so
                        # the retry leg is owed after the checkpoint.
                        self._retry_pending.append(lid)
                elif not state.aggregated:
                    if lid in state.cohort and lid not in state.arrived_ids:
                        state.dropped.add(lid)
                    if fire:
                        check_round_progress(lid)
                return
            ctx: dict[str, Any] = {"staleness": staleness, "up_bytes": up_bytes}
            if event.duplicate:
                ctx["duplicate"] = True
            self._log(event, **ctx)
            clip = None
            try:
                if up is None and not event.duplicate:
                    # Legacy envelope-less update: ingest runs the measured
                    # upload half itself, on this thread — mirror its bytes.
                    before = self.telemetry.value("channel.upload_bytes")
                    clip = c.ingest(event.update)
                    self._up_bytes_seen += int(
                        self.telemetry.value("channel.upload_bytes") - before
                    )
                else:
                    clip = c.ingest(event.update)
            except UploadRejectedError as rej:
                # Admission control refused the row: nothing was stored.
                # Bookkeeping mirrors a lost upload — the quorum shrinks,
                # the learner's reputation takes the full penalty, and the
                # journal gets a typed record saying why the row is absent.
                self._log(
                    UploadRejected(
                        round_id=int(event.update.round_id),
                        learner_id=lid,
                        reason=rej.reason,
                        norm=float(rej.norm),
                    ),
                )
                prof = c._learner_profiles.get(lid)
                if prof is not None:
                    prof.observe_contribution(0.0)
                self._note_offense(lid)
                if continuous:
                    if fire:
                        if completed < target:
                            self._dispatch_one(lid, c._broadcast())  # retry leg
                    elif lid not in self._retry_pending:
                        self._retry_pending.append(lid)
                elif not state.aggregated:
                    if lid in state.cohort and lid not in state.arrived_ids:
                        state.dropped.add(lid)
                    if fire:
                        check_round_progress(lid)
                return
            if clip is not None and not event.duplicate:
                # The row was ingested rescaled; half reputation credit and
                # an offense mark (repeat clipping quarantines too).
                self._log(
                    UploadClipped(
                        round_id=int(event.update.round_id),
                        learner_id=lid,
                        norm=float(clip["norm"]),
                        limit=float(clip["limit"]),
                    ),
                )
                self._note_offense(lid)
            if not event.duplicate:
                prof = c._learner_profiles.get(lid)
                if prof is not None:
                    prof.observe_contribution(
                        0.5 if clip is not None else 1.0
                    )
            if fault == "dup" and not event.duplicate:
                # The uplink delivered twice: the second copy is handled
                # inline, right after the first — posting it through the
                # queue would interleave with worker arrivals and make
                # journal order timing-dependent.  The recursion performs
                # the buffer/arrived bookkeeping for this learner (same
                # id, same update) and may fire an aggregate that
                # advances the round and clears the buffer — so this
                # frame must not fall through, or it would re-register
                # an already-aggregated arrival (phantom buffer member /
                # spurious late carry).
                self._c_dup.add(1)
                handle_upload(
                    dataclasses.replace(event, duplicate=True), fire=fire
                )
                return
            if continuous:
                if lid not in self._buffer:
                    self._buffer.append(lid)
                if fire:
                    pump_continuous()
                return
            if int(event.update.round_id) < c.round_id or state.aggregated:
                # Straggler from an already-aggregated round (deadline fired
                # without it): folded into the next round's reduce.
                self._c_late.add(1)
                if lid not in self._late_carry:
                    self._late_carry.append(lid)
                if fire and not state.aggregated:
                    check_round_progress(lid)  # deadlock check, never a count
                return
            if lid in state.cohort and lid not in state.arrived_ids:
                state.arrived_ids.add(lid)
                state.arrived += 1
            if fire:
                check_round_progress(lid)

        try:
            state = self._start_round()
            if continuous:
                # A restored FedBuff buffer may already satisfy the policy.
                pump_continuous()
            # One loop for every workflow: pop an event, mutate round state,
            # let the policy decide what fires next.  Terminates when the
            # target is met AND nothing is in flight or queued.
            while (completed < target or self._outstanding > 0
                   or not self._events.empty()):
                event = self._events.get()
                if isinstance(event, UploadArrived):
                    handle_upload(event)
                elif isinstance(event, DeadlineExpired):
                    if (not continuous and not state.aggregated
                            and event.round_id == state.round_id
                            and state.arrived > 0):
                        self._c_deadline.add(1)
                        self._log(event)
                        fire_round(trigger=None, partial=True)
                    else:  # stale timer (round already aggregated): log only
                        self._log(event)
                else:  # externally posted / unknown events: logged, not fatal
                    self._log(event)
        except BaseException as exc:
            if state is not None and state.deadline_timer is not None:
                state.deadline_timer.cancel()
            self._abort()
            self._log(EngineStopped(completed=completed, error=repr(exc)))
            raise
        if state is not None and state.deadline_timer is not None:
            state.deadline_timer.cancel()
        self._log(EngineStopped(completed=completed))
        return out

    def _note_offense(self, lid: str) -> None:
        """Record one admission offense; journal a quarantine entry if it
        tipped the learner's decaying score over the threshold."""
        c = self.controller
        if c.note_offense(lid):
            self._log(
                LearnerQuarantined(
                    round_id=int(c.round_id),
                    learner_id=lid,
                    score=float(c.offense_score(lid)),
                )
            )

    def _observe_round(self, timings: RoundTimings) -> None:
        """Fold one completed round into the engine's telemetry instruments."""
        self._h_round_s.observe(timings.federation_round_s)
        self._h_aggregate_s.observe(timings.aggregation_s)
        self._g_round.set(self.controller.round_id)

    def _take_late(self) -> list[str]:
        """Consume the stragglers owed to the next round-based aggregate."""
        late, self._late_carry = self._late_carry, []
        return late

    def _aggregate(self, state: _RoundState, members: tuple | None = None) -> float:
        """Reduce per the policy's weighting hook; returns the agg seconds.

        ``aggregate_scope == "buffer"`` (FedBuff) reduces exactly the
        buffered ``members``; ``"staleness"`` aggregates every valid stored
        model with staleness-damped weights (the continuous/community
        semantics, secure or clear); anything else is the cohort FedAvg /
        secure-sum round reduce over the members that actually arrived,
        plus any stragglers carried over from a deadline-expired round.
        """
        c = self.controller
        if getattr(c.protocol, "aggregate_scope", None) == "buffer":
            return c.aggregate_buffer(list(members or ()))
        if c.protocol.weighting() == "staleness":
            return c.aggregate_community()
        live = [lid for lid in state.cohort if lid in state.arrived_ids]
        seen = set(live)
        extras = [
            lid for lid in self._take_late()
            if lid not in seen and lid in c._learners
        ]
        return c.aggregate_round(live + extras)

    def _abort(self) -> None:
        """Leave the engine re-runnable after an error escapes the loop.

        Blocks until every dispatched-but-unarrived task posts (exactly the
        barrier the legacy ``wait(futures)`` error path provided), then
        discards whatever is left in the queue — stale arrivals or pending
        duplicate deliveries must not leak into a later ``run()``'s round
        accounting.
        """
        while self._outstanding > 0:
            ev = self._events.get()
            if isinstance(ev, UploadArrived) and not ev.duplicate:
                self._outstanding -= 1
        while not self._events.empty():
            self._events.get_nowait()

    # -- lifecycle ----------------------------------------------------------
    def shutdown(self) -> None:
        """Stop the dispatch executor (waits for in-flight tasks) and close
        the journal (final flush; an owned sink file is closed)."""
        self._executor.shutdown(wait=True)
        self.journal.close()
