"""The event-driven round engine: one arrival-driven loop for every protocol.

MetisFL's core claim is that the controller *manages the execution of FL
workflows* as a first-class citizen.  This module is where that management
lives: instead of one hard-coded loop per protocol, a single
:meth:`RoundEngine.run` loop consumes **typed events** and delegates every
protocol-specific decision to the pluggable :class:`~repro.core.scheduler.
ProtocolPolicy` hooks (``select_cohort`` / ``size_task`` /
``should_aggregate`` / ``weighting``).  The controller shrinks to model-state
+ transport + store plumbing (``core/controller.py``); the engine owns the
dispatch executor and the control flow.

Event grammar (one loop, four workflows):

* :class:`Dispatched` — a task left the controller for a learner (logged at
  dispatch; the wire payload is the shared serialize-once broadcast).
* :class:`UploadArrived` — a learner's ``LocalUpdate`` came off the measured
  uplink.  Posted from executor threads via the thread-safe
  :meth:`RoundEngine.post`; the loop ingests it (arena/store write + EWMA
  profile update) and asks ``policy.should_aggregate``.
* :class:`AggregateFired` — the policy said aggregate: full-cohort FedAvg
  for round-based policies, staleness-damped community update (optionally
  through a per-epoch secure mask session) for the continuous one.
* :class:`Evaluated` — the post-aggregation eval fan-out reduced its
  reports (round-based policies only).

Arrival order is whatever the executor produces — the loop is the only
consumer, so all state mutation is serialized without protocol code ever
touching a lock.  ``tests/test_engine.py`` hammers ``post`` from 16 threads
posting ``UploadArrived`` out of order to pin that contract.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import time
from concurrent.futures import ThreadPoolExecutor
from typing import TYPE_CHECKING, Any

from repro.core.journal import EventJournal
from repro.core.learner import EvalReport, LocalUpdate
from repro.core.metrics import Telemetry
from repro.core.scheduler import TrainTask

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from repro.core.controller import Controller

__all__ = [
    "RoundTimings",
    "Dispatched",
    "UploadArrived",
    "AggregateFired",
    "Evaluated",
    "EngineStopped",
    "RoundEngine",
]


@dataclasses.dataclass
class RoundTimings:
    """The six per-operation wall-clock measurements of the paper's Figs 5-7."""

    round_id: int = -1
    train_dispatch_s: float = 0.0
    train_round_s: float = 0.0
    aggregation_s: float = 0.0
    eval_dispatch_s: float = 0.0
    eval_round_s: float = 0.0
    federation_round_s: float = 0.0
    metrics: dict = dataclasses.field(default_factory=dict)

    def as_row(self) -> dict:
        """Flatten to one dict row for the CSV/JSON benchmark output."""
        return {
            "round": self.round_id,
            "train_dispatch_s": self.train_dispatch_s,
            "train_round_s": self.train_round_s,
            "aggregation_s": self.aggregation_s,
            "eval_dispatch_s": self.eval_dispatch_s,
            "eval_round_s": self.eval_round_s,
            "federation_round_s": self.federation_round_s,
        }


# ---------------------------------------------------------------------------
# Typed events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Dispatched:
    """A TrainTask left for a learner (RunTask fire-and-forget)."""

    round_id: int
    learner_id: str
    task: TrainTask


@dataclasses.dataclass(frozen=True)
class UploadArrived:
    """A learner's completed LocalUpdate arrived off the measured uplink.

    ``error`` carries a learner-side exception instead of an update; the
    engine loop re-raises it on the caller's thread (the paper's
    MarkTaskCompleted failure surface).
    """

    update: LocalUpdate | None
    error: BaseException | None = None

    @property
    def learner_id(self) -> str | None:
        """The arriving learner (None for a failed task with no update)."""
        return self.update.learner_id if self.update is not None else None


@dataclasses.dataclass(frozen=True)
class AggregateFired:
    """The policy decided to aggregate (cohort complete / every arrival)."""

    round_id: int
    n_arrived: int
    trigger: str | None = None  # the arriving learner, for continuous re-dispatch


@dataclasses.dataclass(frozen=True)
class Evaluated:
    """The post-aggregation eval fan-out reduced its reports."""

    round_id: int
    metrics: dict


@dataclasses.dataclass(frozen=True)
class EngineStopped:
    """A ``run()`` call ended — the journal's flush-on-stop marker.

    ``completed`` counts the rounds / community updates that finished in
    that call; ``error`` carries the repr of an escaping exception (None on
    a clean return).  Recording this event synchronously flushes the
    journal's file sink, so when ``run()`` returns the JSONL on disk is
    complete.
    """

    completed: int
    error: str | None = None


@dataclasses.dataclass
class _RoundState:
    """Book-keeping for the in-flight round (cohort, arrivals, timings)."""

    round_id: int
    cohort: list[str]
    timings: RoundTimings
    t_round: float  # round start (includes cohort selection)
    t_train: float = 0.0  # dispatch start (the T1 mark train_round_s runs from)
    arrived: int = 0


def reduce_eval(reports: list[EvalReport]) -> dict:
    """Example-weighted mean of per-learner eval metrics."""
    if not reports:
        return {}
    keys = reports[0].metrics.keys()
    total = sum(r.num_examples for r in reports)
    return {
        k: sum(r.metrics[k] * r.num_examples for r in reports) / max(total, 1)
        for k in keys
    }


class RoundEngine:
    """One arrival-driven loop driving every federation workflow.

    The engine owns the dispatch :class:`ThreadPoolExecutor` and the event
    queue; the :class:`~repro.core.controller.Controller` owns model state,
    transport and stores.  ``run(rounds=N)`` drives round-based policies
    (sync / semi-sync, secure or not); ``run(total_updates=N)`` drives the
    continuous (async) policy, secure or not — same loop, same events, the
    policy hooks decide everything protocol-specific.

    Thread contract: :meth:`post` is the only entry point for worker
    threads; every event is *processed* on the single thread inside
    :meth:`run`, so ingest, aggregation and round bookkeeping are serialized
    by construction.  ``event_log`` (bounded) records the typed event
    objects in processing order for tests; ``journal`` (the
    :class:`~repro.core.journal.EventJournal` flight recorder) records their
    serialized form alongside, with optional JSONL persistence and a
    ``replay()`` API — see ``docs/OBSERVABILITY.md``.
    """

    def __init__(
        self,
        controller: "Controller",
        max_dispatch_workers: int = 32,
        journal: EventJournal | None = None,
    ):
        self.controller = controller
        self._executor = ThreadPoolExecutor(max_workers=max_dispatch_workers)
        self._events: queue.Queue = queue.Queue()
        self.event_log: collections.deque = collections.deque(maxlen=4096)
        self.journal = journal if journal is not None else EventJournal()
        self.telemetry: Telemetry = (
            getattr(controller, "telemetry", None) or Telemetry()
        )
        self._h_round_s = self.telemetry.histogram("engine.round_s")
        self._h_aggregate_s = self.telemetry.histogram("engine.aggregate_s")
        self._g_round = self.telemetry.gauge("engine.round_id")
        self.aggregates_fired = 0  # lifetime AggregateFired count
        self._outstanding = 0  # dispatched-but-not-arrived tasks (loop thread only)

    # -- event plumbing -----------------------------------------------------
    def post(self, event: Any) -> None:
        """Thread-safe: enqueue an event for the engine loop (arrival order)."""
        self._events.put(event)

    def _log(self, event: Any, **context: Any) -> None:
        # Processing order == log order: only the loop thread appends.  The
        # journal gets the same event plus engine-attached context (byte
        # sizes, staleness, model version) in its serialized record.
        self.event_log.append(event)
        self.journal.record(event, **context)

    # -- dispatch -----------------------------------------------------------
    def _submit(self, lid: str, task: TrainTask, envelope: Any) -> None:
        """Fire-and-forget one task: recv + fit on a worker, post the arrival."""
        c = self.controller

        def work() -> None:
            try:
                params = c.channel.recv(envelope)
                update = c._learners[lid].fit(params, task)
                self.post(UploadArrived(update=update))
            except BaseException as exc:  # surfaced on the loop thread
                self.post(UploadArrived(update=None, error=exc))

        self._executor.submit(work)
        # Counted only after a successful submit: a rejected submission
        # (executor shut down) must not leave the loop waiting forever.
        self._outstanding += 1

    def _dispatch_one(self, lid: str, broadcast: Any) -> TrainTask:
        """Size (wire-cost aware) and dispatch one learner's task."""
        c = self.controller
        c._learner_versions[lid] = c._model_version
        task = c.protocol.size_task(
            c.round_id, c._learner_profiles[lid], wire_s=c.wire_time_s(lid)
        )
        envelope = broadcast.to({"task": task})
        self._submit(lid, task, envelope)
        self._log(
            Dispatched(round_id=c.round_id, learner_id=lid, task=task),
            model_version=c._model_version,
            down_bytes=int(envelope.buffer.nbytes),
        )
        return task

    def _start_round(self) -> _RoundState:
        """Select the cohort and fan its tasks out (paper T1-T3)."""
        c = self.controller
        state = _RoundState(
            round_id=c.round_id,
            cohort=[],
            timings=RoundTimings(round_id=c.round_id),
            t_round=time.perf_counter(),
        )
        state.cohort = c.protocol.select_cohort(
            c.selection,
            c.learner_ids,
            c.round_id,
            {lid: ln.num_examples for lid, ln in c._learners.items()},
        )
        if not state.cohort:
            # An empty cohort would leave the loop waiting on arrivals that
            # can never come — fail loudly instead (mirrors the aggregation
            # path's empty-cohort error).
            raise RuntimeError("no learners selected for dispatch")
        state.t_train = time.perf_counter()
        broadcast = c._broadcast()
        for lid in state.cohort:
            self._dispatch_one(lid, broadcast)
        state.timings.train_dispatch_s = time.perf_counter() - state.t_train
        return state

    # -- evaluation ---------------------------------------------------------
    def _evaluate(self, state: _RoundState) -> None:
        """Synchronous EvaluateModel fan-out (paper Fig. 10, T7-T9).

        Shares the post-aggregation model's single serialization with the
        next round's train dispatch (both read the same version's broadcast).
        """
        c = self.controller
        t0 = time.perf_counter()
        broadcast = c._broadcast()
        futures = []
        for lid in state.cohort:
            envelope = broadcast.to({"eval": True})

            def run(lid=lid, envelope=envelope) -> EvalReport:
                params = c.channel.recv(envelope)
                return c._learners[lid].evaluate(params, c.round_id)

            futures.append(self._executor.submit(run))
        state.timings.eval_dispatch_s = time.perf_counter() - t0
        reports = [f.result() for f in futures]
        state.timings.eval_round_s = time.perf_counter() - t0
        state.timings.metrics = reduce_eval(reports)
        self._log(Evaluated(round_id=state.round_id, metrics=state.timings.metrics))

    # -- the loop -----------------------------------------------------------
    def run(
        self,
        rounds: int | None = None,
        total_updates: int | None = None,
        checkpoint_every: int | None = None,
        checkpoint_dir: str | None = None,
    ) -> list[RoundTimings]:
        """Drive the federation: ``rounds=`` for round-based policies,
        ``total_updates=`` for the continuous (async) one.

        Returns one :class:`RoundTimings` per completed round / community
        update (continuous runs may append a few extra entries: tasks still
        in flight when the target is reached are drained and — matching the
        paper's per-arrival semantics — still aggregated).

        ``checkpoint_every=k`` persists the full federation state
        (``Controller.save_checkpoint``: global model + version + learner
        profiles + store contents + journal cursor) every k completed
        rounds, *before* the next round's dispatch — so a killed run
        restores at a round boundary and replays forward bit-identically
        (``tests/test_checkpoint_resume.py``).  Both knobs default to the
        controller's ``checkpoint_every``/``checkpoint_dir`` configuration.
        """
        c = self.controller
        if c.global_params is None:
            raise RuntimeError("set_initial_model() before running rounds")
        if checkpoint_every is None:
            checkpoint_every = getattr(c, "checkpoint_every", None)
        if checkpoint_dir is None:
            checkpoint_dir = getattr(c, "checkpoint_dir", None)
        ckpt_every = int(checkpoint_every or 0)
        continuous = bool(getattr(c.protocol, "continuous", False))
        if continuous:
            if total_updates is None:
                raise TypeError("continuous (async) policies need total_updates=")
            target = int(total_updates)
        else:
            if rounds is None:
                raise TypeError("round-based policies need rounds=")
            if total_updates is not None:
                raise TypeError("total_updates= requires a continuous (async) policy")
            target = int(rounds)
        if target <= 0:
            return []

        out: list[RoundTimings] = []
        completed = 0

        def maybe_checkpoint() -> None:
            # At a round boundary, before the next dispatch: the saved state
            # has no partial-round arrivals to reconcile on restore.
            if ckpt_every and checkpoint_dir and c.round_id % ckpt_every == 0:
                c.save_checkpoint(checkpoint_dir)

        try:
            state = self._start_round()
            # One loop for every workflow: pop an event, mutate round state,
            # let the policy decide what fires next.  Terminates when the
            # target is met AND nothing is in flight or queued.
            while (completed < target or self._outstanding > 0
                   or not self._events.empty()):
                event = self._events.get()
                if isinstance(event, UploadArrived):
                    self._outstanding -= 1
                    if event.error is not None:
                        self._log(event)
                        raise event.error
                    up = event.update.upload
                    self._log(
                        event,
                        staleness=(
                            c._model_version
                            - c._learner_versions.get(event.learner_id, 0)
                        ),
                        up_bytes=(
                            int(up.payload.nbytes) if up is not None else None
                        ),
                    )
                    c.ingest(event.update)
                    state.arrived += 1
                    if c.protocol.should_aggregate(state.arrived, len(state.cohort)):
                        self.post(
                            AggregateFired(
                                round_id=state.round_id,
                                n_arrived=state.arrived,
                                trigger=event.learner_id,
                            )
                        )
                        if continuous:
                            state.arrived = 0
                elif isinstance(event, AggregateFired):
                    self._log(
                        event,
                        weighting=c.protocol.weighting(),
                        model_version=c._model_version,
                        bytes_down=self.telemetry.value("channel.bytes_moved"),
                        bytes_up=self.telemetry.value("channel.upload_bytes"),
                    )
                    self.aggregates_fired += 1
                    if continuous:
                        timings = RoundTimings(round_id=c.round_id)
                        timings.aggregation_s = self._aggregate(state)
                        timings.federation_round_s = timings.aggregation_s
                        out.append(timings)
                        c.history.append(timings)
                        c.round_id += 1
                        completed += 1
                        self._observe_round(timings)
                        maybe_checkpoint()
                        if completed < target and event.trigger is not None:
                            # The paper's async loop: the arriving learner
                            # gets the fresh model at once (shared broadcast
                            # per model version).
                            self._dispatch_one(event.trigger, c._broadcast())
                    else:
                        state.timings.train_round_s = (
                            time.perf_counter() - state.t_train
                        )
                        state.timings.aggregation_s = self._aggregate(state)
                        self._evaluate(state)
                        state.timings.federation_round_s = (
                            time.perf_counter() - state.t_round
                        )
                        out.append(state.timings)
                        c.history.append(state.timings)
                        c.round_id += 1
                        completed += 1
                        self._observe_round(state.timings)
                        maybe_checkpoint()
                        if completed < target:
                            state = self._start_round()
                else:  # externally posted / unknown events: logged, not fatal
                    self._log(event)
        except BaseException as exc:
            self._abort()
            self._log(EngineStopped(completed=completed, error=repr(exc)))
            raise
        self._log(EngineStopped(completed=completed))
        return out

    def _observe_round(self, timings: RoundTimings) -> None:
        """Fold one completed round into the engine's telemetry instruments."""
        self._h_round_s.observe(timings.federation_round_s)
        self._h_aggregate_s.observe(timings.aggregation_s)
        self._g_round.set(self.controller.round_id)

    def _aggregate(self, state: _RoundState) -> float:
        """Reduce per the policy's weighting hook; returns the agg seconds.

        ``"staleness"`` aggregates every valid stored model with
        staleness-damped weights (the continuous/community semantics,
        secure or clear); anything else is the cohort FedAvg / secure-sum
        round reduce.
        """
        c = self.controller
        if c.protocol.weighting() == "staleness":
            return c.aggregate_community()
        return c.aggregate_round(state.cohort)

    def _abort(self) -> None:
        """Leave the engine re-runnable after an error escapes the loop.

        Blocks until every dispatched-but-unarrived task posts (exactly the
        barrier the legacy ``wait(futures)`` error path provided), then
        discards whatever is left in the queue — stale arrivals or pending
        ``AggregateFired`` events must not leak into a later ``run()``'s
        round accounting.
        """
        while self._outstanding > 0:
            if isinstance(self._events.get(), UploadArrived):
                self._outstanding -= 1
        while not self._events.empty():
            self._events.get_nowait()

    # -- lifecycle ----------------------------------------------------------
    def shutdown(self) -> None:
        """Stop the dispatch executor (waits for in-flight tasks) and close
        the journal (final flush; an owned sink file is closed)."""
        self._executor.shutdown(wait=True)
        self.journal.close()
