"""Parallelized model aggregation — the paper's core contribution, TPU-native.

MetisFL aggregates a federated model of ``k`` tensors from ``N`` learners with
one OpenMP thread per tensor (paper Fig. 4).  The TPU-native restatement packs
the model into one flat buffer (``core/packing.py``) and performs the whole
aggregation as a single fused weighted reduction over an ``(N, P)`` stack:

* elementwise over ``P`` → embarrassingly parallel across VPU lanes and, under
  ``pjit``/``shard_map``, across every chip of the mesh (each chip reduces its
  1/``mesh_size`` slice of all ``N`` buffers with **zero collectives**);
* the reduction over ``N`` is tiny (N ≤ a few hundred) and lives in registers.

Three execution paths, benchmarked against each other in
``benchmarks/bench_agg.py``:

1. :func:`fedavg` — fused XLA reduction (the production path);
2. ``kernels/fedavg.py`` — the Pallas TPU kernel (explicit VMEM tiling);
3. ``core/naive.py`` — the per-tensor Python-loop baseline (the paper's
   "no parallelization" / old-Python-controller comparison point).

Beyond FedAvg the module provides the robust rules a production controller
ships (coordinate median, trimmed mean) and staleness weighting for the
asynchronous protocol.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "fedavg",
    "weighted_average",
    "masked_normalize",
    "masked_weighted_average",
    "masked_fedavg",
    "masked_fedavg_q8",
    "masked_fedavg_topk",
    "masked_staleness_average",
    "masked_staleness_q8",
    "masked_staleness_topk",
    "coordinate_median",
    "trimmed_mean",
    "masked_coordinate_median",
    "masked_trimmed_mean",
    "staleness_weights",
    "fedavg_sharded",
    "hierarchical_fedavg",
    "masked_fedavg_sharded",
    "masked_fedavg_q8_sharded",
    "masked_fedavg_topk_sharded",
    "masked_staleness_sharded",
    "masked_staleness_q8_sharded",
    "masked_staleness_topk_sharded",
    "masked_median_sharded",
    "masked_trimmed_mean_sharded",
    "arena_axes",
]


def _normalize(weights: jax.Array) -> jax.Array:
    weights = jnp.asarray(weights, jnp.float32)
    total = jnp.sum(weights)
    # Guard the empty/zero-weight federation: fall back to uniform.
    safe = jnp.where(total > 0, total, 1.0)
    n = weights.shape[0]
    return jnp.where(total > 0, weights / safe, jnp.full((n,), 1.0 / max(n, 1)))


@jax.jit
def weighted_average(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """``(N, P) × (N,) -> (P,)`` normalized weighted mean.

    This single einsum is the entire FedAvg aggregation for an arbitrarily
    deep model: tensor boundaries were erased by packing, so XLA sees one
    perfectly regular reduction it can tile across all cores/chips.
    """
    w = _normalize(weights)
    return jnp.einsum("n,np->p", w, stack.astype(jnp.float32))


# FedAvg is a weighted average with example counts as weights.
fedavg = weighted_average


def masked_normalize(weights: jax.Array, mask: jax.Array) -> jax.Array:
    """Normalize ``weights * mask``; uniform over valid rows if all zero."""
    w = jnp.asarray(weights, jnp.float32) * jnp.asarray(mask, jnp.float32)
    total = jnp.sum(w)
    n_valid = jnp.sum(jnp.asarray(mask, jnp.float32))
    uniform = jnp.asarray(mask, jnp.float32) / jnp.maximum(n_valid, 1.0)
    return jnp.where(total > 0, w / jnp.where(total > 0, total, 1.0), uniform)


@jax.jit
def masked_weighted_average(
    arena: jax.Array, weights: jax.Array, mask: jax.Array
) -> jax.Array:
    """``(N, P) × (N,) × (N,) -> (P,)`` weighted mean over valid rows only.

    The arena-store statement of FedAvg: ``arena`` is the persistent
    device-resident buffer (``core/store.ArenaStore``) whose rows may include
    stale or never-written learners; ``mask`` (1.0 valid / 0.0 invalid) folds
    row selection into the weight vector so the reduction stays one fused
    einsum — no gather, no re-stack, no host round-trip.  Invalid rows are
    zeroed before the reduce so even garbage (e.g. NaN) in a dead row cannot
    poison the aggregate.
    """
    m = jnp.asarray(mask, jnp.float32)
    w = masked_normalize(weights, m)
    rows = jnp.where(m[:, None] > 0, arena.astype(jnp.float32), 0.0)
    return jnp.einsum("n,np->p", w, rows)


# Masked FedAvg is a masked weighted average with example counts as weights.
masked_fedavg = masked_weighted_average


@jax.jit
def masked_staleness_average(
    arena: jax.Array,
    num_examples: jax.Array,
    versions: jax.Array,
    current_version: jax.Array,
    mask: jax.Array,
    alpha: float = 0.5,
) -> jax.Array:
    """Asynchronous-protocol aggregation straight off the arena.

    Staleness is derived on device from the per-row ``versions`` vector the
    arena maintains (``s_i = current_version - v_i``), damped by the
    polynomial discount of :func:`staleness_weights`, masked, normalized and
    reduced — one fused kernel per community update instead of a host-side
    stack rebuild per arrival.
    """
    m = jnp.asarray(mask, jnp.float32)
    stal = jnp.maximum(jnp.float32(current_version) - versions, 0.0)
    w = staleness_weights(num_examples, stal, alpha)
    w = masked_normalize(w, m)
    rows = jnp.where(m[:, None] > 0, arena.astype(jnp.float32), 0.0)
    return jnp.einsum("n,np->p", w, rows)


def _dequant_rows(q: jax.Array, scales: jax.Array, group: int) -> jax.Array:
    """Dequantize ``(N, P)`` int8 rows with ``(N, P//group)`` f32 scales."""
    n, p = q.shape
    return (
        q.astype(jnp.float32).reshape(n, p // group, group)
        * scales[:, :, None]
    ).reshape(n, p)


@functools.partial(jax.jit, static_argnames=("group",))
def masked_fedavg_q8(
    q: jax.Array,
    scales: jax.Array,
    weights: jax.Array,
    mask: jax.Array,
    group: int = 256,
) -> jax.Array:
    """Masked FedAvg straight off a quantized arena — one fused XLA program.

    ``(N, P)`` int8 × ``(N, P//group)`` f32 × ``(N,)`` × ``(N,)`` -> ``(P,)``:
    the int8-arena statement of :func:`masked_weighted_average`.  Dequantize
    (a per-group broadcast multiply), mask and reduce compile into a single
    program, so the f32 ``(N, P)`` stack exists only as a fusion-internal
    temporary XLA can tile away — never a second resident copy of the arena.
    The controller's default dispatch for ``arena_dtype="int8"``; the Pallas
    statement with explicit VMEM tiling is ``kernels/ops.masked_fedavg_q8``.
    """
    m = jnp.asarray(mask, jnp.float32)
    w = masked_normalize(weights, m)
    rows = jnp.where(m[:, None] > 0, _dequant_rows(q, scales, group), 0.0)
    return jnp.einsum("n,np->p", w, rows)


@functools.partial(jax.jit, static_argnames=("group",))
def masked_staleness_q8(
    q: jax.Array,
    scales: jax.Array,
    num_examples: jax.Array,
    versions: jax.Array,
    current_version: jax.Array,
    mask: jax.Array,
    alpha: float = 0.5,
    group: int = 256,
) -> jax.Array:
    """Asynchronous-protocol aggregation straight off a quantized arena.

    The int8-arena statement of :func:`masked_staleness_average`: staleness
    discount on the tiny replicated vectors, fused dequantize-mask-reduce on
    the ``(N, P)`` int8 rows — numerically identical to dequantizing and
    calling the f32 path, without ever materializing the f32 stack.
    """
    m = jnp.asarray(mask, jnp.float32)
    stal = jnp.maximum(jnp.float32(current_version) - versions, 0.0)
    w = masked_normalize(staleness_weights(num_examples, stal, alpha), m)
    rows = jnp.where(m[:, None] > 0, _dequant_rows(q, scales, group), 0.0)
    return jnp.einsum("n,np->p", w, rows)


@functools.partial(jax.jit, static_argnames=("out_width",))
def masked_fedavg_topk(
    indices: jax.Array,
    values: jax.Array,
    weights: jax.Array,
    mask: jax.Array,
    out_width: int,
) -> jax.Array:
    """Masked FedAvg straight off a sparse (top-k) arena — scatter, not stack.

    ``(N, k)`` int32 × ``(N, k)`` f32 × ``(N,)`` × ``(N,)`` -> ``(P,)``: the
    sparse-arena statement of :func:`masked_weighted_average`.  The weight
    normalization runs on the tiny replicated vectors; the reduce is one
    combining scatter-add (``kernels/sparse_agg.scatter_accumulate``) of
    every valid row's weighted ``(index, value)`` stream into the dense
    output — the ``(N, P)`` stack is never built, so the reduce moves
    ``~N·k + P`` floats instead of ``N·P``.  Rows hold *deltas* (the topk
    codec sparsifies updates, not parameters); the controller adds the
    aggregated delta onto the global buffer at commit.
    """
    from repro.kernels import sparse_agg

    m = jnp.asarray(mask, jnp.float32)
    w = masked_normalize(weights, m)
    return sparse_agg.scatter_accumulate(indices, values, w, m, out_width)


@functools.partial(jax.jit, static_argnames=("out_width",))
def masked_staleness_topk(
    indices: jax.Array,
    values: jax.Array,
    num_examples: jax.Array,
    versions: jax.Array,
    current_version: jax.Array,
    mask: jax.Array,
    out_width: int,
    alpha: float = 0.5,
) -> jax.Array:
    """Asynchronous-protocol aggregation straight off a sparse arena.

    The sparse statement of :func:`masked_staleness_average`: the staleness
    discount damps the replicated weight vector, then one masked
    scatter-accumulate folds every valid sparse row into the ``(P,)`` delta.
    """
    from repro.kernels import sparse_agg

    m = jnp.asarray(mask, jnp.float32)
    stal = jnp.maximum(jnp.float32(current_version) - versions, 0.0)
    w = masked_normalize(staleness_weights(num_examples, stal, alpha), m)
    return sparse_agg.scatter_accumulate(indices, values, w, m, out_width)


def _robust_out_dtype(stack: jax.Array) -> jnp.dtype:
    """The dtype a robust rule returns: the input's, if it is a float.

    Order statistics are computed in float32 for a stable sort/mean, but the
    result is cast back so a bf16 arena aggregates to a bf16 model instead of
    silently widening every round.  Integer stacks (e.g. quantized codecs
    aggregated pre-dequantize in tests) still come back float32 because their
    mean is not representable in the input dtype.
    """
    dt = jnp.asarray(stack).dtype
    return dt if jnp.issubdtype(dt, jnp.floating) else jnp.dtype(jnp.float32)


@jax.jit
def coordinate_median(stack: jax.Array) -> jax.Array:
    """Coordinate-wise median — a byzantine-robust aggregation rule."""
    out = jnp.median(stack.astype(jnp.float32), axis=0)
    return out.astype(_robust_out_dtype(stack))


@functools.partial(jax.jit, static_argnames=("trim_k",))
def trimmed_mean(stack: jax.Array, trim_k: int) -> jax.Array:
    """Coordinate-wise trimmed mean dropping the ``trim_k`` extremes per side."""
    n = stack.shape[0]
    if 2 * trim_k >= n:
        raise ValueError(f"trim_k={trim_k} too large for N={n}")
    s = jnp.sort(stack.astype(jnp.float32), axis=0)
    out = jnp.mean(s[trim_k : n - trim_k], axis=0)
    return out.astype(_robust_out_dtype(stack))


@jax.jit
def masked_coordinate_median(
    arena: jax.Array, weights: jax.Array, mask: jax.Array
) -> jax.Array:
    """``(N, P) × (N,) × (N,) -> (P,)`` coordinate median over valid rows.

    The arena-store statement of :func:`coordinate_median`: invalid rows are
    pushed to ``+inf`` and a single column-wise sort floats every valid value
    to the top ``n_valid`` positions, so the median is one dynamic gather of
    the two middle ranks — no re-stack, no host round-trip, and garbage
    (even NaN) in a dead row can never reach the reduce.  ``weights`` is
    accepted for signature parity with :func:`masked_weighted_average` but
    ignored: order statistics are deliberately weight-blind, which is exactly
    what makes them robust to a poisoned example count.
    """
    del weights  # order statistics are weight-blind by design
    m = jnp.asarray(mask, jnp.float32)
    rows = jnp.where(m[:, None] > 0, arena.astype(jnp.float32), jnp.inf)
    s = jnp.sort(rows, axis=0)
    n_valid = jnp.sum(m).astype(jnp.int32)
    lo = jnp.maximum((n_valid - 1) // 2, 0)
    hi = jnp.maximum(n_valid // 2, 0)
    med = (jnp.take(s, lo, axis=0) + jnp.take(s, hi, axis=0)) * 0.5
    out = jnp.where(n_valid > 0, med, 0.0)
    return out.astype(_robust_out_dtype(arena))


@functools.partial(jax.jit, static_argnames=("trim_k",))
def masked_trimmed_mean(
    arena: jax.Array, weights: jax.Array, mask: jax.Array, trim_k: int
) -> jax.Array:
    """``(N, P) × (N,) × (N,) -> (P,)`` trimmed mean over valid rows.

    Invalid rows sort to the bottom as ``+inf``; the surviving band is rows
    ``[trim_k, n_valid - trim_k)`` of the sorted arena, selected with a rank
    mask so the whole rule stays one fused sort + masked mean regardless of
    how many arena rows are live.  ``trim_k`` is static: an impossible trim
    against the arena capacity is a clear trace-time ``ValueError``, while
    a cohort that is merely *currently* too small (``n_valid <= 2*trim_k``)
    yields an empty band and falls back to the masked mean of the valid rows
    rather than producing inf/NaN.  ``weights`` is ignored (see
    :func:`masked_coordinate_median`).
    """
    del weights  # order statistics are weight-blind by design
    n = arena.shape[0]
    if 2 * trim_k >= n:
        raise ValueError(f"trim_k={trim_k} too large for N={n}")
    m = jnp.asarray(mask, jnp.float32)
    rows = jnp.where(m[:, None] > 0, arena.astype(jnp.float32), jnp.inf)
    s = jnp.sort(rows, axis=0)
    n_valid = jnp.sum(m).astype(jnp.int32)
    ranks = jnp.arange(n, dtype=jnp.int32)
    band = (ranks >= trim_k) & (ranks < n_valid - trim_k)
    count = jnp.sum(band.astype(jnp.float32))
    safe_rows = jnp.where(band[:, None], s, 0.0)
    trimmed = jnp.sum(safe_rows, axis=0) / jnp.maximum(count, 1.0)
    # Degenerate cohort (n_valid <= 2*trim_k): untrimmed masked mean instead.
    fallback_band = ranks < n_valid
    fb_rows = jnp.where(fallback_band[:, None], s, 0.0)
    fallback = jnp.sum(fb_rows, axis=0) / jnp.maximum(
        jnp.sum(fallback_band.astype(jnp.float32)), 1.0
    )
    out = jnp.where(count > 0, trimmed, jnp.where(n_valid > 0, fallback, 0.0))
    return out.astype(_robust_out_dtype(arena))


def staleness_weights(
    num_examples: jax.Array, staleness: jax.Array, alpha: float = 0.5
) -> jax.Array:
    """Asynchronous-protocol weights: FedAvg weights damped by staleness.

    ``w_i ∝ n_i * (1 + s_i)^(-alpha)`` — the polynomial staleness discount used
    by async FL controllers; ``s_i`` is how many global updates happened since
    learner *i* pulled the model it trained from.
    """
    n = jnp.asarray(num_examples, jnp.float32)
    s = jnp.asarray(staleness, jnp.float32)
    return n * (1.0 + s) ** (-alpha)


# ---------------------------------------------------------------------------
# Mesh-sharded aggregation
# ---------------------------------------------------------------------------


def fedavg_sharded(mesh: Mesh, stack: jax.Array, weights: jax.Array) -> jax.Array:
    """Paper-faithful aggregation on a device mesh.

    The ``(N, P)`` stack is sharded over *all* mesh axes along ``P`` (the
    flattened-parameter dimension) and replicated along ``N``.  Every chip
    reduces its own parameter slice — one worker per shard, the generalization
    of MetisFL's one-thread-per-tensor.  The compiled HLO contains **no
    collectives**; this is verified by ``tests/test_aggregation.py`` and the
    dry-run roofline.
    """
    axes = tuple(mesh.axis_names)
    in_spec = NamedSharding(mesh, P(None, axes))
    out_spec = NamedSharding(mesh, P(axes))
    fn = jax.jit(weighted_average, in_shardings=(in_spec, NamedSharding(mesh, P())),
                 out_shardings=out_spec)
    return fn(stack, weights)


def arena_axes(mesh: Mesh, axes=None) -> tuple[str, ...]:
    """Resolve the arena column-sharding axes for ``mesh``.

    The single source of truth for the default — the ``"data"`` axis if the
    mesh has one, else every axis — shared by ``models.sharding.arena_specs``
    (the store's buffer layout), the sharded reductions below, and
    ``kernels/ops.masked_fedavg_sharded``, so the arena's layout and the
    jitted reductions' shardings can never silently disagree.
    """
    if axes is None:
        return ("data",) if "data" in mesh.axis_names else tuple(mesh.axis_names)
    return (axes,) if isinstance(axes, str) else tuple(axes)




def masked_fedavg_sharded(mesh: Mesh, axes=None):
    """Masked FedAvg over a column-sharded arena — zero collectives.

    Returns a jitted ``(arena (N_max,P), weights (N_max,), mask (N_max,)) ->
    (P,)`` closed over the mesh: the arena arrives (and stays) sharded
    ``P(None, axes)``, the tiny metadata vectors are replicated, and the
    output keeps the ``P(axes)`` column sharding — every device reduces its
    own ``(N_max, P/n_shards)`` shard and nothing is gathered until the
    caller unpacks the model.  The per-shard math is exactly
    :func:`masked_weighted_average` (the weight normalization only reduces
    over the replicated ``(N_max,)`` vectors), so the result is numerically
    identical to the single-device arena path.
    """
    ax = arena_axes(mesh, axes)
    return jax.jit(
        masked_weighted_average,
        in_shardings=(
            NamedSharding(mesh, P(None, ax)),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
        ),
        out_shardings=NamedSharding(mesh, P(ax)),
    )


def masked_fedavg_q8_sharded(mesh: Mesh, axes=None, group: int = 256):
    """Masked FedAvg over a column-sharded *quantized* arena — zero collectives.

    Returns a jitted ``(q (N,P) int8, scales (N,P//group), weights, mask) ->
    (P,)``: values and scales carry the same ``P(None, axes)`` column
    sharding (``ArenaStore(arena_dtype="int8", mesh=...)`` keeps every shard
    a whole number of groups), so each device fuses dequantize-mask-reduce
    over its own slice and only the replicated ``(N,)`` vectors are reduced
    globally — the same contract as :func:`masked_fedavg_sharded`.
    """
    ax = arena_axes(mesh, axes)

    def _agg(q, scales, weights, mask):
        return masked_fedavg_q8(q, scales, weights, mask, group)

    repl = NamedSharding(mesh, P())
    col = NamedSharding(mesh, P(None, ax))
    return jax.jit(
        _agg,
        in_shardings=(col, col, repl, repl),
        out_shardings=NamedSharding(mesh, P(ax)),
    )


def masked_staleness_q8_sharded(mesh: Mesh, axes=None, alpha: float = 0.5,
                                group: int = 256):
    """Sharded statement of :func:`masked_staleness_q8` for async int8 arenas.

    Same sharding contract as :func:`masked_fedavg_q8_sharded`; the staleness
    discount runs on the replicated ``(N,)`` vectors so the per-shard fused
    dequantize-reduce stays collective-free.
    """
    ax = arena_axes(mesh, axes)

    def _agg(q, scales, num_examples, versions, current_version, mask):
        return masked_staleness_q8(
            q, scales, num_examples, versions, current_version, mask,
            alpha, group,
        )

    repl = NamedSharding(mesh, P())
    col = NamedSharding(mesh, P(None, ax))
    return jax.jit(
        _agg,
        in_shardings=(col, col, repl, repl, repl, repl),
        out_shardings=NamedSharding(mesh, P(ax)),
    )


def masked_fedavg_topk_sharded(mesh: Mesh, axes=None, out_width: int = 0):
    """Masked sparse FedAvg over a column-sharded output — zero collectives.

    Returns a jitted ``(indices (N,k) int32, values (N,k) f32, weights (N,),
    mask (N,)) -> (P,)`` closed over the mesh and the (static) output width.
    Unlike the dense sharded reductions, the *inputs* stay replicated — the
    sparse arena is ``N·k``-small by construction — and only the ``(P,)``
    output is column-sharded: inside ``shard_map`` each device buckets the
    global indices into its own column window and scatters locally
    (``kernels/sparse_agg.scatter_accumulate_sharded``), so the compiled
    HLO stays collective-free.
    """
    from repro.kernels import sparse_agg

    ax = arena_axes(mesh, axes)
    scatter = sparse_agg.scatter_accumulate_sharded(mesh, ax, int(out_width))

    def _agg(indices, values, weights, mask):
        m = jnp.asarray(mask, jnp.float32)
        w = masked_normalize(weights, m)
        return scatter(indices, values, w, m)

    return jax.jit(_agg)


def masked_staleness_topk_sharded(mesh: Mesh, axes=None, out_width: int = 0,
                                  alpha: float = 0.5):
    """Sharded statement of :func:`masked_staleness_topk` for async sparse
    arenas — same replicated-input / sharded-output contract as
    :func:`masked_fedavg_topk_sharded`, with the staleness discount on the
    replicated ``(N,)`` vectors.
    """
    from repro.kernels import sparse_agg

    ax = arena_axes(mesh, axes)
    scatter = sparse_agg.scatter_accumulate_sharded(mesh, ax, int(out_width))

    def _agg(indices, values, num_examples, versions, current_version, mask):
        m = jnp.asarray(mask, jnp.float32)
        stal = jnp.maximum(jnp.float32(current_version) - versions, 0.0)
        w = masked_normalize(staleness_weights(num_examples, stal, alpha), m)
        return scatter(indices, values, w, m)

    return jax.jit(_agg)


def masked_staleness_sharded(mesh: Mesh, axes=None, alpha: float = 0.5):
    """Sharded statement of :func:`masked_staleness_average` for async FL.

    Returns a jitted ``(arena, num_examples, versions, current_version,
    mask) -> (P,)`` with the same column sharding contract as
    :func:`masked_fedavg_sharded`; the staleness discount is computed on the
    replicated ``(N_max,)`` vectors so the sharded reduction stays
    collective-free.
    """
    ax = arena_axes(mesh, axes)

    def _agg(arena, num_examples, versions, current_version, mask):
        return masked_staleness_average(
            arena, num_examples, versions, current_version, mask, alpha
        )

    repl = NamedSharding(mesh, P())
    return jax.jit(
        _agg,
        in_shardings=(NamedSharding(mesh, P(None, ax)), repl, repl, repl, repl),
        out_shardings=NamedSharding(mesh, P(ax)),
    )


def masked_median_sharded(mesh: Mesh, axes=None):
    """Masked coordinate median over a column-sharded arena — zero collectives.

    Returns a jitted ``(arena (N_max,P), weights (N_max,), mask (N_max,)) ->
    (P,)`` with the same sharding contract as :func:`masked_fedavg_sharded`.
    The median is coordinate-wise, so each device sorts and selects within its
    own ``(N_max, P/n_shards)`` column slice independently; the only
    cross-row reductions (``n_valid``) run on the replicated mask vector, so
    the compiled HLO stays collective-free.
    """
    ax = arena_axes(mesh, axes)
    repl = NamedSharding(mesh, P())
    return jax.jit(
        masked_coordinate_median,
        in_shardings=(NamedSharding(mesh, P(None, ax)), repl, repl),
        out_shardings=NamedSharding(mesh, P(ax)),
    )


def masked_trimmed_mean_sharded(mesh: Mesh, axes=None, trim_k: int = 1):
    """Masked trimmed mean over a column-sharded arena — zero collectives.

    Same sharding contract as :func:`masked_median_sharded`; ``trim_k`` is
    closed over (static) so the rank-band selection compiles once per trim.
    """
    ax = arena_axes(mesh, axes)

    def _agg(arena, weights, mask):
        return masked_trimmed_mean(arena, weights, mask, trim_k)

    repl = NamedSharding(mesh, P())
    return jax.jit(
        _agg,
        in_shardings=(NamedSharding(mesh, P(None, ax)), repl, repl),
        out_shardings=NamedSharding(mesh, P(ax)),
    )


def hierarchical_fedavg(mesh: Mesh, pod_axis: str = "pod"):
    """Beyond-paper: in-network aggregation over the ``pod`` mesh axis.

    Each pod *is* a learner silo: the global stack has shape
    ``(n_pods, P)`` with learner ``i``'s buffer living entirely on pod ``i``,
    sharded over the in-pod (``data``,``model``) axes.  The federation average
    is then a single ``psum`` over ``pod`` — in-network aggregation whose
    bandwidth scales with ICI links instead of a single controller-host NIC.

    Returns a jit-able function ``(stack (n_pods,P), weights (n_pods,)) ->
    (P,)`` built on ``shard_map`` over the full mesh.
    """

    other_axes = tuple(a for a in mesh.axis_names if a != pod_axis)

    def agg(local_buffer: jax.Array, local_weight: jax.Array) -> jax.Array:
        # local_buffer: (1, P / prod(other_axes)) — this pod's slice of its
        # own learner's buffer.  local_weight: (1,).
        wsum = jax.lax.psum(jnp.sum(local_weight), pod_axis)
        contrib = local_buffer[0].astype(jnp.float32) * local_weight[0]
        agg = jax.lax.psum(contrib, pod_axis) / jnp.maximum(wsum, 1e-12)
        return agg

    from repro.compat import shard_map

    return shard_map(
        agg,
        mesh=mesh,
        in_specs=(P(pod_axis, other_axes), P(pod_axis)),
        out_specs=P(other_axes),
        check_vma=False,
    )
