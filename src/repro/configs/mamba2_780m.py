"""mamba2-780m [ssm] — attention-free SSD (state-space duality).

[arXiv:2405.21060]: 48L, d_model=1536 (d_inner=3072, 48 ssm heads of 64),
ssm_state=128, vocab=50280 (padded to 50432), no MLP (d_ff=0).
"""

from repro.models.config import MAMBA, ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "mamba2-780m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=24,  # unused (attention-free); kept for completeness
        n_kv_heads=24,
        d_ff=0,
        vocab_size=50280,
        layer_pattern=(MAMBA,),
        ssm_state=128,
        ssm_head_dim=64,
        tie_embeddings=True,
        source="arXiv:2405.21060 (Mamba2)",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
