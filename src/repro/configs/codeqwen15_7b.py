"""codeqwen1.5-7b [dense] — Qwen1.5 architecture (QKV bias, MHA kv=32).

[hf:Qwen/CodeQwen1.5-7B]: 32L, d_model=4096, 32H (GQA kv=32 -> full MHA),
d_ff=13440, vocab=92416.
"""

from repro.models.config import ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "codeqwen1.5-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/CodeQwen1.5-7B",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
