"""qwen2-72b [dense] — GQA with QKV bias.

[arXiv:2407.10671]: 80L, d_model=8192, 64H (GQA kv=8), d_ff=29568,
vocab=152064.
"""

from repro.models.config import ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "qwen2-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        source="arXiv:2407.10671 (Qwen2)",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
