"""fedlm-100m — the ~100M-parameter LM used by the end-to-end federated
training example (examples/fed_lm_e2e.py).  Not part of the assigned-arch
registry; CPU-trainable in minutes.
"""

from repro.models.config import ModelConfig

ARCH_ID = "fedlm-100m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=8,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2304,
        vocab_size=24576,
        tie_embeddings=True,
        remat=False,
        source="(this repo: e2e example config)",
    )
