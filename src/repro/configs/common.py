"""Shared helpers for architecture configs."""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

__all__ = ["reduce_config"]


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family, tiny dimensions.

    2 pattern-cycles of layers (so heterogeneous patterns keep their
    structure), d_model<=256, <=4 experts, small vocab.
    """
    pat = len(cfg.layer_pattern)
    n_layers = max(2, pat) if pat > 1 else 2
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=min(cfg.d_model, 256),
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=512,
        vocab_pad_to=128,
        sliding_window=min(cfg.sliding_window, 16),
        remat=False,
    )
    if cfg.n_experts:
        changes.update(
            n_experts=4, top_k=2, moe_d_ff=128,
            n_shared_experts=min(cfg.n_shared_experts, 1),
            shared_d_ff=128 if cfg.n_shared_experts else 0,
            expert_pad_to=1, first_k_dense=min(cfg.first_k_dense, 1),
        )
    if cfg.attn_impl == "mla":
        changes.update(
            q_lora_rank=48, kv_lora_rank=32,
            qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.is_encoder_decoder:
        changes.update(n_encoder_layers=2, encoder_seq_len=24)
    if cfg.frontend:
        changes.update(frontend_dim=64, num_prefix_tokens=8)
    if cfg.mtp_depth:
        changes.update(mtp_depth=1)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
