"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

[arXiv:2412.19437]: 61L, d_model=7168, 128H MLA (q_lora=1536, kv_lora=512,
nope=128, rope=64, v=128), moe_d_ff=2048, vocab=129280, first 3 layers dense
(d_ff=18432), multi-token-prediction depth 1.
"""

from repro.models.config import ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,  # dense layers (first_k_dense)
        vocab_size=129280,
        attn_impl="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        n_experts=256,
        n_shared_experts=1,
        shared_d_ff=2048,
        top_k=8,
        moe_d_ff=2048,
        first_k_dense=3,
        mtp_depth=1,
        source="arXiv:2412.19437 (DeepSeek-V3)",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
