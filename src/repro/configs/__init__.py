"""Architecture registry: the 10 assigned configs + the paper's HousingMLP.

Usage:  ``from repro.configs import get_config, ARCHITECTURES``
        ``cfg = get_config("qwen3-14b")`` / ``get_reduced("qwen3-14b")``.
"""

from __future__ import annotations

import importlib

_MODULES = {
    "llava-next-34b": "repro.configs.llava_next_34b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen3-14b": "repro.configs.qwen3_14b",
}

ARCHITECTURES = tuple(_MODULES)


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHITECTURES}")
    return importlib.import_module(_MODULES[arch]).config()


def get_reduced(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHITECTURES}")
    return importlib.import_module(_MODULES[arch]).reduced()


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------

INPUT_SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}

# archs with sub-quadratic attention that run long_500k (DESIGN.md §4)
LONG_CONTEXT_ARCHS = ("mamba2-780m", "zamba2-1.2b", "gemma3-4b")


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """Whether (arch, shape) runs; returns (applicable, reason-if-not)."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 500k KV requires sub-quadratic variant"
    return True, ""
