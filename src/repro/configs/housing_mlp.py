"""The paper's stress-test model: 100-hidden-layer HousingMLP.

§4.2: "we define an MLP architecture with 100 densely connected (hidden)
layers and a constant number of parameters per layer — 100k: 32 params/layer,
1M: 100 params/layer, 10M: 320 params/layer" — i.e. hidden widths 32 / 100 /
320, trained on a housing regression task with Vanilla SGD, batch 100.
"""

from __future__ import annotations

import dataclasses

ARCH_ID = "housing-mlp"

# width -> (label, approx params)
SIZES = {"100k": 32, "1m": 100, "10m": 320}


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    name: str
    n_hidden_layers: int
    width: int
    n_features: int = 13  # housing dataset feature count
    n_outputs: int = 1

    @property
    def param_count(self) -> int:
        w, L = self.width, self.n_hidden_layers
        total = self.n_features * w + w
        total += (L - 1) * (w * w + w)
        total += w * self.n_outputs + self.n_outputs
        return total


def config(size: str = "10m") -> MLPConfig:
    if size not in SIZES:
        raise ValueError(f"size must be one of {list(SIZES)}")
    return MLPConfig(name=f"{ARCH_ID}-{size}", n_hidden_layers=100, width=SIZES[size])


def reduced() -> MLPConfig:
    return MLPConfig(name=f"{ARCH_ID}-smoke", n_hidden_layers=4, width=16)
