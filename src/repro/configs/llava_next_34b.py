"""llava-next-34b [vlm] — anyres tiling VLM backbone.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] scaled to the 34B variant's LM
backbone: 60L, d_model=7168, 56H (GQA kv=8), d_ff=20480, vocab=64000.
The vision tower (SigLIP/CLIP ViT + anyres tile packing) is a STUB per the
assignment carve-out: ``input_specs`` supplies precomputed patch embeddings
(one base tile, 576 patches of dim 1152) which ``frontend_proj`` maps into
the LM embedding space and prepends to the text sequence.
"""

from repro.models.config import ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "llava-next-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        rope_theta=5_000_000.0,
        frontend="vision_stub",
        frontend_dim=1152,
        num_prefix_tokens=576,  # one anyres base tile (24x24 patches)
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34B backbone dims)",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
