"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L, d_model=2048, 16H (kv=16),
moe_d_ff=1408, vocab=151936.  60 routed experts are padded to 64 for
expert-sharding divisibility over the 16-way model axis (DESIGN.md §4);
the 4 pad experts receive -inf router logits and are never selected.
Shared-expert intermediate = 5632 (4 x 1408).
"""

from repro.models.config import ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "qwen2-moe-a2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=151936,
        qkv_bias=True,
        n_experts=60,
        expert_pad_to=64,
        n_shared_experts=4,
        shared_d_ff=5632,
        top_k=4,
        moe_d_ff=1408,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
