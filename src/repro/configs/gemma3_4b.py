"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k.

[hf:google/gemma-3-1b-pt scaled to 4B dims]: 34L, d_model=2560, 8H (GQA
kv=4), head_dim=256, d_ff=10240, vocab=262144, sliding_window=1024,
qk-norm, tied embeddings, embeddings scaled by sqrt(d_model).
Deviation noted in DESIGN.md: a single rope_theta is used for local and
global layers (upstream uses 10k local / 1M global).
"""

from repro.models.config import ATTN, SWA, ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "gemma3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        qk_norm=True,
        sliding_window=1024,
        layer_pattern=(SWA, SWA, SWA, SWA, SWA, ATTN),
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt (4B dims)",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
