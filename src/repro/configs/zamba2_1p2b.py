"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242]: 38L, d_model=2048, shared attn 32H (kv=32),
d_ff=8192 (shared block MLP), ssm_state=64.  The single shared transformer
block (tied weights) is applied every 6th layer; per-instance scale adapters
keep applications distinguishable (the paper uses LoRA adapters).
"""

from repro.models.config import MAMBA, SHARED_ATTN, ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "zamba2-1.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        layer_pattern=(MAMBA,) * 5 + (SHARED_ATTN,),
        ssm_state=64,
        ssm_head_dim=64,
        source="arXiv:2411.15242 (Zamba2)",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
