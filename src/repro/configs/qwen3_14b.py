"""qwen3-14b [dense] — qk_norm, GQA.

[hf:Qwen/Qwen3-8B scaled to 14B dims]: 40L, d_model=5120, 40H (GQA kv=8),
head_dim=128, d_ff=17408, vocab=151936, qk-norm, no qkv bias.
"""

from repro.models.config import ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "qwen3-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-8B (14B dims)",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
