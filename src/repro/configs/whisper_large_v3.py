"""whisper-large-v3 [audio] — encoder-decoder with stubbed conv frontend.

[arXiv:2212.04356]: 32 encoder + 32 decoder layers, d_model=1280, 20H
(kv=20), d_ff=5120 (plain GELU MLP), vocab=51866 (padded to 51968),
LayerNorm, absolute sinusoidal positions, 1500 encoder frames.  The
mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: ``input_specs`` supplies precomputed frame embeddings.
Decode shapes exercise the *decoder* serve step; 32k decode positions
exceed Whisper's trained 448-token context and are a stress shape only.
"""

from repro.models.config import ATTN, XATTN, ModelConfig
from repro.configs.common import reduce_config

ARCH_ID = "whisper-large-v3"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="audio",
        n_layers=32,  # decoder layers
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        layer_pattern=(XATTN,),
        is_encoder_decoder=True,
        n_encoder_layers=32,
        encoder_seq_len=1500,
        frontend="audio_stub",
        frontend_dim=1280,
        mlp_gated=False,
        norm_type="layernorm",
        pos_embedding="sinusoidal",
        source="arXiv:2212.04356 (Whisper; large-v3 dims)",
    )


def reduced() -> ModelConfig:
    return reduce_config(config())
