"""Secure aggregation + asynchronous protocol + int8 transport — the three
controller features the paper's Table 1 highlights as MetisFL differentiators,
composed in one workflow.

Phase 1: synchronous rounds with MASKED SECURE AGGREGATION — the controller
only ever sums fixed-point-masked uploads (pairwise pads cancel exactly).
Phase 2: SECURE ASYNCHRONOUS federation — the engine aggregates on every
arrival with staleness-discounted weights inside a fresh per-epoch mask
session (keyed by the global model version), still never seeing an
individual model; no round barrier.
Both phases ship models through the int8 Pallas transport codec.

    PYTHONPATH=src python examples/secure_async_fl.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AsyncProtocol, Controller, Driver, FederationEnv, SyncProtocol,
    TerminationCriteria,
)
from repro.kernels.ops import QuantCodec
from repro.launch.train import build_housing_learners
from repro.models import mlp as mlp_model


def main():
    cfg, learners = build_housing_learners("100k", n_learners=4, seed=0)
    initial = mlp_model.init_params(jax.random.key(0), cfg)

    # ---- phase 1: secure synchronous rounds --------------------------------
    env = FederationEnv(
        protocol="sync", local_steps=6, batch_size=50, learning_rate=0.01,
        secure_aggregation=True,
        termination=TerminationCriteria(max_rounds=3),
    )
    driver = Driver(env)
    driver.controller.channel.codec = QuantCodec()
    driver.initialize(initial, learners)
    hist = driver.run()
    print("secure sync phase:")
    for h in hist:
        print(f"  round {h.round_id}: eval_loss={h.metrics['eval_loss']:.5f} "
              f"agg={h.aggregation_s:.4f}s")
    secure_params = driver.controller.global_params
    stats = driver.controller.channel.stats
    print(f"  wire: {stats.bytes_moved/1e6:.1f} MB over {stats.messages} msgs "
          f"(int8 codec)")

    # ---- phase 2: SECURE asynchronous continuation (a NEW task: fresh silos
    # with a different ground truth, warm-started from the secure phase's
    # model) — every community update opens a per-epoch mask session --------
    cfg2, learners2 = build_housing_learners("100k", n_learners=4, seed=1)
    ctrl = Controller(
        protocol=AsyncProtocol(local_steps=8, batch_size=50, learning_rate=0.01,
                               staleness_alpha=0.5),
        secure=True,
    )
    ctrl.set_initial_model(secure_params)
    start = float(mlp_model.mse_loss(secure_params, learners2[0]._eval_data_fn()))
    for l in learners2:
        ctrl.register_learner(l)
    updates = ctrl.engine.run(total_updates=20)
    ctrl.shutdown()
    print(f"secure async phase: {len(updates)} community updates, "
          f"mean agg {np.mean([u.aggregation_s for u in updates])*1e3:.2f} ms")

    final = float(mlp_model.mse_loss(ctrl.global_params,
                                     learners2[0]._eval_data_fn()))
    print(f"secure async adaptation: eval loss {start:.4f} -> {final:.4f}")
    assert final < start, "secure async federation must adapt to the new task"
    print("secure sync → secure async federation complete ✓")


if __name__ == "__main__":
    main()
