"""Serving example: batched decode across three architecture families —
sliding-window dense (gemma3), attention-free SSM (mamba2), and MLA MoE
(deepseek) — through the same ``make_serve_step`` the production dry-run
lowers on the 16x16 mesh.

    PYTHONPATH=src python examples/serve_multiarch.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.launch.steps import make_serve_step
from repro.models import kvcache, transformer


def serve(arch: str, batch=4, gen=24):
    cfg = get_reduced(arch)
    params = transformer.init_params(jax.random.key(0), cfg)
    step = jax.jit(make_serve_step(cfg))
    caches = kvcache.init_cache(cfg, batch, 64)
    tok = jnp.full((batch, 1), 1, jnp.int32)
    # warmup/compile
    _, _ = step(params, caches, tok, jnp.asarray(0, jnp.int32), None)

    caches = kvcache.init_cache(cfg, batch, 64)
    out = []
    t0 = time.time()
    for t in range(gen):
        tok, caches = step(params, caches, tok, jnp.asarray(t, jnp.int32), None)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.padded_vocab_size)))
    print(f"{arch:16s} {batch * gen / dt:8.1f} tok/s (batch={batch})  "
          f"sample: {toks[0, :8].tolist()}")


def main():
    for arch in ("gemma3-4b", "mamba2-780m", "deepseek-v3-671b"):
        serve(arch)
    print("multi-family serving ✓")


if __name__ == "__main__":
    main()
