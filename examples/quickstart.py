"""Quickstart: a 4-learner federated workflow in ~40 lines.

Reproduces the paper's workflow (Fig. 1) end to end on the host: the driver
initializes the controller with the model state, learners register, and
synchronous FedAvg rounds run with per-operation timing — the measurements
of Figs. 5-7.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Driver, FederationEnv, Learner, TerminationCriteria
from repro.optim import sgd

# --- a private dataset per learner (linear regression silos) ---------------
rng = np.random.default_rng(0)
W_TRUE = rng.normal(size=(8, 1)).astype(np.float32)


def make_learner(i: int) -> Learner:
    X = rng.normal(size=(256, 8)).astype(np.float32)
    y = X @ W_TRUE + 0.01 * rng.normal(size=(256, 1)).astype(np.float32)

    def loss_fn(params, batch):
        xb, yb = batch
        return jnp.mean((xb @ params["w"] + params["b"] - yb) ** 2)

    def data_fn(batch_size):
        idx = rng.integers(0, 256, size=batch_size)
        return X[idx], y[idx]

    return Learner(
        learner_id=f"hospital_{i}",
        loss_fn=loss_fn,
        eval_fn=lambda p, b: {"eval_loss": loss_fn(p, b)},
        data_fn=data_fn,
        eval_data_fn=lambda: (X, y),
        optimizer=sgd(0.1),
        num_examples=256,
    )


def main():
    env = FederationEnv(
        protocol="sync", local_steps=10, batch_size=64,
        server_optimizer="fedavg",
        termination=TerminationCriteria(max_rounds=5),
    )
    driver = Driver(env)
    driver.initialize(
        initial_params={"w": jnp.zeros((8, 1)), "b": jnp.zeros((1,))},
        learners=[make_learner(i) for i in range(4)],
    )
    history = driver.run()

    print("round | federation_s | aggregation_s | eval_loss")
    for h in history:
        print(f"{h.round_id:>5} | {h.federation_round_s:>11.3f} | "
              f"{h.aggregation_s:>12.4f} | {h.metrics['eval_loss']:.6f}")
    assert history[-1].metrics["eval_loss"] < 1e-2
    print("converged ✓")


if __name__ == "__main__":
    main()
