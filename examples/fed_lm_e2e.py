"""End-to-end driver: federated training of the ~100M-parameter LM.

8 learner silos hold disjoint synthetic token shards; the controller runs
synchronous FedAvg with a FedAdam server optimizer.  A few hundred local
steps total (rounds x learners x local_steps) on CPU.

    PYTHONPATH=src python examples/fed_lm_e2e.py            # full (~100M)
    PYTHONPATH=src python examples/fed_lm_e2e.py --small    # 2-min variant
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.fedlm_100m import config as fedlm_config
from repro.core import Driver, FederationEnv, TerminationCriteria
from repro.launch.train import build_lm_learners
from repro.models import transformer
from repro.optim import sgd
from repro.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--learners", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--checkpoint-dir", default="experiments/fedlm_ckpt")
    args = ap.parse_args()

    cfg = fedlm_config()
    if args.small:
        import dataclasses

        cfg = dataclasses.replace(cfg, n_layers=2, d_model=256, n_heads=4,
                                  n_kv_heads=2, d_ff=512, vocab_size=4096)

    n_params_est = cfg.param_count_estimate()
    print(f"model: {cfg.name}  ~{n_params_est/1e6:.0f}M params, "
          f"{args.learners} learners x {args.rounds} rounds x "
          f"{args.local_steps} local steps")

    learners = build_lm_learners(
        cfg, args.learners, seed=0, n_seq_per_learner=48, seq_len=48,
        optimizer=sgd(0.3),
    )
    initial = transformer.init_params(jax.random.key(0), cfg)

    env = FederationEnv(
        protocol="sync", local_steps=args.local_steps, batch_size=16,
        server_optimizer="fedadam", server_lr=0.5,
        termination=TerminationCriteria(max_rounds=args.rounds),
    )
    driver = Driver(env)
    t0 = time.time()
    driver.initialize(initial, learners)
    history = driver.run()
    wall = time.time() - t0

    losses = [h.metrics["eval_loss"] for h in history]
    print("\nround | eval_loss | fed_round_s | agg_s")
    for h in history:
        print(f"{h.round_id:>5} | {h.metrics['eval_loss']:>9.4f} | "
              f"{h.federation_round_s:>11.2f} | {h.aggregation_s:.4f}")
    print(f"\nwall: {wall:.1f}s  loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "federated training must reduce loss"

    path = save_checkpoint(args.checkpoint_dir, len(history),
                           driver.controller.global_params,
                           metadata={"arch": cfg.name})
    print(f"checkpoint: {path}")


if __name__ == "__main__":
    main()
