"""Data / optimizer / checkpoint / transport-codec substrate tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import dirichlet_partition, iid_partition, make_housing_data, make_lm_data
from repro.optim import adafactor, adam, adamw, apply_fedprox, momentum, sgd


# -- data --------------------------------------------------------------------


def test_iid_partition_disjoint_and_complete():
    shards = iid_partition(100, 7, seed=0)
    allidx = np.concatenate(shards)
    assert len(allidx) == 100 and len(np.unique(allidx)) == 100


def test_iid_partition_paper_mode():
    shards = iid_partition(506, 200, seed=0, per_learner=100, with_replacement=True)
    assert len(shards) == 200 and all(len(s) == 100 for s in shards)


def test_dirichlet_partition_skews():
    labels = np.repeat(np.arange(5), 200)
    even = dirichlet_partition(labels, 4, alpha=1000.0, seed=0)
    skew = dirichlet_partition(labels, 4, alpha=0.05, seed=0)

    def class_entropy(shards):
        ents = []
        for s in shards:
            if not len(s):
                continue
            c = np.bincount(labels[s], minlength=5) / len(s)
            c = c[c > 0]
            ents.append(-(c * np.log(c)).sum())
        return np.mean(ents)

    assert class_entropy(skew) < class_entropy(even)
    assert all(len(s) >= 1 for s in skew)


def test_lm_data_learnable_structure():
    toks = make_lm_data(16, 32, vocab_size=50, seed=0)
    assert toks.shape == (16, 33) and toks.max() < 50 and toks.min() >= 0
    # bigram copy structure exists: successor-of-previous appears often
    nxt = (toks[:, :-1] + 1) % 50
    frac = (toks[:, 1:] == nxt).mean()
    assert frac > 0.2


# -- optimizers ----------------------------------------------------------------


@pytest.mark.parametrize(
    "opt", [sgd(0.1), momentum(0.05), adam(0.05), adamw(0.05), adafactor(0.1)]
)
def test_optimizers_descend_quadratic(opt):
    params = {"w": jnp.full((6, 3), 2.0), "b": jnp.full((3,), -1.5)}
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    st = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        params, st = opt.apply(params, jax.grad(loss)(params), st)
    assert float(loss(params)) < 0.2 * l0, opt.name


def test_fedprox_pulls_towards_global():
    g = {"w": jnp.zeros((4,))}
    base = lambda p, b: jnp.sum((p["w"] - 10.0) ** 2)  # pulls towards 10
    prox = apply_fedprox(base, mu=100.0, global_params=g)  # dominates: stay near 0
    params = {"w": jnp.zeros((4,))}
    opt = sgd(0.005)
    st = opt.init(params)
    for _ in range(100):
        params, st = opt.apply(params, jax.grad(lambda p: prox(p, None))(params), st)
    assert float(jnp.max(params["w"])) < 1.0  # without prox it would go to ~10


# -- checkpoint ----------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    params = {
        "w": jax.random.normal(jax.random.key(0), (8, 4), jnp.float32),
        "emb": jax.random.normal(jax.random.key(1), (10, 4), jnp.bfloat16),
    }
    save_checkpoint(d, 3, params, extra_arrays={"rounds": np.asarray([1, 2, 3])},
                    metadata={"arch": "test"})
    save_checkpoint(d, 7, params)
    assert latest_step(d) == 7
    back, extras, meta = restore_checkpoint(d, 3)
    assert meta["step"] == 3 and meta["arch"] == "test"
    np.testing.assert_array_equal(extras["rounds"], [1, 2, 3])
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-6
        )


def test_checkpoint_restore_latest(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"w": jnp.ones((2,))})
    _, _, meta = restore_checkpoint(d)
    assert meta["step"] == 1


def test_checkpoint_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path))
