"""Property tests for the robust (order-statistic) aggregation rules.

The byzantine-robust controller stands on four algebraic guarantees of
``coordinate_median`` / ``trimmed_mean`` and their masked arena forms
(``core/aggregation.py``):

* **mask/dense agreement** — a masked rule over a fully-valid arena equals
  the dense rule over the same rows stacked (no re-stack needed, ever);
* **row-permutation invariance** — order statistics cannot depend on
  arrival order (the arena writes rows in registration order; a shuffled
  cohort must aggregate identically);
* **boundedness** — a trimmed mean lies inside the per-coordinate
  [min, max] envelope of the valid rows (an adversary cannot drag the
  global model outside what *some* learner proposed);
* **minority resistance** — with fewer than half the rows corrupted
  arbitrarily, the coordinate median stays inside the honest rows'
  envelope, and a trimmed mean with ``trim_k`` at least the corruption
  count does too.

Runs under the real `hypothesis` when installed, else the deterministic
``hypothesis_compat`` fallback engine.
"""

import jax.numpy as jnp
import numpy as np

from hypothesis_compat import given, settings, st
from repro.core import aggregation


@st.composite
def _arenas(draw, min_rows=1, max_rows=7):
    """A small (n, p) float matrix with per-row weights, as nested lists."""
    n = draw(st.integers(min_value=min_rows, max_value=max_rows))
    p = draw(st.integers(min_value=1, max_value=9))
    rows = [
        [draw(st.floats(min_value=-100.0, max_value=100.0)) for _ in range(p)]
        for _ in range(n)
    ]
    weights = [draw(st.floats(min_value=0.5, max_value=10.0)) for _ in range(n)]
    return rows, weights


def _as_arrays(rows, weights):
    arena = jnp.asarray(np.asarray(rows, np.float32))
    w = jnp.asarray(np.asarray(weights, np.float32))
    mask = jnp.ones((arena.shape[0],), jnp.float32)
    return arena, w, mask


@settings(max_examples=40)
@given(data=_arenas())
def test_masked_median_equals_dense_under_full_mask(data):
    rows, weights = data
    arena, w, mask = _as_arrays(rows, weights)
    masked = np.asarray(aggregation.masked_coordinate_median(arena, w, mask))
    dense = np.asarray(aggregation.coordinate_median(arena))
    np.testing.assert_allclose(masked, dense, rtol=1e-6, atol=1e-6)


@settings(max_examples=40)
@given(data=_arenas(min_rows=3))
def test_masked_trimmed_mean_equals_dense_under_full_mask(data):
    rows, weights = data
    arena, w, mask = _as_arrays(rows, weights)
    masked = np.asarray(aggregation.masked_trimmed_mean(arena, w, mask, 1))
    dense = np.asarray(aggregation.trimmed_mean(arena, 1))
    np.testing.assert_allclose(masked, dense, rtol=1e-5, atol=1e-5)


@settings(max_examples=40)
@given(data=_arenas(min_rows=3), seed=st.integers(min_value=0, max_value=999))
def test_row_permutation_invariance(data, seed):
    rows, weights = data
    arena, w, mask = _as_arrays(rows, weights)
    perm = np.random.default_rng(seed).permutation(arena.shape[0])
    arena_p, w_p, mask_p = arena[perm], w[perm], mask[perm]
    for fn in (
        lambda a, ww, m: aggregation.masked_coordinate_median(a, ww, m),
        lambda a, ww, m: aggregation.masked_trimmed_mean(a, ww, m, 1),
    ):
        np.testing.assert_allclose(
            np.asarray(fn(arena, w, mask)),
            np.asarray(fn(arena_p, w_p, mask_p)),
            rtol=1e-6, atol=1e-6,
        )


@settings(max_examples=40)
@given(data=_arenas(min_rows=3))
def test_trimmed_mean_stays_inside_valid_envelope(data):
    rows, weights = data
    arena, w, mask = _as_arrays(rows, weights)
    out = np.asarray(aggregation.masked_trimmed_mean(arena, w, mask, 1))
    dense = np.asarray(arena)
    lo, hi = dense.min(axis=0), dense.max(axis=0)
    assert np.all(out >= lo - 1e-5) and np.all(out <= hi + 1e-5)


@settings(max_examples=40)
@given(
    data=_arenas(min_rows=3, max_rows=7),
    bad_value=st.floats(min_value=-1e6, max_value=1e6),
)
def test_median_resists_minority_corruption(data, bad_value):
    """Corrupt floor((n-1)/2) rows arbitrarily: the median of the full set
    stays inside the honest rows' per-coordinate envelope."""
    rows, weights = data
    honest = np.asarray(rows, np.float32)
    n = honest.shape[0]
    n_bad = (n - 1) // 2
    corrupt = np.full((n_bad, honest.shape[1]), np.float32(bad_value))
    arena = jnp.asarray(np.concatenate([honest, corrupt], axis=0))
    w = jnp.ones((n + n_bad,), jnp.float32)
    mask = jnp.ones((n + n_bad,), jnp.float32)
    med = np.asarray(aggregation.masked_coordinate_median(arena, w, mask))
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert np.all(med >= lo - 1e-4) and np.all(med <= hi + 1e-4)


@settings(max_examples=25)
@given(
    data=_arenas(min_rows=3, max_rows=5),
    bad_value=st.floats(min_value=-1e6, max_value=1e6),
    n_bad=st.integers(min_value=1, max_value=2),
)
def test_trimmed_mean_discards_extremes_it_was_sized_for(data, bad_value, n_bad):
    """With trim_k >= the number of corrupted rows, the trimmed mean over
    honest+corrupt rows stays inside the honest envelope."""
    rows, weights = data
    honest = np.asarray(rows, np.float32)
    n = honest.shape[0]
    trim_k = n_bad
    if 2 * trim_k >= n + n_bad:
        return  # degenerate cohort: the rule falls back to the plain mean
    corrupt = np.full((n_bad, honest.shape[1]), np.float32(bad_value))
    arena = jnp.asarray(np.concatenate([honest, corrupt], axis=0))
    w = jnp.ones((n + n_bad,), jnp.float32)
    mask = jnp.ones((n + n_bad,), jnp.float32)
    out = np.asarray(aggregation.masked_trimmed_mean(arena, w, mask, trim_k))
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert np.all(out >= lo - 1e-4) and np.all(out <= hi + 1e-4)


@settings(max_examples=40)
@given(data=_arenas(min_rows=4))
def test_invalid_rows_never_influence_the_reduce(data):
    """Garbage (NaN / 1e30) in masked-out rows must not leak: the masked
    rule over valid rows + garbage equals the dense rule over valid rows."""
    rows, weights = data
    valid = np.asarray(rows, np.float32)
    garbage = np.full((2, valid.shape[1]), np.nan, np.float32)
    garbage[1] = 1e30
    arena = jnp.asarray(np.concatenate([valid, garbage], axis=0))
    w = jnp.ones((arena.shape[0],), jnp.float32)
    mask = jnp.asarray(
        np.concatenate([np.ones(valid.shape[0]), np.zeros(2)]), jnp.float32
    )
    med = np.asarray(aggregation.masked_coordinate_median(arena, w, mask))
    np.testing.assert_allclose(
        med, np.asarray(aggregation.coordinate_median(jnp.asarray(valid))),
        rtol=1e-6, atol=1e-6,
    )
    if valid.shape[0] > 2:
        tm = np.asarray(aggregation.masked_trimmed_mean(arena, w, mask, 1))
        np.testing.assert_allclose(
            tm, np.asarray(aggregation.trimmed_mean(jnp.asarray(valid), 1)),
            rtol=1e-5, atol=1e-5,
        )
