"""Mesh-sharded aggregation arena: parity with the single-device arena.

Every test runs in a SUBPROCESS with 8 XLA-forced host devices (the
``test_multidevice.py`` pattern) and asserts the acceptance surface of the
sharded arena (``core/store.ArenaStore(mesh=...)``, ``docs/ARENA.md``):

* the ``(n_max, P)`` buffer is laid out column-sharded ``P(None, ("data",))``
  and growth preserves both the sharding and the row contents;
* the masked fused reduction, the staleness-weighted async reduction, and the
  shard_map-ed Pallas kernel all match the single-device arena to ``allclose``
  with **zero collectives** in the compiled HLO;
* the sharded secure masked sum is **bit-identical** to the single-device
  arena secure path;
* the controller produces the same global model with ``arena_mesh=`` as
  without, on sync / semi-sync / async / secure, and the Driver's
  ``arena_shards`` knob plumbs through.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Shared by the controller-parity subprocess scripts: a deterministic linear
# learner identical to the one tests/test_arena.py uses for arena-vs-stack
# parity, so the only varying factor between arms is the arena layout.
_LEARNER = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (
        AsyncProtocol, Controller, Learner, SemiSyncProtocol, SyncProtocol,
    )
    from repro.launch.mesh import make_controller_mesh
    from repro.optim import sgd

    def make_learner(i):
        def loss_fn(p, b):
            return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)
        rng = np.random.default_rng(i)
        X = rng.normal(size=(64, 4)).astype(np.float32)
        y = X @ np.ones((4, 1), np.float32)
        def data_fn(bs):
            j = rng.integers(0, 64, size=bs)
            return X[j], y[j]
        return Learner(
            f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
            data_fn, lambda: (X, y), sgd(0.05), 64,
        )

    def run(proto, mesh, secure=False, async_updates=0, n_learners=3):
        ctrl = Controller(protocol=proto, secure=secure, arena_mesh=mesh)
        ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
        for i in range(n_learners):
            ctrl.register_learner(make_learner(i))
        if async_updates:
            ctrl.engine.run(total_updates=async_updates)
        else:
            ctrl.engine.run(rounds=2)
        out = np.asarray(ctrl.global_params["w"])
        ctrl.shutdown()
        return out, ctrl
"""


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_store_parity_and_no_collectives():
    """Store-level: layout, growth, fused/staleness/Pallas parity, secure
    bit-identity, and a zero-collective compiled reduction."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import aggregation
        from repro.core.secure import secure_fedavg_arena
        from repro.core.store import ArenaStore
        from repro.kernels import ops
        from repro.launch.mesh import make_controller_mesh

        mesh = make_controller_mesh()
        assert mesh.shape["data"] == 8
        P_ = 3000
        sh = ArenaStore(num_params=P_, n_max=4, row_align=1024, mesh=mesh)
        sd = ArenaStore(num_params=P_, n_max=4, row_align=1024)

        # shard layout: P padded to row_align * n_shards, lane-aligned shards
        assert sh.sharded and sh.n_shards == 8
        assert sh.padded_params == 8192 and sh.shard_width == 1024
        assert sh.buffer.sharding.spec == P(None, ("data",))

        bufs, ws = [], []
        for i in range(5):  # 5 > n_max=4: forces growth in both arms
            buf = jax.random.normal(jax.random.key(i), (P_,), jnp.float32)
            sh.write(f"l{i}", buf, weight=10.0 * (i + 1), version=float(i))
            sd.write(f"l{i}", buf, weight=10.0 * (i + 1), version=float(i))
            bufs.append(buf); ws.append(10.0 * (i + 1))
        assert sh.grow_events == 1 and sh.n_max == 8
        assert sh.buffer.sharding.spec == P(None, ("data",))  # growth kept it

        # fused masked reduction parity + zero collectives
        f = aggregation.masked_fedavg_sharded(mesh)
        got = f(sh.buffer, sh.weights, sh.mask)[:P_]
        want = aggregation.masked_weighted_average(sd.buffer, sd.weights, sd.mask)[:P_]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        hlo = f.lower(sh.buffer, sh.weights, sh.mask).compile().as_text()
        for op in ("all-reduce", "all-gather", "all-to-all", "collective-permute"):
            assert f" {op}(" not in hlo, f"unexpected collective {op}"

        # staleness-weighted async reduction parity
        fs = aggregation.masked_staleness_sharded(mesh, alpha=0.5)
        got = fs(sh.buffer, sh.weights, sh.versions, jnp.float32(7.0), sh.mask)[:P_]
        want = aggregation.masked_staleness_average(
            sd.buffer, sd.weights, sd.versions, jnp.float32(7.0), sd.mask, 0.5)[:P_]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

        # shard_map-ed Pallas kernel parity (interpret mode on CPU)
        fk = ops.masked_fedavg_sharded(mesh)
        got = fk(sh.buffer, sh.weights, sh.mask)[:P_]
        want = aggregation.masked_weighted_average(sd.buffer, sd.weights, sd.mask)[:P_]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

        # secure masked sum: bit-identical, sharded accumulator or not
        rows = [sh.row_of(f"l{i}") for i in range(5)]
        got = secure_fedavg_arena(sh.buffer, rows, ws, num_params=P_,
                                  base_seed=3, out_sharding=sh.row_sharding)
        want = secure_fedavg_arena(sd.buffer, rows, ws, num_params=P_, base_seed=3)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        print("SHARDED STORE PARITY OK")
    """)


def test_sharded_controller_parity_sync_semisync_secure():
    """Controller-level: identical global model with and without arena_mesh
    on sync, sync+secure, and semi-sync rounds."""
    _run(_LEARNER + """
    mesh = make_controller_mesh()
    arms = [
        ("sync", lambda: SyncProtocol(local_steps=2, batch_size=16), False),
        ("sync-secure", lambda: SyncProtocol(local_steps=2, batch_size=16), True),
        ("semisync", lambda: SemiSyncProtocol(hyperperiod_s=0.05, batch_size=16), False),
    ]
    for name, mk, secure in arms:
        a, actrl = run(mk(), mesh, secure=secure)
        b, _ = run(mk(), None, secure=secure)
        tol = 1e-3 if secure else 1e-5  # secure: fixed-point quantization
        np.testing.assert_allclose(a, b, atol=tol)
        assert actrl.arena.sharded and actrl.arena.n_shards == 8
        assert actrl.arena.total_writes >= 6
        print(name, "OK")
    print("SHARDED CONTROLLER SYNC/SEMI/SECURE OK")
    """)


def test_sharded_controller_parity_async_and_driver():
    """Async community updates off the sharded arena match the single-device
    arena (one learner keeps arrival order deterministic), and the Driver's
    arena_shards knob builds the controller mesh."""
    _run(_LEARNER + """
    from repro.core import Driver, FederationEnv, TerminationCriteria

    mesh = make_controller_mesh()
    a, actrl = run(AsyncProtocol(local_steps=1, batch_size=8), mesh,
                   async_updates=3, n_learners=1)
    b, _ = run(AsyncProtocol(local_steps=1, batch_size=8), None,
               async_updates=3, n_learners=1)
    np.testing.assert_allclose(a, b, atol=1e-5)
    assert actrl.arena.sharded and actrl.arena.total_writes >= 3
    print("async OK")

    env = FederationEnv(protocol="sync", local_steps=1, batch_size=8,
                        arena_shards=-1,
                        termination=TerminationCriteria(max_rounds=1))
    d = Driver(env)
    d.initialize({"w": jnp.zeros((4, 1))}, [make_learner(0)])
    d.run()
    assert d.controller.arena.sharded and d.controller.arena.n_shards == 8
    print("SHARDED ASYNC + DRIVER OK")
    """)
