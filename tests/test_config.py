"""FederationConfig: validation, from_kwargs, and the FederationEnv bridge.

Pins the knob-consolidation satellite: every machinery knob lives in one
validated frozen dataclass, ``FederationEnv(config=...)`` is the documented
entry point (legacy flat fields stay as aliases), and the Driver threads the
journal/checkpoint knobs through to the Controller.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Driver, FederationConfig, FederationEnv, Learner
from repro.core.driver import TerminationCriteria
from repro.optim import sgd


def _make_learner(i):
    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(16, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)
    return Learner(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        lambda bs: (X, y), lambda: (X, y), sgd(0.05), 16,
    )


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_defaults_are_valid_and_frozen():
    cfg = FederationConfig()
    assert cfg.store_mode == "auto" and cfg.journal_capacity == 4096
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.store_mode = "arena"


@pytest.mark.parametrize(
    "kwargs,match",
    [
        ({"store_mode": "hashmap"}, "store_mode"),
        ({"arena_shards": -2}, "arena_shards"),
        ({"arena_shards": 2, "store_mode": "stack"}, "arena_shards"),
        ({"upload_codec": "zstd"}, "upload_codec"),
        ({"profile_decay": 1.0}, "profile_decay"),
        ({"profile_decay": -0.1}, "profile_decay"),
        ({"prox_mu": -0.5}, "prox_mu"),
        ({"checkpoint_every": 0}, "checkpoint_every"),
        ({"journal_capacity": -1}, "journal_capacity"),
    ],
)
def test_bad_knobs_rejected_at_construction(kwargs, match):
    with pytest.raises(ValueError, match=match):
        FederationConfig(**kwargs)


def test_from_kwargs_rejects_unknown_keys_by_name():
    with pytest.raises(TypeError, match="store_modee"):
        FederationConfig.from_kwargs(store_modee="arena")
    cfg = FederationConfig.from_kwargs(store_mode="arena", journal_capacity=8)
    assert cfg.store_mode == "arena" and cfg.journal_capacity == 8


def test_replace_revalidates():
    cfg = FederationConfig()
    assert cfg.replace(profile_decay=0.9).profile_decay == 0.9
    with pytest.raises(ValueError):
        cfg.replace(profile_decay=2.0)


# ---------------------------------------------------------------------------
# the FederationEnv bridge
# ---------------------------------------------------------------------------


def test_env_builds_config_from_flat_aliases():
    env = FederationEnv(store_mode="stack", upload_codec="int8",
                        profile_decay=0.25, prox_mu=0.125)
    assert env.config == FederationConfig(
        store_mode="stack", upload_codec="int8",
        profile_decay=0.25, prox_mu=0.125,
    )


def test_env_config_wins_and_mirrors_to_aliases():
    cfg = FederationConfig(store_mode="arena", upload_codec="int8",
                           wire_aware=False, profile_decay=0.0, prox_mu=0.5)
    env = FederationEnv(protocol="semi_sync", config=cfg)
    # aliases mirror the config so legacy reads (and make_protocol) agree
    assert env.store_mode == "arena" and env.upload_codec == "int8"
    assert env.wire_aware is False and env.profile_decay == 0.0
    proto = env.make_protocol()
    assert proto.wire_aware is False
    assert proto.size_task(0, {}).prox_mu == 0.5


def test_env_make_protocol_reaches_every_policy():
    from repro.core import (
        BufferedAsyncProtocol,
        DeadlineCohortProtocol,
        ReputationProtocol,
    )

    proto = FederationEnv(protocol="buffered_async", buffer_k=5).make_protocol()
    assert isinstance(proto, BufferedAsyncProtocol) and proto.buffer_k == 5
    proto = FederationEnv(protocol="deadline", deadline_s=2.5).make_protocol()
    assert isinstance(proto, DeadlineCohortProtocol) and proto.deadline_s == 2.5
    proto = FederationEnv(
        protocol="reputation", reputation_fraction=0.25).make_protocol()
    assert isinstance(proto, ReputationProtocol) and proto.fraction == 0.25


def test_env_flat_validation_now_rejects_typos():
    with pytest.raises(ValueError, match="store_mode"):
        FederationEnv(store_mode="hashmap")
    with pytest.raises(ValueError, match="upload_codec"):
        FederationEnv(upload_codec="zstd")


# ---------------------------------------------------------------------------
# Driver threads the knobs through
# ---------------------------------------------------------------------------


def test_driver_threads_journal_and_checkpoint_knobs(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    sink = str(tmp_path / "journal.jsonl")
    cfg = FederationConfig(journal_sink=sink, journal_capacity=16,
                           checkpoint_every=1, checkpoint_dir=ckpt_dir)
    env = FederationEnv(
        config=cfg, local_steps=1, batch_size=8,
        termination=TerminationCriteria(max_rounds=2),
    )
    drv = Driver(env)
    ctrl = drv.controller
    assert ctrl.checkpoint_every == 1 and ctrl.checkpoint_dir == ckpt_dir
    assert ctrl.journal.capacity == 16
    drv.initialize({"w": jnp.zeros((4, 1), jnp.float32)},
                   [_make_learner(0), _make_learner(1)])
    history = drv.run()
    assert len(history) == 2
    from repro.checkpoint.checkpoint import latest_step
    from repro.core import EventJournal

    assert latest_step(ckpt_dir) == 2  # checkpointed every completed round
    recs = EventJournal.read_jsonl(sink)
    assert recs and recs[-1]["kind"] == "engine_stopped"


def test_driver_journal_disabled_via_config():
    env = FederationEnv(config=FederationConfig(journal_capacity=0),
                        termination=TerminationCriteria(max_rounds=1))
    drv = Driver(env)
    assert not drv.controller.journal.enabled
    drv.controller.shutdown()
