"""Secure-aggregation properties: exact mask cancellation, quantization bound,
and upload indistinguishability from the per-learner view."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import secure
from repro.core.aggregation import fedavg


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 6),
    p=st.integers(1, 128),
    seed=st.integers(0, 1000),
)
def test_secure_fedavg_matches_plain(n, p, seed):
    """Masks cancel exactly; the only error is fixed-point quantization,
    bounded by n/(2*scale) per coordinate."""
    buffers = [
        jax.random.normal(jax.random.key(seed + i), (p,), jnp.float32)
        for i in range(n)
    ]
    weights = [float(i + 1) for i in range(n)]
    got = secure.secure_fedavg(buffers, weights, base_seed=seed)
    want = fedavg(jnp.stack(buffers), jnp.asarray(weights))
    bound = n / (2.0 * secure.FIXED_SCALE) + 1e-6
    assert float(jnp.max(jnp.abs(got - want))) <= bound


def test_net_masks_sum_to_zero():
    masker = secure.PairwiseMasker(base_seed=42, participants=(0, 1, 2, 3))
    total = sum(masker.net_mask(i, 64) for i in range(4))
    assert bool(jnp.all(total == 0))


def test_upload_is_masked():
    """A single upload must differ wildly from its plaintext encoding (one-
    time-pad over Z_2^32): check it's not simply the fixed-point encoding."""
    masker = secure.PairwiseMasker(base_seed=7, participants=(0, 1))
    x = jnp.ones((256,), jnp.float32)
    upload = secure.mask_upload(masker, 0, x)
    plain = secure.encode_fixed(x)
    # all-but-vanishing coordinates must be perturbed
    frac_equal = float(jnp.mean((upload == plain).astype(jnp.float32)))
    assert frac_equal < 0.01


def test_masks_change_with_seed_and_pair():
    m1 = secure.PairwiseMasker(1, (0, 1)).net_mask(0, 32)
    m2 = secure.PairwiseMasker(2, (0, 1)).net_mask(0, 32)
    assert not bool(jnp.all(m1 == m2))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_fixed_point_codec_bound(seed):
    x = jax.random.normal(jax.random.key(seed), (512,), jnp.float32) * 10
    back = secure.decode_fixed(secure.encode_fixed(x))
    assert float(jnp.max(jnp.abs(back - x))) <= 0.5 / secure.FIXED_SCALE + 1e-7
