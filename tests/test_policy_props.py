"""Property tests for the LearnerProfile reputation / EWMA algebra.

The reputation-weighted selection policy (``ReputationProtocol``) ranks
learners by ``LearnerProfile.observe_contribution``'s EWMA estimate and
churn decays it (``decay_reputation``); these properties pin the algebra
the policy stands on: bounded estimates, monotone convergence toward a
repeated observation, decay=0 legacy last-sample equivalence, and no NaN
under degenerate zero-valued observations.
"""

import math

import pytest

from hypothesis_compat import given, settings, st
from repro.core import LearnerProfile


@settings(max_examples=50)
@given(
    decay=st.floats(min_value=0.0, max_value=0.99),
    scores=st.lists(
        st.floats(min_value=0.0, max_value=1.0), min_size=1, max_size=20
    ),
)
def test_reputation_stays_inside_observed_range(decay, scores):
    prof = LearnerProfile(decay=decay)
    for s in scores:
        est = prof.observe_contribution(s)
        assert min(scores) - 1e-9 <= est <= max(scores) + 1e-9
    assert prof.rep_observations == len(scores)


@settings(max_examples=50)
@given(
    decay=st.floats(min_value=0.0, max_value=0.99),
    start=st.floats(min_value=0.0, max_value=1.0),
    target=st.floats(min_value=0.0, max_value=1.0),
    n=st.integers(min_value=1, max_value=30),
)
def test_repeated_observation_converges_monotonically(decay, start, target, n):
    prof = LearnerProfile(decay=decay)
    prof.observe_contribution(start)
    gap = abs(prof.reputation() - target)
    for _ in range(n):
        prof.observe_contribution(target)
        new_gap = abs(prof.reputation() - target)
        assert new_gap <= gap + 1e-9  # never moves away from the target
        gap = new_gap
    assert gap <= abs(start - target) * decay**n + 1e-6


@settings(max_examples=50)
@given(
    scores=st.lists(
        st.floats(min_value=0.0, max_value=1.0), min_size=1, max_size=20
    )
)
def test_decay_zero_is_legacy_last_sample(scores):
    prof = LearnerProfile(decay=0.0)
    for s in scores:
        prof.observe_contribution(s)
        assert prof.reputation() == pytest.approx(s)


@settings(max_examples=50)
@given(n=st.integers(min_value=1, max_value=10),
       decay=st.floats(min_value=0.0, max_value=0.99))
def test_zero_observations_never_produce_nan(n, decay):
    prof = LearnerProfile(decay=decay)
    for _ in range(n):
        prof.observe_step_time(0.0)
        prof.observe_contribution(0.0)
    assert math.isfinite(prof.reputation())
    assert prof.reputation() == 0.0
    assert math.isfinite(float(prof["seconds_per_step"]))


@settings(max_examples=50)
@given(
    rep=st.floats(min_value=0.0, max_value=1.0),
    absent=st.integers(min_value=0, max_value=20),
    rate=st.floats(min_value=0.1, max_value=0.99),
)
def test_decay_reputation_algebra(rep, absent, rate):
    prof = LearnerProfile(decay=0.5)
    prof.observe_contribution(rep)
    out = prof.decay_reputation(absent, rate=rate)
    assert out == pytest.approx(rep * rate**absent)
    assert math.isfinite(out)
    # zero rounds absent is the identity
    assert prof.decay_reputation(0, rate=rate) == pytest.approx(out)


def test_decay_reputation_on_unobserved_profile_is_default():
    prof = LearnerProfile(decay=0.5)
    assert prof.decay_reputation(5) == 1.0  # default reputation, undecayed
    assert prof.reputation() == 1.0
    assert prof.rep_observations == 0


@settings(max_examples=30)
@given(
    a=st.floats(min_value=0.0, max_value=1.0),
    b=st.floats(min_value=0.0, max_value=1.0),
    decay=st.floats(min_value=0.0, max_value=0.99),
)
def test_first_observation_seeds_the_estimate(a, b, decay):
    """The first observation is taken whole (no bias toward an implicit 0)."""
    prof = LearnerProfile(decay=decay)
    assert prof.observe_contribution(a) == pytest.approx(a)
    expected = decay * a + (1.0 - decay) * b
    assert prof.observe_contribution(b) == pytest.approx(expected)
