"""Flight recorder: ring/JSONL semantics, determinism, replay, slow sinks.

Pins the tentpole's journal contracts:

* ring capacity bounds memory; ``capacity=0`` with no sink disables
  recording entirely (the bench baseline's ``record()`` early-exit);
* with an injected deterministic clock, two identical runs emit
  byte-identical JSONL — the "same seed ⇒ same journal" replayability claim;
* ``replay()`` reconstructs per-round provenance (cohort, arrivals,
  staleness histogram, policy decision, wire deltas);
* a file sink is written off the engine loop thread (a deliberately slow
  sink must not stretch ``record()``), yet ``EngineStopped`` flushes
  synchronously so the JSONL on disk is complete when ``run()`` returns.
"""

import itertools
import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Controller,
    EvalReport,
    EventJournal,
    Learner,
    LocalUpdate,
    SyncProtocol,
)
from repro.core.journal import jsonable
from repro.optim import sgd


def _make_learner(i):
    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(16, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)

    class _Fixed(Learner):
        # Fixed reported step time: measured wall-clock is the one
        # nondeterministic field a learner produces, and it must not leak
        # into the journal's determinism contract via profile-driven sizing.
        def fit(self, params, task):
            update = super().fit(params, task)
            update.seconds_per_step = 1e-3
            return update

    return _Fixed(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        lambda bs: (X, y), lambda: (X, y), sgd(0.05), 16,
    )


def _run_federation(journal, rounds=2, n=3):
    # One dispatch worker ⇒ uploads arrive in cohort order: the event
    # sequence itself is deterministic, so JSONL byte-identity is testable
    # (with concurrent workers, arrival order is scheduler-dependent).
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8),
                      max_dispatch_workers=1, journal=journal)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1), jnp.float32)})
    for i in range(n):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=rounds)
    ctrl.shutdown()
    return ctrl


# ---------------------------------------------------------------------------
# ring / enablement
# ---------------------------------------------------------------------------


def test_ring_capacity_bounds_memory():
    j = EventJournal(capacity=3, clock=lambda: 0.0)
    for i in range(10):
        j.record(object(), i=i)
    recs = j.records()
    assert len(recs) == 3
    assert [r["i"] for r in recs] == [7, 8, 9]  # oldest evicted first
    assert j.cursor == 10  # cursor counts everything ever recorded


def test_capacity_zero_without_sink_disables_recording():
    j = EventJournal(capacity=0)
    assert not j.enabled
    assert j.record(object()) is None
    assert j.records() == [] and j.cursor == 0


def test_capacity_zero_with_sink_still_records(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = EventJournal(capacity=0, sink=path, clock=lambda: 0.0)
    assert j.enabled
    j.record(object(), tag="x")
    j.close()
    (rec,) = EventJournal.read_jsonl(path)
    assert rec["kind"] == "external" and rec["tag"] == "x"
    assert j.records() == []  # nothing retained in memory


def test_negative_capacity_rejected():
    with pytest.raises(ValueError):
        EventJournal(capacity=-1)


def test_jsonable_coercion():
    assert jsonable(np.float32(1.5)) == 1.5
    assert jsonable(jnp.int32(3)) == 3
    assert jsonable({"a": (np.int64(1), [np.bool_(True)])}) == {"a": [1, [True]]}
    assert isinstance(jsonable(object()), str)  # repr fallback always works
    json.dumps(jsonable({"x": np.arange(2)}))  # arrays never crash encoding


# ---------------------------------------------------------------------------
# determinism + replay
# ---------------------------------------------------------------------------


def test_identical_runs_emit_identical_jsonl():
    def one_run():
        counter = itertools.count()
        journal = EventJournal(clock=lambda: float(next(counter)))
        _run_federation(journal, rounds=2, n=3)
        return journal.to_jsonl()

    a, b = one_run(), one_run()
    assert a == b  # byte-identical, timestamps included (injected clock)
    assert a.count("\n") > 0


def test_replay_reconstructs_round_provenance():
    journal = EventJournal(clock=lambda: 0.0)
    ctrl = _run_federation(journal, rounds=2, n=3)
    summaries = journal.replay()
    done = [s for s in summaries if s.aggregated]
    assert [s.round_id for s in done] == [0, 1]
    for s in done:
        assert sorted(s.cohort) == ["l0", "l1", "l2"]  # dispatch order kept
        assert sorted(s.arrivals) == ["l0", "l1", "l2"]
        assert s.staleness == {0: 3}  # sync: nobody lags the model version
        assert s.n_arrived == 3
        assert s.weighting == ctrl.protocol.weighting()
        assert s.trigger in s.arrivals
        assert "eval_loss" in s.metrics
    # wire deltas: every round moves the same envelope volume both ways
    down = ctrl.manifest.total_bytes
    up = 4 * ctrl.arena.padded_params
    # round 0's aggregate happens before its eval fan-out, so its down delta
    # covers only the train dispatch; round 1's covers round 0's eval + its
    # own train dispatch.
    assert done[0].down_bytes == 3 * down
    assert done[1].down_bytes == 6 * down
    assert done[0].up_bytes == done[1].up_bytes == 3 * up
    assert done[0].model_version == 0 and done[1].model_version == 1


def test_replay_from_jsonl_file_roundtrip(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    journal = EventJournal(sink=path, clock=lambda: 0.0)
    _run_federation(journal, rounds=2, n=2)
    from_file = journal.replay(EventJournal.read_jsonl(path))
    from_ring = journal.replay()
    assert [s.__dict__ for s in from_file] == [s.__dict__ for s in from_ring]


def test_external_events_journal_without_crashing():
    class Oddball:
        pass

    j = EventJournal(clock=lambda: 0.0)
    j.record(Oddball(), note="posted via engine.post")
    (rec,) = j.records()
    assert rec["kind"] == "external" and rec["type"] == "Oddball"
    assert j.replay() == []  # no round info: nothing to fold


# ---------------------------------------------------------------------------
# sink: off-loop writes + flush-on-stop
# ---------------------------------------------------------------------------


class _SlowSink:
    """A text sink whose write() stalls, emulating a laggy filesystem."""

    def __init__(self, delay_s):
        self.delay_s = delay_s
        self.lines = []
        self.writer_threads = set()

    def write(self, s):
        self.writer_threads.add(threading.get_ident())
        time.sleep(self.delay_s)
        self.lines.append(s)

    def flush(self):
        pass


def test_slow_sink_does_not_block_record():
    sink = _SlowSink(delay_s=0.002)
    j = EventJournal(sink=sink, clock=lambda: 0.0)
    n = 200
    t0 = time.perf_counter()
    for i in range(n):
        j.record(object(), i=i)
    recording_s = time.perf_counter() - t0
    j.close()
    # Synchronous writes would take >= n * delay = 0.4s; buffered recording
    # must finish in a small fraction of that.
    assert recording_s < n * sink.delay_s / 4
    assert len(sink.lines) == n  # close() drained everything
    assert threading.get_ident() not in sink.writer_threads  # off-thread


def test_slow_sink_federation_round_not_stretched():
    """The 16-thread hammer with a laggy sink: the engine loop must not
    serialize on sink writes (regression for satellite journal-off-thread).
    Bound: a round emits ~50 records; synchronous 5ms writes would add
    >= 0.25s per round."""
    sink = _SlowSink(delay_s=0.005)
    journal = EventJournal(sink=sink, clock=lambda: 0.0)
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8),
                      max_dispatch_workers=16, arena_n_max=16,
                      journal=journal)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1), jnp.float32)})
    for i in range(16):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=1)  # warmup: jit compiles outside the timed round
    (t,) = ctrl.engine.run(rounds=1)
    ctrl.shutdown()
    per_round_records = 16 * 2 + 2  # dispatches + uploads + agg + eval
    assert t.federation_round_s < per_round_records * sink.delay_s / 2
    assert len(sink.lines) == journal.cursor  # nothing lost


def test_engine_stopped_flushes_sink_synchronously(tmp_path):
    path = str(tmp_path / "j.jsonl")
    journal = EventJournal(sink=path, flush_interval_s=60.0,  # never on timer
                           clock=lambda: 0.0)
    _run_federation(journal, rounds=1, n=2)
    # run() has returned; without waiting for any flusher tick the sink must
    # already hold every record, ending with the engine_stopped marker.
    recs = EventJournal.read_jsonl(path)
    assert len(recs) == journal.cursor
    assert recs[-1]["kind"] == "engine_stopped"
    assert recs[-1]["completed"] == 1 and recs[-1]["error"] is None


def test_engine_stopped_records_error(tmp_path):
    class _Failing(Learner):
        def fit(self, params, task):
            raise RuntimeError("boom in fit")

    dummy = lambda *a, **k: None  # noqa: E731
    path = str(tmp_path / "j.jsonl")
    journal = EventJournal(sink=path, clock=lambda: 0.0)
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=1),
                      journal=journal)
    ctrl.set_initial_model({"w": jnp.zeros((4,), jnp.float32)})
    ctrl.register_learner(_Failing("bad", dummy, dummy, dummy, dummy,
                                   sgd(0.1), 1))
    with pytest.raises(RuntimeError, match="boom in fit"):
        ctrl.engine.run(rounds=1)
    ctrl.shutdown()
    recs = EventJournal.read_jsonl(path)
    assert recs[-1]["kind"] == "engine_stopped"
    assert recs[-1]["completed"] == 0
    assert "boom in fit" in recs[-1]["error"]


def test_journal_knobs_reach_controller(tmp_path):
    path = str(tmp_path / "j.jsonl")
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8),
                      journal_sink=path, journal_capacity=7)
    assert ctrl.journal is ctrl.engine.journal
    assert ctrl.journal.capacity == 7
    ctrl.set_initial_model({"w": jnp.zeros((4, 1), jnp.float32)})
    ctrl.register_learner(_make_learner(0))
    ctrl.engine.run(rounds=1)
    ctrl.shutdown()
    assert len(EventJournal.read_jsonl(path)) == ctrl.journal.cursor

    off = Controller(protocol=SyncProtocol(), journal_capacity=0)
    assert not off.journal.enabled
    off.shutdown()
