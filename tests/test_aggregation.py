"""Aggregation-rule tests: FedAvg correctness + invariants, robust rules,
staleness weighting, naive-baseline equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import aggregation, naive


def _rand_stack(n, p, seed=0):
    return jax.random.normal(jax.random.key(seed), (n, p), jnp.float32)


def test_fedavg_uniform_is_mean():
    stack = _rand_stack(5, 100)
    out = aggregation.fedavg(stack, jnp.ones((5,)))
    np.testing.assert_allclose(out, jnp.mean(stack, 0), rtol=1e-5, atol=1e-7)


def test_fedavg_weighted():
    stack = jnp.stack([jnp.zeros((10,)), jnp.ones((10,))])
    out = aggregation.fedavg(stack, jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(out, 0.75 * jnp.ones((10,)), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 8),
    p=st.integers(1, 64),
    seed=st.integers(0, 100),
)
def test_fedavg_invariants(n, p, seed):
    """Convexity: the average lies inside the per-coordinate envelope, and
    aggregation is permutation-invariant."""
    stack = _rand_stack(n, p, seed)
    w = jax.random.uniform(jax.random.key(seed + 1), (n,)) + 0.01
    out = aggregation.fedavg(stack, w)
    assert bool(jnp.all(out <= jnp.max(stack, 0) + 1e-5))
    assert bool(jnp.all(out >= jnp.min(stack, 0) - 1e-5))
    perm = jax.random.permutation(jax.random.key(seed + 2), n)
    out_p = aggregation.fedavg(stack[perm], w[perm])
    np.testing.assert_allclose(out, out_p, rtol=1e-5, atol=1e-6)


def test_fedavg_zero_weights_falls_back_uniform():
    stack = _rand_stack(4, 16)
    out = aggregation.fedavg(stack, jnp.zeros((4,)))
    np.testing.assert_allclose(out, jnp.mean(stack, 0), rtol=1e-5)


def test_median_resists_outlier():
    base = jnp.ones((5, 32))
    stack = base.at[0].set(1e6)  # byzantine learner
    out = aggregation.coordinate_median(stack)
    np.testing.assert_allclose(out, jnp.ones((32,)), rtol=1e-6)


def test_trimmed_mean_resists_outliers():
    stack = jnp.concatenate([jnp.ones((4, 8)), jnp.full((1, 8), 1e9)], 0)
    out = aggregation.trimmed_mean(stack, trim_k=1)
    np.testing.assert_allclose(out, jnp.ones((8,)), rtol=1e-6)
    with pytest.raises(ValueError):
        aggregation.trimmed_mean(stack, trim_k=3)


def test_staleness_weights_monotone():
    n = jnp.ones((4,)) * 100
    s = jnp.asarray([0.0, 1.0, 5.0, 50.0])
    w = aggregation.staleness_weights(n, s, alpha=0.5)
    assert bool(jnp.all(jnp.diff(w) < 0))  # staler -> strictly less weight
    np.testing.assert_allclose(w[0], 100.0)


def test_naive_aggregate_matches_fused():
    """The paper's old-controller baseline must be numerically equivalent —
    it is only *slower*, which benchmarks/bench_agg.py quantifies."""
    models = []
    for i in range(4):
        k = jax.random.key(i)
        models.append({
            "w1": jax.random.normal(k, (16, 8)),
            "b1": jax.random.normal(jax.random.fold_in(k, 1), (8,)),
        })
    weights = [1.0, 2.0, 3.0, 4.0]
    out_naive = naive.naive_aggregate(models, weights)

    from repro.core import packing
    stack = jnp.stack([packing.pack_numeric(m) for m in models])
    out_fused = aggregation.fedavg(stack, jnp.asarray(weights))
    m = packing.build_manifest(models[0])
    out_fused_tree = packing.unpack_numeric(out_fused, m)
    for a, b in zip(jax.tree_util.tree_leaves(out_naive),
                    jax.tree_util.tree_leaves(out_fused_tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_naive_serialize_roundtrip():
    params = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    blobs = naive.naive_serialize(params)
    back = naive.naive_deserialize(blobs, jax.tree_util.tree_structure(params))
    np.testing.assert_array_equal(back["w"], params["w"])
