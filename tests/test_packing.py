"""Packing / wire-format tests: roundtrip properties, manifest integrity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import packing

# -- strategies --------------------------------------------------------------

_dtypes = st.sampled_from([jnp.float32, jnp.bfloat16, jnp.float16, jnp.int32])
_shapes = st.lists(st.integers(1, 5), min_size=0, max_size=3).map(tuple)


@st.composite
def pytrees(draw):
    n = draw(st.integers(1, 5))
    tree = {}
    for i in range(n):
        shape = draw(_shapes)
        dtype = draw(_dtypes)
        size = int(np.prod(shape)) if shape else 1
        vals = draw(
            st.lists(
                st.floats(-100, 100, allow_nan=False, width=16),
                min_size=size, max_size=size,
            )
        )
        arr = jnp.asarray(np.array(vals, np.float32).reshape(shape)).astype(dtype)
        tree[f"leaf_{i}"] = arr
    return tree


# -- properties --------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(pytrees())
def test_numeric_roundtrip(tree):
    m = packing.build_manifest(tree)
    buf = packing.pack_numeric(tree)
    assert buf.shape == (m.total_elements,)
    back = packing.unpack_numeric(buf, m)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-2, atol=1e-2
        )


@settings(max_examples=30, deadline=None)
@given(pytrees())
def test_bytes_roundtrip_bitexact(tree):
    buf, m = packing.pack_bytes(tree)
    assert buf.dtype == np.uint8 and buf.shape == (m.total_bytes,)
    back = packing.unpack_bytes(buf, m)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@settings(max_examples=30, deadline=None)
@given(pytrees())
def test_pack_bytes_from_numeric_matches_pytree_pack(tree):
    """The broadcast fast path (wire bytes straight off the flat numeric
    buffer) must serialize exactly what the numeric state decodes to."""
    m = packing.build_manifest(tree)
    num = packing.pack_numeric(tree)
    want, _ = packing.pack_bytes(packing.unpack_numeric(num, m))
    got = packing.pack_bytes_from_numeric(num, m)
    assert got.dtype == np.uint8
    assert want.tobytes() == got.tobytes()
    # zero-padded tails (arena row alignment) never reach the wire
    padded = packing.pack_numeric(tree, pad_to=128)
    assert packing.pack_bytes_from_numeric(padded, m).tobytes() == want.tobytes()


def test_manifest_offsets_contiguous():
    tree = {"a": jnp.zeros((3, 4)), "b": jnp.zeros((5,), jnp.bfloat16), "c": jnp.zeros(())}
    m = packing.build_manifest(tree)
    offset = 0
    for spec in m.specs:
        assert spec.offset == offset
        offset += spec.size
    assert m.total_elements == offset == 3 * 4 + 5 + 1


def test_pack_numeric_jit_compatible():
    tree = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    out = jax.jit(packing.pack_numeric)(tree)
    assert out.shape == (20,)


def test_num_params():
    tree = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,)), "s": jnp.zeros(())}
    assert packing.num_params(tree) == 21


def test_unpack_restores_structure():
    tree = {"outer": {"inner": [jnp.ones((2,)), jnp.zeros((3,))]}}
    m = packing.build_manifest(tree)
    back = packing.unpack_numeric(packing.pack_numeric(tree), m)
    assert jax.tree_util.tree_structure(back) == jax.tree_util.tree_structure(tree)
