"""Multi-device tests: run in a SUBPROCESS with 8 forced host devices so the
main test process keeps 1 device (smoke tests must not see 512).

Covers: sharded zero-collective aggregation, hierarchical pod-axis FedAvg,
expert-parallel MoE on a real (2,2) mesh, and a reduced train_step under pjit
on a (2,2,2) pod mesh — the same code paths the production dry-run lowers.
"""

import os
import subprocess
import sys
import textwrap

import pytest

# Every test here spawns a subprocess with XLA-forced host devices; the CI
# tier-1 lane runs them (8 forced devices) to exercise real mesh sharding.
pytestmark = pytest.mark.multidevice

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_fedavg_sharded_no_collectives():
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.aggregation import weighted_average
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        stack = jax.random.normal(jax.random.key(0), (5, 4096), jnp.float32)
        w = jnp.arange(1., 6.)
        fn = jax.jit(
            weighted_average,
            in_shardings=(NamedSharding(mesh, P(None, ("data","model"))),
                          NamedSharding(mesh, P())),
            out_shardings=NamedSharding(mesh, P(("data","model"))),
        )
        with mesh:
            lowered = fn.lower(stack, w)
            compiled = lowered.compile()
            hlo = compiled.as_text()
            for op in ("all-reduce", "all-gather", "all-to-all", "collective-permute"):
                assert f" {op}(" not in hlo, f"unexpected collective {op}"
            got = fn(stack, w)
        want = weighted_average(stack, w)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5
        print("NO-COLLECTIVE AGG OK")
    """)


def test_hierarchical_pod_fedavg():
    _run("""
        import jax, jax.numpy as jnp
        from repro.core.aggregation import hierarchical_fedavg, weighted_average
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        n_pods, P_ = 2, 1024
        stack = jax.random.normal(jax.random.key(0), (n_pods, P_), jnp.float32)
        w = jnp.asarray([1.0, 3.0])
        with mesh:
            agg = jax.jit(hierarchical_fedavg(mesh))(stack, w)
        want = weighted_average(stack, w)
        err = float(jnp.max(jnp.abs(agg - want)))
        assert err < 1e-5, err
        print("HIERARCHICAL AGG OK")
    """)


def test_moe_ep_on_2x2_mesh_matches_dense():
    _run("""
        import jax, jax.numpy as jnp
        from repro.models.config import ModelConfig
        from repro.models import layers
        from repro.models.sharding import make_policy
        cfg = ModelConfig(name='t', arch_type='moe', n_layers=1, d_model=32,
                          n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=100,
                          n_experts=4, top_k=2, moe_d_ff=48, n_shared_experts=1,
                          shared_d_ff=48, capacity_factor=4.0)
        p = layers.init_moe(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 8, 32), jnp.float32)
        y_dense, _ = layers.apply_moe_dense(p, x, cfg)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        pol = make_policy(cfg, mesh)
        with mesh:
            y_ep, _ = jax.jit(lambda pp, xx: layers.apply_moe_ep(pp, xx, cfg, pol))(p, x)
        err = float(jnp.max(jnp.abs(y_dense - y_ep)))
        assert err < 1e-4, err
        print("MOE EP 2x2 OK")
    """)


def test_reduced_train_step_on_pod_mesh():
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.launch.specs import input_specs
        from repro.launch.steps import make_train_step
        from repro.models import transformer
        from repro.models.sharding import make_policy
        from repro.optim import sgd
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_reduced("qwen3-14b")
        pol = make_policy(cfg, mesh, multi_pod=True, fsdp=True)
        params = transformer.init_params(jax.random.key(0), cfg)
        opt = sgd(0.1)
        B, S = 4, 16
        tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        step = make_train_step(cfg, opt, pol)
        with mesh:
            newp, _, loss = jax.jit(step)(params, opt.init(params), batch)
        assert bool(jnp.isfinite(loss)), float(loss)
        # distributed result must match single-device execution
        step1 = make_train_step(cfg, opt, None)
        newp1, _, loss1 = jax.jit(step1)(params, opt.init(params), batch)
        assert abs(float(loss) - float(loss1)) < 1e-3, (float(loss), float(loss1))
        print("POD-MESH TRAIN STEP OK", float(loss))
    """)


def test_serve_step_with_sharded_cache():
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.launch.steps import make_serve_step
        from repro.models import kvcache, transformer
        from repro.models.sharding import make_policy

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = get_reduced("gemma3-4b")
        pol = make_policy(cfg, mesh)
        params = transformer.init_params(jax.random.key(0), cfg)
        B = 4
        caches = kvcache.init_cache(cfg, B, 32)
        tok = jnp.zeros((B, 1), jnp.int32)
        step = make_serve_step(cfg, pol)
        with mesh:
            nxt, caches = jax.jit(step)(params, caches, tok, jnp.asarray(0, jnp.int32), None)
        assert nxt.shape == (B, 1)
        assert int(nxt.max()) < cfg.padded_vocab_size
        print("SHARDED SERVE OK")
    """)


@pytest.mark.slow
def test_flash_decode_matches_unsharded():
    """shard_map flash-decoding (seq-sharded cache) must equal the plain
    decode path — GQA + sliding + MLA, on a real (2,2) mesh."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import kvcache, transformer
        from repro.models.sharding import make_policy

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        for arch in ("gemma3-4b", "deepseek-v3-671b", "qwen3-14b"):
            cfg = dataclasses.replace(get_reduced(arch), dtype=jnp.float32)
            pol = make_policy(cfg, mesh)
            params = transformer.init_params(jax.random.key(0), cfg)
            B, S = 4, 8
            toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
            # unsharded reference
            cache_r = kvcache.init_cache(cfg, B, 16, dtype=jnp.float32)
            outs_r = []
            for t in range(S):
                lg, cache_r = transformer.decode_step(
                    params, toks[:, t:t+1], cache_r, jnp.asarray(t, jnp.int32), cfg)
                outs_r.append(lg)
            ref = jnp.concatenate(outs_r, 1)
            # sharded flash decode
            cache_s = kvcache.init_cache(cfg, B, 16, dtype=jnp.float32)
            outs_s = []
            with mesh:
                step = jax.jit(lambda p, c, t, i: transformer.decode_step(
                    p, t, c, i, cfg, policy=pol))
                for t in range(S):
                    lg, cache_s = step(params, cache_s, toks[:, t:t+1],
                                       jnp.asarray(t, jnp.int32))
                    outs_s.append(lg)
            got = jnp.concatenate(outs_s, 1)
            err = float(jnp.max(jnp.abs(got - ref)))
            assert err < 2e-3, (arch, err)
            print(arch, "flash-decode err", err)
        print("FLASH DECODE OK")
    """)


def test_moe_2d_decode_matches_unsharded():
    """Weights-stationary 2D expert-parallel decode (serving layout) must
    match the single-device decode output."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import kvcache, transformer
        from repro.models.sharding import make_policy

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = dataclasses.replace(get_reduced("deepseek-v3-671b"), dtype=jnp.float32,
                                  mtp_depth=0)
        pol = make_policy(cfg, mesh, fsdp=True, serving=True)
        params = transformer.init_params(jax.random.key(0), cfg)
        B, S = 4, 6
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        cache_r = kvcache.init_cache(cfg, B, 8, dtype=jnp.float32)
        outs_r = []
        for t in range(S):
            lg, cache_r = transformer.decode_step(
                params, toks[:, t:t+1], cache_r, jnp.asarray(t, jnp.int32), cfg)
            outs_r.append(lg)
        ref = jnp.concatenate(outs_r, 1)
        cache_s = kvcache.init_cache(cfg, B, 8, dtype=jnp.float32)
        outs_s = []
        with mesh:
            step = jax.jit(lambda p, c, t, i: transformer.decode_step(
                p, t, c, i, cfg, policy=pol))
            for t in range(S):
                lg, cache_s = step(params, cache_s, toks[:, t:t+1],
                                   jnp.asarray(t, jnp.int32))
                outs_s.append(lg)
        got = jnp.concatenate(outs_s, 1)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 5e-3, err
        print("2D-EP DECODE OK", err)
    """)
