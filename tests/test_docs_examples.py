"""Execute every fenced ``python`` example in the documentation.

Docs rot when their examples stop running.  This module collects every
```` ```python ```` code fence from ``docs/*.md``, ``README.md`` and
``benchmarks/README.md`` and executes each one in a fresh namespace, so a
signature change that breaks a documented example fails CI (the docs lane in
``.github/workflows/ci.yml``) instead of silently shipping.

Fences in other languages (bash, text) are ignored.  Examples are written to
be single-device-safe and fast (tiny arenas); anything needing a real mesh
uses ``make_controller_mesh(1)``.
"""

from __future__ import annotations

import pathlib
import re

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _doc_files() -> list[pathlib.Path]:
    files = sorted((_ROOT / "docs").glob("*.md"))
    for extra in (_ROOT / "README.md", _ROOT / "benchmarks" / "README.md"):
        if extra.exists():
            files.append(extra)
    return files


def _snippets() -> list[tuple[str, int, str]]:
    out = []
    for path in _doc_files():
        for i, m in enumerate(_FENCE.finditer(path.read_text())):
            out.append((str(path.relative_to(_ROOT)), i, m.group(1)))
    return out


_SNIPPETS = _snippets()


def test_docs_have_python_examples():
    """The three docs pages exist and at least some examples are executable."""
    names = {f for f, _, _ in _SNIPPETS}
    for page in ("docs/ARCHITECTURE.md", "docs/ARENA.md", "docs/PROTOCOLS.md"):
        assert (_ROOT / page).exists(), f"{page} missing"
    assert len(_SNIPPETS) >= 5, names


@pytest.mark.parametrize(
    "relpath,index,code",
    _SNIPPETS,
    ids=[f"{f}#{i}" for f, i, _ in _SNIPPETS],
)
def test_docs_example_runs(relpath, index, code):
    """Each fenced python example must execute cleanly in a fresh namespace."""
    compiled = compile(code, f"{relpath}#fence{index}", "exec")
    exec(compiled, {"__name__": f"docs_example_{index}"})
