"""Unified telemetry registry: instruments, shims, and wire reconciliation.

Pins the tentpole's metrics contract:

* :class:`Telemetry` get-or-create semantics (same name ⇒ same instrument,
  kind mismatch raises) and the ``value``/``snapshot`` read surface;
* every deprecated attribute shim (``channel.stats.*``,
  ``controller.dispatch_serializations``, store counters) reads the exact
  same instrument the registry exposes;
* the counters reconcile against exact byte/message counts computed from
  first principles after a real federation run — the same formulas
  ``tests/test_dispatch.py`` asserts on the shims.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ArenaStore,
    Channel,
    Controller,
    Counter,
    EvalReport,
    Gauge,
    Histogram,
    Learner,
    LocalUpdate,
    ModelStore,
    SyncProtocol,
    Telemetry,
)
from repro.optim import sgd


def _make_learner(i):
    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(16, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)
    return Learner(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        lambda bs: (X, y), lambda: (X, y), sgd(0.05), 16,
    )


# ---------------------------------------------------------------------------
# registry basics
# ---------------------------------------------------------------------------


def test_get_or_create_returns_same_instrument():
    t = Telemetry()
    c1 = t.counter("a.b")
    c2 = t.counter("a.b")
    assert c1 is c2
    c1.add(3)
    assert t.value("a.b") == 3 and isinstance(t.value("a.b"), int)


def test_kind_mismatch_raises():
    t = Telemetry()
    t.counter("x")
    with pytest.raises(ValueError, match="counter"):
        t.gauge("x")
    with pytest.raises(ValueError):
        t.histogram("x")


def test_counter_monotonic():
    c = Counter("n")
    c.add(2)
    c.add(0.5)
    assert c.value == 2.5
    with pytest.raises(ValueError):
        c.add(-1)


def test_gauge_last_set_wins():
    g = Gauge("v")
    g.set(7)
    g.set(3)
    assert g.value == 3


def test_histogram_summary_and_mean():
    h = Histogram("lat")
    assert h.mean == 0.0
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    assert h.mean == pytest.approx(2.0)
    r = h.render()
    assert r == {"count": 3, "sum": 6.0, "min": 1.0, "max": 3.0, "last": 2.0}


def test_value_default_and_histogram_mean():
    t = Telemetry()
    assert t.value("missing") == 0
    assert t.value("missing", default=None) is None
    t.histogram("h").observe(4.0)
    assert t.value("h") == 4.0


def test_snapshot_is_sorted_jsonable():
    t = Telemetry()
    t.counter("z.last").add(1)
    t.gauge("a.first").set(2)
    t.histogram("m.mid").observe(0.5)
    snap = t.snapshot()
    assert list(snap) == sorted(snap)
    json.dumps(snap)  # JSON-able end to end
    assert t.names() == ["a.first", "m.mid", "z.last"]


# ---------------------------------------------------------------------------
# shims read the registry
# ---------------------------------------------------------------------------


def test_channel_stats_shim_reads_registry():
    ch = Channel()
    ch.send({"w": jnp.zeros((50,), jnp.float32)})
    assert ch.stats.messages == ch.telemetry.value("channel.messages") == 1
    assert ch.stats.bytes_moved == ch.telemetry.value("channel.bytes_moved") == 200
    assert ch.stats.serializations == 1
    assert ch.stats.total_bytes == ch.stats.bytes_moved  # no uploads yet


def test_store_shims_and_bind_telemetry_carries_values():
    store = ModelStore()
    from repro.core import ModelRecord

    store.insert(ModelRecord("l0", 0, jnp.zeros((8,), jnp.float32), 1))
    assert store.total_inserts == 1 and store.bytes_ingested == 32
    shared = Telemetry()
    store.bind_telemetry(shared)
    assert shared.value("store.model.total_inserts") == 1
    assert shared.value("store.model.bytes_ingested") == 32
    store.insert(ModelRecord("l1", 0, jnp.zeros((8,), jnp.float32), 1))
    assert shared.value("store.model.total_inserts") == store.total_inserts == 2


def test_arena_counters_in_registry():
    t = Telemetry()
    arena = ArenaStore(num_params=16, n_max=1, row_align=16, telemetry=t)
    arena.write("a", jnp.zeros((16,), jnp.float32), weight=1.0)
    arena.write("b", jnp.ones((16,), jnp.float32), weight=1.0)  # forces grow
    assert t.value("store.arena.total_writes") == arena.total_writes == 2
    assert t.value("store.arena.bytes_ingested") == arena.bytes_ingested == 128
    assert t.value("store.arena.grow_events") == arena.grow_events == 1


# ---------------------------------------------------------------------------
# reconciliation: registry values == exact wire math after a real run
# ---------------------------------------------------------------------------


def test_federation_counters_reconcile_exactly():
    n, rounds = 3, 2
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1), jnp.float32)})
    for i in range(n):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=rounds)
    ctrl.shutdown()

    tm = ctrl.telemetry
    assert tm is ctrl.channel.telemetry  # one registry for the federation
    down = ctrl.manifest.total_bytes
    row_bytes = 4 * ctrl.arena.padded_params

    # downlink: train + eval fan-out each round, one serialization per model
    # version (round models + the final post-aggregation eval model)
    assert tm.value("channel.messages") == 2 * n * rounds
    assert tm.value("channel.bytes_moved") == 2 * n * rounds * down
    assert tm.value("channel.serializations") == rounds + 1
    assert tm.value("controller.dispatch_serializations") == rounds + 1
    # uplink: one measured upload per train task, flat fast path only
    assert tm.value("channel.upload_messages") == n * rounds
    assert tm.value("channel.upload_serializations") == n * rounds
    assert tm.value("channel.upload_bytes") == n * rounds * row_bytes
    assert tm.value("controller.upload_fallback_packs") == 0
    # store: every upload became one arena row write
    assert tm.value("store.arena.total_writes") == n * rounds
    assert tm.value("store.arena.bytes_ingested") == n * rounds * row_bytes
    # engine: gauges track the final round/version, histograms saw a round
    assert tm.value("controller.model_version") == rounds
    assert tm.value("engine.round_id") == rounds
    assert tm.get("engine.round_s").count == rounds
    assert tm.get("engine.aggregate_s").count == rounds

    # the deprecated shims are views of the same instruments
    stats = ctrl.channel.stats
    assert stats.messages == tm.value("channel.messages")
    assert stats.upload_bytes == tm.value("channel.upload_bytes")
    assert ctrl.dispatch_serializations == tm.value(
        "controller.dispatch_serializations"
    )
    assert ctrl.upload_fallback_packs == 0
    assert ctrl.arena.total_writes == tm.value("store.arena.total_writes")

    # snapshot mirrors value() for every scalar instrument
    snap = tm.snapshot()
    for name in ("channel.messages", "channel.upload_bytes",
                 "controller.dispatch_serializations",
                 "store.arena.total_writes"):
        assert snap[name] == tm.value(name)


def test_per_upload_bytes_are_integral():
    """Mirror of the conformance arithmetic: cumulative upload bytes divide
    evenly into per-upload payloads on the raw codec."""
    n, rounds = 2, 2
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1), jnp.float32)})
    for i in range(n):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=rounds)
    ctrl.shutdown()
    tm = ctrl.telemetry
    per_upload = (tm.value("channel.upload_bytes")
                  / tm.value("channel.upload_messages"))
    assert per_upload == int(per_upload) == 4 * ctrl.arena.padded_params


def test_engine_telemetry_survives_mock_controller():
    """The engine must build a private registry when its controller has no
    telemetry attribute (the mock-controller pattern of engine unit tests)."""
    from repro.core import RoundEngine

    class _Mock:
        pass

    eng = RoundEngine(_Mock())
    assert isinstance(eng.telemetry, Telemetry)
    eng.telemetry.counter("x").add(1)
    eng.shutdown()
