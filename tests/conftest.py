"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see 1 device; multi-device tests spawn subprocesses
(test_multidevice.py)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
