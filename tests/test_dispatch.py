"""Serialize-once broadcast dispatch + flat-buffer upload fast path.

Asserts the acceptance surface of the dispatch re-engineering
(``docs/DISPATCH.md``):

* ``Channel.broadcast`` serializes once, shares one read-only byte buffer
  across every recipient's envelope, and charges per-recipient bytes/wire
  time — bit-identical received params vs the legacy per-send path;
* the controller serializes the global model exactly once per model version
  (train dispatch, eval fan-out and async re-dispatches share it) and never
  flattens a pytree on the arena upload path (counters);
* flat-upload parity with the legacy pack-on-arrival path on sync,
  semi-sync, async and secure protocols, in arena and stack store modes, and
  on the mesh-sharded arena under 8 forced host devices;
* ``ChannelStats`` survives being hammered from 16 threads without losing
  updates — on the downlink *and* the upload half;
* uplink byte/message totals reconcile exactly with round counts on sync,
  semi-sync, async and secure, in arena and stack modes, fast path and
  legacy (controller-stand-in) path alike;
* the empty-cohort check reads the arena's host-side row map
  (``ArenaStore.num_valid``), not the device mask.
"""

import os
import subprocess
import sys
import textwrap
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsyncProtocol, Channel, Controller, Driver, FederationEnv, Learner,
    SemiSyncProtocol, SyncProtocol, TerminationCriteria, packing,
)
from repro.core.store import ArenaStore
from repro.optim import sgd

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _make_learner(i):
    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(64, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)

    def data_fn(bs):
        j = rng.integers(0, 64, size=bs)
        return X[j], y[j]

    return Learner(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        data_fn, lambda: (X, y), sgd(0.05), 64,
    )


def _mixed_tree():
    return {
        "w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6) * 0.25,
        "h": (jnp.arange(10, dtype=jnp.bfloat16) * 0.5),
        "s": jnp.asarray(3.5, jnp.float32),
    }


# ---------------------------------------------------------------------------
# channel-level broadcast
# ---------------------------------------------------------------------------


def test_broadcast_parity_with_per_send():
    tree = _mixed_tree()
    ch = Channel(bandwidth_gbps=1.0, latency_ms=1.0)
    sent = ch.recv(ch.send(tree))

    manifest = packing.build_manifest(tree)
    numeric = packing.pack_numeric(tree)
    bc = ch.broadcast(buffer=numeric, manifest=manifest)
    e1, e2 = bc.to({"task": 1}), bc.to({"task": 2})

    # shared read-only buffer, per-recipient metadata
    assert e1.buffer is e2.buffer and e1.manifest is e2.manifest
    assert e1.metadata == {"task": 1} and e2.metadata == {"task": 2}
    assert not e1.buffer.flags.writeable
    assert bc.recipients == 2

    # bit-identical received params vs per-send
    got = ch.recv(e1)
    for k in tree:
        assert got[k].dtype == sent[k].dtype
        assert np.asarray(got[k]).tobytes() == np.asarray(sent[k]).tobytes()

    # accounting: 2 serializations total (send + broadcast), 3 messages,
    # bytes and wire time counted per recipient
    nbytes = e1.buffer.nbytes
    assert ch.stats.serializations == 2
    assert ch.stats.messages == 3
    assert ch.stats.bytes_moved == 3 * nbytes
    per_msg = 1e-3 + nbytes * 8 / 1e9
    assert abs(ch.stats.virtual_wire_s - 3 * per_msg) < 1e-9


def test_broadcast_falls_back_to_pytree_once_with_codec():
    from repro.kernels.ops import QuantCodec

    tree = {"w": jnp.linspace(-1, 1, 64, dtype=jnp.float32)}
    ch = Channel(quantize_codec=QuantCodec())
    bc = ch.broadcast(
        params=tree,
        buffer=packing.pack_numeric(tree),
        manifest=packing.build_manifest(tree),
    )
    outs = [ch.recv(bc.to()) for _ in range(4)]
    assert ch.stats.serializations == 1 and ch.stats.messages == 4
    for out in outs:
        np.testing.assert_allclose(
            np.asarray(out["w"]), np.asarray(tree["w"]), atol=0.02
        )


def test_pack_bytes_from_numeric_bit_identical_and_pad_oblivious():
    tree = _mixed_tree()
    manifest = packing.build_manifest(tree)
    want, _ = packing.pack_bytes(packing.unpack_numeric(
        packing.pack_numeric(tree), manifest))
    got = packing.pack_bytes_from_numeric(packing.pack_numeric(tree), manifest)
    assert want.tobytes() == got.tobytes()
    padded = packing.pack_numeric(tree, pad_to=256)
    assert packing.pack_bytes_from_numeric(padded, manifest).tobytes() == want.tobytes()


def test_channel_stats_threadsafe_under_16_thread_hammer():
    """send/recv/broadcast.to/upload/recv_upload from 16 threads must not
    lose counter updates in either wire direction."""
    tree = {"w": jnp.ones((50,), jnp.float32)}
    row = packing.pack_numeric(tree)
    ch = Channel()
    bc = ch.broadcast(buffer=row, manifest=packing.build_manifest(tree))
    n_threads, iters = 16, 25
    barrier = threading.Barrier(n_threads)

    def hammer():
        barrier.wait()
        for _ in range(iters):
            env = ch.send(tree)
            ch.recv(env)
            bc.to()
            up = ch.upload(row)
            ch.recv_upload(up)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    total = n_threads * iters
    nbytes = 50 * 4
    assert ch.stats.messages == 2 * total  # one send + one broadcast.to each
    assert ch.stats.bytes_moved == 2 * total * nbytes
    assert ch.stats.serializations == total + 1  # sends + the one broadcast
    assert bc.recipients == total
    # uplink half: every upload is its own message AND serialization
    assert ch.stats.upload_messages == total
    assert ch.stats.upload_serializations == total
    assert ch.stats.upload_bytes == total * nbytes
    assert ch.stats.upload_virtual_wire_s > 0
    assert ch.stats.total_bytes == ch.stats.bytes_moved + ch.stats.upload_bytes


# ---------------------------------------------------------------------------
# controller: serialize-once + flat uploads
# ---------------------------------------------------------------------------


def test_sync_rounds_serialize_once_per_version_and_never_flatten_uploads():
    n_learners, rounds = 4, 3
    ctrl = Controller(protocol=SyncProtocol(local_steps=2, batch_size=16))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(n_learners):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=rounds)
    stats = ctrl.channel.stats
    ctrl.shutdown()

    # one serialization per model version: the initial model (round 0 train
    # dispatch) plus one per aggregation (shared by eval + next train
    # dispatch) — NOT one per learner per fan-out.
    assert stats.serializations == rounds + 1
    assert ctrl.dispatch_serializations == rounds + 1
    # every learner still got its own envelope, twice per round (train+eval)
    assert stats.messages == 2 * n_learners * rounds
    # the arena upload path never flattened a pytree on arrival
    assert ctrl.upload_fallback_packs == 0
    assert ctrl.arena.total_writes == n_learners * rounds


def test_async_shares_serialization_between_community_updates():
    ctrl = Controller(protocol=AsyncProtocol(local_steps=1, batch_size=8))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(3):
        ctrl.register_learner(_make_learner(i))
    hist = ctrl.engine.run(total_updates=9)
    stats = ctrl.channel.stats
    ctrl.shutdown()
    assert len(hist) >= 9
    assert ctrl.upload_fallback_packs == 0
    # at most one serialization per model version (initial + one per
    # community update); strictly fewer messages would mean dispatch stopped
    assert stats.serializations <= ctrl._model_version + 1
    assert stats.messages >= stats.serializations


def test_flat_uploads_disabled_counts_fallback_packs():
    ctrl = Controller(
        protocol=SyncProtocol(local_steps=1, batch_size=8), flat_uploads=False
    )
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(3):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=1)
    ctrl.shutdown()
    assert ctrl.upload_fallback_packs == 3  # controller packed every upload


def _global_after(protocol_fn, *, flat, secure=False, store_mode="arena",
                  rounds=2, n=3, async_updates=0):
    ctrl = Controller(protocol=protocol_fn(), secure=secure,
                      store_mode=store_mode, flat_uploads=flat)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(n):
        ctrl.register_learner(_make_learner(i))
    if async_updates:
        ctrl.engine.run(total_updates=async_updates)
    else:
        ctrl.engine.run(rounds=rounds)
    out = np.asarray(ctrl.global_params["w"])
    fallbacks = ctrl.upload_fallback_packs
    ctrl.shutdown()
    return out, fallbacks


@pytest.mark.parametrize(
    "proto,rounds",
    [
        (lambda: SyncProtocol(local_steps=2, batch_size=16), 2),
        # one round only: from round 2 on, semi-sync task sizing depends on
        # *measured* seconds-per-step, which is not comparable across arms
        (lambda: SemiSyncProtocol(hyperperiod_s=0.05, batch_size=16,
                                  default_steps=2), 1),
    ],
    ids=["sync", "semi_sync"],
)
def test_flat_upload_parity_sync_protocols(proto, rounds):
    fast, fb_fast = _global_after(proto, flat=True, rounds=rounds)
    slow, fb_slow = _global_after(proto, flat=False, rounds=rounds)
    # allclose, not bit-equal: arena row order follows upload *arrival*
    # order, so the float reduction's accumulation order varies per run
    np.testing.assert_allclose(fast, slow, rtol=1e-6, atol=1e-7)
    assert fb_fast == 0 and fb_slow > 0


def test_flat_upload_parity_secure():
    proto = lambda: SyncProtocol(local_steps=2, batch_size=16)  # noqa: E731
    fast, fb = _global_after(proto, flat=True, secure=True)
    slow, _ = _global_after(proto, flat=False, secure=True)
    np.testing.assert_array_equal(fast, slow)
    assert fb == 0


def test_flat_upload_parity_async_single_learner_deterministic():
    proto = lambda: AsyncProtocol(local_steps=2, batch_size=16)  # noqa: E731
    fast, fb = _global_after(proto, flat=True, n=1, async_updates=3)
    slow, _ = _global_after(proto, flat=False, n=1, async_updates=3)
    np.testing.assert_array_equal(fast, slow)
    assert fb == 0


def test_flat_upload_parity_stack_mode():
    proto = lambda: SyncProtocol(local_steps=2, batch_size=16)  # noqa: E731
    fast, fb = _global_after(proto, flat=True, store_mode="stack")
    slow, _ = _global_after(proto, flat=False, store_mode="stack")
    np.testing.assert_array_equal(fast, slow)
    assert fb == 0


def test_late_joining_learner_gets_manifest():
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(2):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=1)
    ctrl.register_learner(_make_learner(2))  # joins mid-federation
    ctrl.engine.run(rounds=1)
    ctrl.shutdown()
    assert ctrl.upload_fallback_packs == 0
    assert ctrl.arena.total_writes == 2 + 3


def test_driver_plumbs_flat_uploads_knob():
    for flat in (True, False):
        env = FederationEnv(
            protocol="sync", local_steps=1, batch_size=16, flat_uploads=flat,
            termination=TerminationCriteria(max_rounds=1),
        )
        drv = Driver(env)
        drv.initialize({"w": jnp.zeros((4, 1))}, [_make_learner(0)])
        drv.run()
        assert (drv.controller.upload_fallback_packs == 0) == flat


# ---------------------------------------------------------------------------
# measured uplink: byte totals reconcile with round counts on every protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("flat", [True, False], ids=["flat", "legacy"])
@pytest.mark.parametrize(
    "proto_fn,secure",
    [
        (lambda: SyncProtocol(local_steps=1, batch_size=8), False),
        (lambda: SemiSyncProtocol(hyperperiod_s=0.05, batch_size=8,
                                  default_steps=1), False),
        (lambda: SyncProtocol(local_steps=1, batch_size=8), True),
    ],
    ids=["sync", "semi_sync", "secure"],
)
def test_uplink_reconciles_with_round_counts(proto_fn, secure, flat):
    """Both wire directions must report nonzero totals that reconcile
    exactly with round counts — on the fast path and on the legacy path
    (where the controller stands in for the learner's send half)."""
    n, rounds = 3, 2
    ctrl = Controller(protocol=proto_fn(), secure=secure, flat_uploads=flat)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(n):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=rounds)
    ctrl.shutdown()
    stats = ctrl.channel.stats

    uploads = n * rounds
    row_bytes = 4 * ctrl.arena.padded_params  # decoded f32 row per upload
    wire_down = ctrl.manifest.total_bytes
    # uplink: one measured message AND serialization per upload
    assert stats.upload_messages == uploads == stats.upload_serializations
    assert stats.upload_bytes == uploads * row_bytes
    assert stats.upload_virtual_wire_s > 0
    # downlink: one train + one eval envelope per learner per round
    assert stats.messages == 2 * n * rounds
    assert stats.bytes_moved == stats.messages * wire_down
    assert stats.virtual_wire_s > 0
    # every decoded upload landed in the arena, byte for byte
    assert ctrl.arena.bytes_ingested == uploads * row_bytes
    assert stats.total_bytes == stats.bytes_moved + stats.upload_bytes
    assert (ctrl.upload_fallback_packs == 0) == flat


def test_uplink_reconciles_async_executor():
    """The async protocol uploads from concurrent executor threads; totals
    must still reconcile exactly with the number of arena writes."""
    ctrl = Controller(protocol=AsyncProtocol(local_steps=1, batch_size=8))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(3):
        ctrl.register_learner(_make_learner(i))
    hist = ctrl.engine.run(total_updates=9)
    ctrl.shutdown()  # barrier: in-flight completions drain before we count
    stats = ctrl.channel.stats

    assert len(hist) >= 9
    writes = ctrl.arena.total_writes
    row_bytes = 4 * ctrl.arena.padded_params
    assert writes >= 9
    assert stats.upload_messages == writes == stats.upload_serializations
    assert stats.upload_bytes == writes * row_bytes
    assert ctrl.arena.bytes_ingested == writes * row_bytes
    assert stats.bytes_moved == stats.messages * ctrl.manifest.total_bytes
    assert stats.upload_virtual_wire_s > 0 and stats.virtual_wire_s > 0


def test_uplink_reconciles_stack_store():
    """Stack mode: uploads are unpadded; the hash-map store's ingest bytes
    must equal the channel's decoded uplink volume."""
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8),
                      store_mode="stack")
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(2):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=1)
    ctrl.shutdown()
    stats = ctrl.channel.stats
    row_bytes = 4 * int(ctrl.global_buffer.shape[0])
    assert stats.upload_messages == 2
    assert stats.upload_bytes == 2 * row_bytes
    assert ctrl.store.bytes_ingested == 2 * row_bytes


# ---------------------------------------------------------------------------
# arena host-side cohort check
# ---------------------------------------------------------------------------


def test_arena_num_valid_is_host_side_and_tracks_invalidation():
    arena = ArenaStore(num_params=8, n_max=2, row_align=8)
    assert arena.num_valid() == 0 and arena.num_valid(["a", "b"]) == 0
    arena.write("a", jnp.ones((8,)), weight=1.0)
    arena.write("b", jnp.ones((8,)), weight=2.0)
    assert arena.num_valid() == 2
    assert arena.num_valid(["a"]) == 1
    assert arena.num_valid(["a", "missing"]) == 1
    arena.invalidate("a")
    assert arena.num_valid(["a", "b"]) == 1


def test_empty_cohort_still_raises():
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    ctrl.register_learner(_make_learner(0))
    with pytest.raises(RuntimeError, match="no local models"):
        ctrl.aggregate_round(["l0"])  # nothing uploaded yet
    ctrl.shutdown()


# ---------------------------------------------------------------------------
# sharded arena (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_flat_upload_parity_sharded_arena():
    """Flat uploads on the mesh-sharded arena match the legacy path exactly,
    with zero controller-side flattening, on sync and async protocols."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    script = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import AsyncProtocol, Controller, Learner, SyncProtocol
        from repro.launch.mesh import make_controller_mesh
        from repro.optim import sgd

        def make_learner(i):
            def loss_fn(p, b):
                return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)
            rng = np.random.default_rng(i)
            X = rng.normal(size=(64, 4)).astype(np.float32)
            y = X @ np.ones((4, 1), np.float32)
            def data_fn(bs):
                j = rng.integers(0, 64, size=bs)
                return X[j], y[j]
            return Learner(
                f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
                data_fn, lambda: (X, y), sgd(0.05), 64,
            )

        assert jax.device_count() == 8
        for proto_fn, async_updates in (
            (lambda: SyncProtocol(local_steps=2, batch_size=16), 0),
            (lambda: AsyncProtocol(local_steps=2, batch_size=16), 3),
        ):
            outs = {}
            for flat in (True, False):
                mesh = make_controller_mesh()
                n = 1 if async_updates else 3
                ctrl = Controller(protocol=proto_fn(), arena_mesh=mesh,
                                  flat_uploads=flat)
                ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
                for i in range(n):
                    ctrl.register_learner(make_learner(i))
                if async_updates:
                    ctrl.engine.run(total_updates=async_updates)
                else:
                    ctrl.engine.run(rounds=2)
                assert (ctrl.upload_fallback_packs == 0) == flat, flat
                outs[flat] = np.asarray(ctrl.global_params["w"])
                ctrl.shutdown()
            # allclose: arena row order follows arrival order (see the
            # single-device parity test)
            np.testing.assert_allclose(outs[True], outs[False],
                                       rtol=1e-6, atol=1e-7)
        print("SHARDED-FLAT-OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "SHARDED-FLAT-OK" in out.stdout
