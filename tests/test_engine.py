"""Event-driven round engine: event grammar, policy hooks, wire-aware sizing.

Covers the engine refactor's acceptance surface:

* the 16-thread ``UploadArrived`` out-of-order hammer — arrival order must
  not change when aggregation fires or what it computes;
* the event-log grammar of a round (Dispatched* → UploadArrived* →
  AggregateFired → Evaluated);
* ``prox_mu`` plumbed through all three protocol policies (FedProx is
  reachable from protocol config);
* EWMA learner profiles (convergence, noise damping, legacy decay=0);
* wire-cost-aware semi-sync sizing (budget covers train + round-trip wire);
* secure + async: staleness-damped masked community updates in per-epoch
  mask sessions.
"""

import random
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AggregateFired,
    AsyncProtocol,
    Channel,
    Controller,
    Dispatched,
    EvalReport,
    Evaluated,
    FederationEnv,
    Learner,
    LearnerProfile,
    LocalUpdate,
    SemiSyncProtocol,
    SyncProtocol,
    UploadArrived,
)
from repro.core import secure as secure_mod
from repro.optim import sgd


def _make_learner(i):
    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(64, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)

    def data_fn(bs):
        j = rng.integers(0, 64, size=bs)
        return X[j], y[j]

    return Learner(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        data_fn, lambda: (X, y), sgd(0.05), 64,
    )


# ---------------------------------------------------------------------------
# event-ordering hammer
# ---------------------------------------------------------------------------


class _GatedLearner(Learner):
    """A learner whose fit() blocks until the test releases its gate, then
    uploads a constant-valued pre-packed row — so 16 executor threads post
    their ``UploadArrived`` events in exactly the (shuffled) release order."""

    def __init__(self, lid, value, gate, pad_to):
        dummy = lambda *a, **k: None  # noqa: E731
        super().__init__(lid, dummy, dummy, dummy, dummy, sgd(0.1), 1)
        self._value = value
        self._gate = gate
        self._pad_to = pad_to

    def fit(self, params, task):
        self._gate.wait(timeout=30)
        return LocalUpdate(
            learner_id=self.learner_id, round_id=task.round_id,
            params=None, num_examples=1, metrics={}, seconds_per_step=1e-4,
            buffer=jnp.full((self._pad_to,), float(self._value), jnp.float32),
        )

    def evaluate(self, params, round_id):
        return EvalReport(self.learner_id, round_id, {"eval_loss": 0.0}, 1)


def test_event_ordering_hammer_16_threads():
    """16 concurrent workers posting UploadArrived in a shuffled order: the
    engine must ingest all of them, fire aggregation exactly once per round,
    and produce the order-independent exact mean."""
    n = 16
    # admission_control off: the screen's norm EWMA warms up in *arrival*
    # order, so with 15x-heterogeneous row norms (0..480) an unlucky
    # interleaving clips the largest row — exactly the order dependence
    # this test asserts the aggregation itself does not have.
    ctrl = Controller(
        protocol=SyncProtocol(local_steps=1, batch_size=1),
        max_dispatch_workers=n, arena_n_max=n, admission_control=False,
    )
    ctrl.set_initial_model({"w": jnp.zeros((8,), jnp.float32)})
    gates = {}
    for i in range(n):
        gates[f"l{i}"] = threading.Event()
        ctrl.register_learner(
            _GatedLearner(f"l{i}", i, gates[f"l{i}"], 1024)
        )

    rng = random.Random(0)
    releaser_done = threading.Event()

    def release_shuffled():
        # Scramble arrival order: all 16 fits are blocked on their gates in
        # executor threads; release them in a random permutation.
        order = list(gates)
        rng.shuffle(order)
        for lid in order:
            gates[lid].set()
        releaser_done.set()

    rounds = 3
    for r in range(rounds):
        for g in gates.values():
            g.clear()
        releaser_done.clear()
        threading.Thread(target=release_shuffled, daemon=True).start()
        (t,) = ctrl.engine.run(rounds=1)
        assert releaser_done.wait(timeout=30)
        # one aggregation per round, every upload ingested, exact mean:
        # values 0..15 with equal weights -> (0+..+15)/16 = 7.5 in any
        # summation order (exact in float32)
        assert ctrl.engine.aggregates_fired == r + 1
        assert ctrl.arena.total_writes == n * (r + 1)
        np.testing.assert_array_equal(
            np.asarray(ctrl.global_params["w"]), np.full((8,), 7.5, np.float32)
        )
        assert t.metrics == {"eval_loss": 0.0}
    ctrl.shutdown()

    # event-log grammar for the last round: 16 UploadArrived all precede the
    # AggregateFired, which precedes the Evaluated
    log = list(ctrl.engine.event_log)
    last_agg = max(i for i, e in enumerate(log) if isinstance(e, AggregateFired))
    arrivals = [i for i, e in enumerate(log) if isinstance(e, UploadArrived)]
    assert sum(1 for i in arrivals if last_agg - 17 < i < last_agg) == n
    assert isinstance(log[last_agg + 1], Evaluated)
    dispatched = [e for e in log if isinstance(e, Dispatched)]
    assert len(dispatched) == n * rounds


def test_engine_run_argument_contract():
    ctrl = Controller(protocol=SyncProtocol())
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    with pytest.raises(TypeError):
        ctrl.engine.run()  # round-based needs rounds=
    with pytest.raises(TypeError):
        ctrl.engine.run(total_updates=3)  # sync is not continuous
    ctrl.shutdown()

    actrl = Controller(protocol=AsyncProtocol())
    actrl.set_initial_model({"w": jnp.zeros((4, 1))})
    with pytest.raises(TypeError):
        actrl.engine.run(rounds=2)  # continuous needs total_updates=
    assert actrl.engine.run(total_updates=0) == []
    actrl.shutdown()


def test_learner_failure_surfaces_on_engine_thread():
    class _FailingLearner(Learner):
        def fit(self, params, task):
            raise RuntimeError("boom in fit")

    dummy = lambda *a, **k: None  # noqa: E731
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=1))
    ctrl.set_initial_model({"w": jnp.zeros((4,), jnp.float32)})
    ctrl.register_learner(_FailingLearner("bad", dummy, dummy, dummy, dummy,
                                          sgd(0.1), 1))
    with pytest.raises(RuntimeError, match="boom in fit"):
        ctrl.engine.run(rounds=1)
    ctrl.shutdown()


def test_engine_reruns_clean_after_learner_failure():
    """A failed round must not poison the next run(): in-flight tasks are
    drained and stale events discarded, so a retry round sees only its own
    cohort's arrivals and aggregates exactly once."""

    class _FlakyLearner(Learner):
        fail_next = True

        def fit(self, params, task):
            if _FlakyLearner.fail_next:
                _FlakyLearner.fail_next = False
                raise RuntimeError("transient learner failure")
            return super().fit(params, task)

    def flaky(i):
        base = _make_learner(i)
        fl = _FlakyLearner.__new__(_FlakyLearner)
        fl.__dict__.update(base.__dict__)
        return fl

    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    ctrl.register_learner(flaky(0))
    for i in range(1, 3):
        ctrl.register_learner(_make_learner(i))
    with pytest.raises(RuntimeError, match="transient learner failure"):
        ctrl.engine.run(rounds=1)
    # retry: the engine must start from a clean queue and outstanding count
    (t,) = ctrl.engine.run(rounds=1)
    ctrl.shutdown()
    assert ctrl.engine.aggregates_fired == 1  # never fired in the bad round
    assert t.federation_round_s > 0 and "eval_loss" in t.metrics
    assert len(ctrl.history) == 1


# ---------------------------------------------------------------------------
# prox_mu: FedProx reachable from protocol config
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "proto",
    [
        SyncProtocol(prox_mu=0.25),
        SemiSyncProtocol(prox_mu=0.25),
        AsyncProtocol(prox_mu=0.25),
    ],
    ids=["sync", "semi_sync", "async"],
)
def test_prox_mu_reaches_train_task(proto):
    """Regression: every policy must stamp its prox_mu on the TrainTask
    (it used to be silently dropped, making FedProx unreachable)."""
    task = proto.size_task(0, {})
    assert task.prox_mu == 0.25
    # the legacy alias goes through the same path
    assert proto.make_task(0, {}).prox_mu == 0.25


def test_prox_mu_plumbed_through_federation_env():
    for name in ("sync", "semi_sync", "async"):
        env = FederationEnv(protocol=name, prox_mu=0.125)
        assert env.make_protocol().size_task(0, {}).prox_mu == 0.125
    assert FederationEnv(protocol="sync").make_protocol().size_task(0, {}).prox_mu == 0.0


def test_prox_mu_federation_runs_and_stays_finite():
    ctrl = Controller(protocol=SyncProtocol(local_steps=2, batch_size=16,
                                            prox_mu=0.1))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(2):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=2)
    ctrl.shutdown()
    assert np.isfinite(np.asarray(ctrl.global_params["w"])).all()
    # the dispatched tasks carried the proximal coefficient
    tasks = [e.task for e in ctrl.engine.event_log if isinstance(e, Dispatched)]
    assert tasks and all(t.prox_mu == 0.1 for t in tasks)


# ---------------------------------------------------------------------------
# EWMA learner profiles
# ---------------------------------------------------------------------------


def test_ewma_profile_converges_under_noise():
    """A noisy-but-stationary step time must converge to its mean and the
    estimate's wobble must be far smaller than the observation noise."""
    rng = np.random.default_rng(0)
    prof = LearnerProfile(decay=0.8)
    true = 0.1
    estimates = []
    for _ in range(300):
        prof.observe_step_time(true + rng.uniform(-0.05, 0.05))
        estimates.append(prof["seconds_per_step"])
    tail = np.asarray(estimates[100:])
    assert abs(tail.mean() - true) < 0.01
    # noise damping: EWMA std well under the uniform(-.05,.05) sample std
    assert tail.std() < 0.015


def test_ewma_profile_converges_to_constant():
    prof = LearnerProfile(decay=0.8)
    prof.observe_step_time(1.0)  # stale initial estimate
    for _ in range(60):
        prof.observe_step_time(0.2)
    assert abs(prof["seconds_per_step"] - 0.2) < 1e-4


def test_decay_zero_is_legacy_last_sample():
    prof = LearnerProfile(decay=0.0)
    prof.observe_step_time(1.0)
    prof.observe_step_time(0.25)
    assert prof["seconds_per_step"] == 0.25


def test_profile_rejects_bad_decay():
    with pytest.raises(ValueError):
        LearnerProfile(decay=1.0)
    with pytest.raises(ValueError):
        LearnerProfile(decay=-0.1)


def test_controller_profiles_use_ewma():
    ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=8),
                      profile_decay=0.5)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    ctrl.register_learner(_make_learner(0))
    ctrl.engine.run(rounds=3)
    ctrl.shutdown()
    prof = ctrl._learner_profiles["l0"]
    assert isinstance(prof, LearnerProfile)
    assert prof.observations == 3
    assert prof["seconds_per_step"] > 0
    assert prof["upload_bytes"] == 4 * ctrl.arena.padded_params


# ---------------------------------------------------------------------------
# wire-cost-aware semi-sync sizing
# ---------------------------------------------------------------------------


def test_semi_sync_wire_aware_subtracts_wire_time():
    proto = SemiSyncProtocol(hyperperiod_s=1.0, default_steps=2)
    prof = {"seconds_per_step": 0.01}
    assert proto.size_task(0, prof, wire_s=0.0).local_steps == 100
    assert proto.size_task(0, prof, wire_s=0.5).local_steps == 50
    # naive arm ignores the wire time
    naive = SemiSyncProtocol(hyperperiod_s=1.0, wire_aware=False)
    assert naive.size_task(0, prof, wire_s=0.5).local_steps == 100
    # wire time >= budget still dispatches the minimum task
    assert proto.size_task(0, prof, wire_s=2.0).local_steps == 1
    # no profile yet -> default steps regardless of wire time
    assert proto.size_task(0, {}, wire_s=0.5).local_steps == 2


def test_semi_sync_budget_covers_train_plus_wire():
    """Property: whenever at least one step fits in the post-wire budget,
    the wire-aware completion estimate stays within the hyper-period."""
    rng = np.random.default_rng(1)
    proto = SemiSyncProtocol(hyperperiod_s=1.0)
    for _ in range(200):
        sps = float(rng.uniform(1e-4, 0.2))
        wire = float(rng.uniform(0.0, 0.9))
        steps = proto.size_task(0, {"seconds_per_step": sps}, wire_s=wire).local_steps
        if proto.hyperperiod_s - wire >= sps:
            assert steps * sps + wire <= proto.hyperperiod_s + 1e-9


def test_controller_wire_time_estimate_matches_channel_model():
    ch = Channel(bandwidth_gbps=0.1, latency_ms=1.0)
    ctrl = Controller(protocol=SemiSyncProtocol(hyperperiod_s=0.05,
                                                batch_size=8),
                      channel=ch)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    ctrl.register_learner(_make_learner(0))
    down = ctrl.manifest.total_bytes
    # before any upload: the codec's modeled payload for the padded row
    up = 4 * ctrl.arena.padded_params
    assert ctrl.wire_time_s("l0") == pytest.approx(ch.round_trip_s(down, up))
    expect = 2 * 1e-3 + (down + up) * 8 / 0.1e9
    assert ctrl.wire_time_s("l0") == pytest.approx(expect)
    # after a round the profile's measured upload bytes take over
    ctrl.engine.run(rounds=1)
    ctrl.shutdown()
    assert ctrl._learner_profiles["l0"]["upload_bytes"] == up
    assert ctrl.wire_time_s("l0") == pytest.approx(ch.round_trip_s(down, up))


def test_wire_aware_sizing_shapes_real_rounds():
    """Under a bandwidth cap, the wire-aware arm must assign fewer steps
    than the naive arm once profiles exist (the --schedule bench claim)."""
    class _FixedSpsLearner(Learner):
        # Reports a fixed seconds-per-step: the *sizing* is under test, and
        # wall-clock on a loaded CI box would make the expectation flaky.
        def fit(self, params, task):
            update = super().fit(params, task)
            update.seconds_per_step = 1e-3
            return update

    def run(wire_aware):
        ctrl = Controller(
            protocol=SemiSyncProtocol(hyperperiod_s=0.1, batch_size=8,
                                      default_steps=1, wire_aware=wire_aware),
            channel=Channel(bandwidth_gbps=0.0005, latency_ms=5.0),
        )
        ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
        base = _make_learner(0)
        fixed = _FixedSpsLearner.__new__(_FixedSpsLearner)
        fixed.__dict__.update(base.__dict__)
        ctrl.register_learner(fixed)
        ctrl.engine.run(rounds=3)
        steps = [e.task.local_steps
                 for e in ctrl.engine.event_log if isinstance(e, Dispatched)]
        wire = ctrl.wire_time_s("l0")
        ctrl.shutdown()
        return steps, wire

    aware_steps, wire = run(True)
    naive_steps, _ = run(False)
    assert wire > 0.05  # the cap makes wire time a large budget fraction
    # round 0 has no profile (both arms dispatch default_steps); later
    # rounds must be sized down by the wire-aware arm, and its modeled
    # completion must fit the hyper-period where the naive arm overshoots
    assert aware_steps[0] == naive_steps[0] == 1
    assert naive_steps[-1] == 100                    # 0.1 / 1e-3
    assert aware_steps[-1] == int((0.1 - wire) / 1e-3)
    assert aware_steps[-1] * 1e-3 + wire <= 0.1
    assert naive_steps[-1] * 1e-3 + wire > 0.1


# ---------------------------------------------------------------------------
# secure + async: per-epoch mask sessions
# ---------------------------------------------------------------------------


def test_custom_policy_weighting_hook_is_consulted():
    """The engine must route the reduce through policy.weighting(): a
    round-based policy declaring "staleness" gets the community aggregate
    (every valid stored model), not the cohort-masked FedAvg."""

    class StaleSync(SyncProtocol):
        def weighting(self):
            return "staleness"

    def run(proto):
        ctrl = Controller(protocol=proto)
        ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
        for i in range(2):
            ctrl.register_learner(_make_learner(i))
        # a heavy out-of-cohort row: included only by the community reduce
        ghost = jnp.full((ctrl.arena.padded_params,), 123.0, jnp.float32)
        ctrl.arena.write("ghost", ghost, weight=1e9, version=0.0)
        ctrl.engine.run(rounds=1)
        out = np.asarray(ctrl.global_params["w"])
        ctrl.shutdown()
        return out

    staleness_out = run(StaleSync(local_steps=1, batch_size=8))
    fedavg_out = run(SyncProtocol(local_steps=1, batch_size=8))
    np.testing.assert_allclose(staleness_out, 123.0, rtol=1e-3)  # ghost dominates
    assert np.abs(fedavg_out).max() < 10  # cohort-only reduce excluded it


def test_mask_session_seeds_are_fresh_per_epoch():
    seeds = {secure_mod.MaskSession(7, e).seed for e in range(200)}
    assert len(seeds) == 200  # every epoch re-keys the pads
    assert secure_mod.MaskSession(7, 3).seed == secure_mod.MaskSession(7, 3).seed
    assert secure_mod.MaskSession(7, 3).seed != secure_mod.MaskSession(8, 3).seed
    masker = secure_mod.MaskSession(7, 3).masker(4)
    assert masker.participants == (0, 1, 2, 3)


def test_secure_community_update_matches_clear_staleness_average():
    """aggregate_community with secure=True must equal the clear
    staleness-weighted average up to fixed-point quantization — exercised
    on a hand-built arena with mixed staleness."""
    alpha = 0.5
    ctrl = Controller(protocol=AsyncProtocol(staleness_alpha=alpha), secure=True)
    ctrl.set_initial_model({"w": jnp.zeros((8,), jnp.float32)})
    rng = np.random.default_rng(0)
    rows = rng.normal(size=(3, 8)).astype(np.float32) * 0.5
    weights = [10.0, 20.0, 30.0]
    versions = [0.0, 1.0, 2.0]
    for i in range(3):
        buf = jnp.pad(jnp.asarray(rows[i]), (0, ctrl.arena.padded_params - 8))
        ctrl.arena.write(f"l{i}", buf, weight=weights[i], version=versions[i])
    ctrl._model_version = 3
    ctrl.aggregate_community()
    got = np.asarray(ctrl.global_params["w"])
    ctrl.shutdown()

    damped = np.asarray(
        [w * (1.0 + 3 - v) ** (-alpha) for w, v in zip(weights, versions)]
    )
    expect = (damped[:, None] * rows).sum(0) / damped.sum()
    np.testing.assert_allclose(got, expect, atol=1e-3)


def test_secure_async_federation_converges_and_hides_models():
    """End-to-end secure async on real learners: the engine runs community
    updates through per-epoch mask sessions and the model stays sane."""
    ctrl = Controller(protocol=AsyncProtocol(local_steps=2, batch_size=16),
                      secure=True)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(3):
        ctrl.register_learner(_make_learner(i))
    hist = ctrl.engine.run(total_updates=6)
    stats = ctrl.channel.stats
    ctrl.shutdown()
    assert len(hist) >= 6
    assert ctrl._model_version >= 6
    assert np.isfinite(np.asarray(ctrl.global_params["w"])).all()
    assert stats.upload_messages == ctrl.arena.total_writes
    assert all(h.aggregation_s > 0 for h in hist)


def test_secure_async_single_learner_matches_plain_quantized():
    """n=1 async: secure and clear paths differ only by the fixed-point
    round-trip (the masks of a single participant cancel to zero)."""
    def run(secure):
        ctrl = Controller(protocol=AsyncProtocol(local_steps=2, batch_size=16),
                          secure=secure)
        ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
        ctrl.register_learner(_make_learner(0))
        ctrl.engine.run(total_updates=3)
        out = np.asarray(ctrl.global_params["w"])
        ctrl.shutdown()
        return out

    np.testing.assert_allclose(run(True), run(False), atol=1e-3)


# ---------------------------------------------------------------------------
# mid-round dropout: orphaned uploads are tolerated, never fatal
# ---------------------------------------------------------------------------


class _DroppingLearner(Learner):
    """A learner whose fit() deregisters it from the controller mid-round,
    so its upload lands *after* it left the federation (the orphan path)."""

    def __init__(self, inner, controller):
        self.__dict__.update(inner.__dict__)
        self._ctrl = controller

    def fit(self, params, task):
        update = super().fit(params, task)
        self._ctrl.deregister_learner(self.learner_id)
        return update


@pytest.mark.parametrize("store_mode", ["arena", "stack"])
def test_mid_round_dropout_upload_is_orphaned_not_fatal(store_mode):
    ctrl = Controller(protocol=SyncProtocol(local_steps=2, batch_size=16),
                      store_mode=store_mode, max_dispatch_workers=1)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    ctrl.register_learner(_make_learner(0))
    ctrl.register_learner(_DroppingLearner(_make_learner(1), ctrl))
    ctrl.register_learner(_make_learner(2))

    hist = ctrl.engine.run(rounds=1)  # must not raise

    assert len(hist) == 1
    assert ctrl.telemetry.value("engine.uploads.orphaned") == 1
    assert ctrl.telemetry.value("engine.faults.dropouts") == 1
    assert "l1" not in ctrl._learners
    assert np.isfinite(np.asarray(ctrl.global_params["w"])).all()
    # the survivors keep federating
    hist2 = ctrl.engine.run(rounds=1)
    assert len(hist2) == 1
    ctrl.shutdown()


def test_every_learner_dropping_mid_round_raises():
    ctrl = Controller(protocol=SyncProtocol(local_steps=2, batch_size=16),
                      max_dispatch_workers=1)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    ctrl.register_learner(_DroppingLearner(_make_learner(0), ctrl))
    with pytest.raises(RuntimeError, match="dropped out"):
        ctrl.engine.run(rounds=1)
    ctrl.shutdown()


# ---------------------------------------------------------------------------
# fault fates: dup must not double-register, lost-during-drain must retry
# ---------------------------------------------------------------------------


class _ScriptedInjector:
    """A FaultInjector stand-in with scripted upload fates: keys are
    ``(learner_id, round_id)`` or bare ``learner_id`` (every round)."""

    def __init__(self, fates):
        self.fates = dict(fates)

    def upload_fate(self, lid, rid):
        return self.fates.get((lid, int(rid))) or self.fates.get(lid, "ok")


def _faulty_controller(protocol, fates, **kwargs):
    from repro.core import FaultyChannel

    ctrl = Controller(
        protocol=protocol, channel=FaultyChannel(_ScriptedInjector(fates)),
        max_dispatch_workers=1, **kwargs,
    )
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(2):
        ctrl.register_learner(_make_learner(i))
    return ctrl


def test_dup_completing_quorum_is_not_counted_late():
    """A duplicated upload whose second copy completes the sync quorum must
    not leave the original frame re-registering it as a late straggler."""
    ctrl = _faulty_controller(
        SyncProtocol(local_steps=1, batch_size=16), {"l1": "dup"}
    )
    # one worker: l1 (dup-fated) is always the quorum-completing arrival
    hist = ctrl.engine.run(rounds=2)
    assert len(hist) == 2
    assert ctrl.telemetry.value("engine.faults.uploads_duplicated") == 2
    assert ctrl.telemetry.value("engine.faults.uploads_late") == 0
    assert ctrl.engine._late_carry == []
    ctrl.shutdown()


def test_dup_completing_buffer_leaves_no_phantom_member():
    """A duplicated upload whose second copy fills the FedBuff buffer fires
    the aggregate inside the recursion; the original frame must not re-append
    the learner to the freshly cleared buffer."""
    from repro.core import BufferedAsyncProtocol

    ctrl = _faulty_controller(
        BufferedAsyncProtocol(buffer_k=2, local_steps=1, batch_size=16),
        {"l1": "dup"},
    )
    ctrl.engine.run(total_updates=2)
    assert ctrl.engine._buffer == []  # no phantom carry-over
    fired = [e for e in ctrl.engine.event_log if isinstance(e, AggregateFired)]
    assert len(fired) == 2
    assert all(e.members == ("l0", "l1") for e in fired)
    assert ctrl.telemetry.value("engine.faults.uploads_duplicated") == 2
    ctrl.shutdown()


def test_lost_during_checkpoint_drain_rejoins_rotation(tmp_path):
    """An upload lost while the pre-checkpoint drain is absorbing arrivals
    (no immediate retry leg) must be re-dispatched after the checkpoint —
    and recorded in the checkpoint's pending dispatches — instead of
    silently leaving the rotation for the rest of the run."""
    from repro.checkpoint import checkpoint as ckpt
    from repro.core import BufferedAsyncProtocol

    ctrl = _faulty_controller(
        BufferedAsyncProtocol(buffer_k=1, local_steps=1, batch_size=16),
        {("l1", 0): "lost"},
    )
    # checkpoint after every community update: l0's first arrival fires,
    # the drain then absorbs l1's lost upload with fire=False
    ctrl.engine.run(
        total_updates=3, checkpoint_every=1, checkpoint_dir=str(tmp_path)
    )
    assert ctrl.telemetry.value("engine.faults.uploads_lost") == 1
    dispatched_l1 = [
        e for e in ctrl.engine.event_log
        if isinstance(e, Dispatched) and e.learner_id == "l1"
    ]
    assert len(dispatched_l1) >= 2  # the owed retry leg actually left
    # the checkpoint written around the drain owes l1's retry on restore
    _, _, meta = ckpt.restore_checkpoint(str(tmp_path), step=1)
    assert meta["pending_dispatch"] == ["l0", "l1"]
    ctrl.shutdown()

    ctrl2 = _faulty_controller(
        BufferedAsyncProtocol(buffer_k=1, local_steps=1, batch_size=16), {}
    )
    ctrl2.restore(str(tmp_path), step=1)
    assert ctrl2.engine._resume_dispatch == ["l0", "l1"]
    ctrl2.shutdown()


def test_rejoin_preserves_profile_and_decays_reputation():
    ctrl = Controller(protocol=SyncProtocol(local_steps=2, batch_size=16))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(2):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=1)
    prof = ctrl._learner_profiles["l0"]
    rep_before = prof.reputation()
    obs_before = prof.observations
    assert rep_before > 0

    ctrl.deregister_learner("l0")
    assert "l0" in ctrl._deregistered_at
    ctrl.engine.run(rounds=2)  # two rounds absent
    ctrl.register_learner(_make_learner(0))

    prof2 = ctrl._learner_profiles["l0"]
    assert prof2 is prof  # profile survives churn
    assert prof2.observations == obs_before
    assert prof2.reputation() == pytest.approx(rep_before * 0.9**2)
    assert ctrl.telemetry.value("engine.faults.rejoins") == 1
    assert "l0" not in ctrl._deregistered_at
    ctrl.engine.run(rounds=1)  # the rejoined learner participates again
    ctrl.shutdown()
