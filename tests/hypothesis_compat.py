"""`hypothesis` with a thin fallback so tier-1 collects on a bare interpreter.

With the `dev` extra installed (``pip install -e .[dev]``) this module simply
re-exports the real `hypothesis` — full property-based testing with shrinking.
Without it, a deterministic mini-engine stands in: each ``@given`` test runs
against ``max_examples`` seeded pseudo-random draws covering exactly the
strategy surface this suite uses (integers, floats, lists, sampled_from,
composite, ``.map``).  No shrinking, no database, no assume() — just enough to
keep the properties exercised instead of skipped.

Usage in test modules::

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _FALLBACK_CAP = 25  # keep bare-interpreter runs fast

    class _Strategy:
        """A draw function wrapper mirroring the hypothesis strategy API."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _StrategiesModule:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float, **_kwargs) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options) -> _Strategy:
            options = list(options)
            return _Strategy(lambda rng: options[rng.randrange(len(options))])

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
            def draw(rng):
                k = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(k)]

            return _Strategy(draw)

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                return _Strategy(
                    lambda rng: fn(lambda strat: strat.draw(rng), *args, **kwargs)
                )

            return build

    st = _StrategiesModule()

    def settings(max_examples: int = 10, **_ignored):
        """Record max_examples on the (already-@given-wrapped) test."""

        def deco(fn):
            fn._compat_max_examples = min(max_examples, _FALLBACK_CAP)
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        """Run the test once per deterministic seeded draw."""

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", 10)
                for i in range(n):
                    rng = random.Random(0xC0FFEE + 7919 * i)
                    drawn = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # Hide the strategy-filled parameters from pytest's fixture
            # resolution (real hypothesis does the same via its plugin).
            params = list(inspect.signature(fn).parameters.values())
            params = params[len(arg_strategies):]
            params = [p for p in params if p.name not in kw_strategies]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper

        return deco
