"""Protocol × store × upload-codec conformance matrix.

Every future transport or engine change runs this whole grid: {sync,
semi-sync, async, secure, secure async, buffered async (FedBuff), deadline
cohorts, reputation} × {arena, stack, sharded arena under
8 forced host devices} × {raw, int8 upload codec}, each arm driven through
the event-driven round engine (``engine.run`` — the only loop there is) and
compared against a learner-side *replay reference* that re-runs the exact
fit sequence outside the controller and aggregates it two ways:

* **exact** — the controller's own fused pipeline (``weighted_average`` /
  ``secure_fedavg`` + the fedavg server optimizer) over the replayed uploads
  in selection order.  Raw-codec arms whose aggregation order is
  deterministic (stack mode, async single-learner, secure) must match it
  **bit-identically**; arena arms (row order follows upload *arrival* order)
  match to float-accumulation tolerance.
* **naive** — ``core/naive.naive_aggregate``, the per-tensor f64 Python-loop
  baseline.  Every raw arm must agree to ~1e-5 relative; int8 arms to the
  quantization-bounded tolerance.

Uplink accounting is asserted alongside: every arm must report a nonzero,
reconciling upload byte/message count (the full-duplex wire contract).

Marked ``conformance`` (``pytest -m conformance`` runs just this grid — the
CI fast lane does).
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsyncProtocol, BufferedAsyncProtocol, Controller, DeadlineCohortProtocol,
    Learner, ReputationProtocol, SemiSyncProtocol, SyncProtocol,
    aggregation, naive, packing,
)
from repro.core import secure as secure_mod
from repro.core.server_opt import make_server_optimizer
from repro.optim import sgd

pytestmark = pytest.mark.conformance

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_INIT = {"w": np.zeros((4, 1), np.float32)}

# int8 upload quantization error bound: weights stay O(0.5) in these runs and
# the per-group error compounds over at most 3 aggregation hops.
_INT8_RTOL, _INT8_ATOL = 0.02, 0.02


def _make_learner(i):
    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(64, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)

    def data_fn(bs):
        j = rng.integers(0, 64, size=bs)
        return X[j], y[j]

    return Learner(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        data_fn, lambda: (X, y), sgd(0.05), 64,
    )


_CASES = {
    "sync": dict(
        proto=lambda: SyncProtocol(local_steps=2, batch_size=16),
        n=3, rounds=2, updates=0, secure=False,
    ),
    # one round only: from round 2 on, semi-sync task sizing depends on
    # *measured* seconds-per-step, which is not replayable
    "semi_sync": dict(
        proto=lambda: SemiSyncProtocol(hyperperiod_s=0.05, batch_size=16,
                                       default_steps=2),
        n=3, rounds=1, updates=0, secure=False,
    ),
    # single learner: the async community-update sequence is deterministic
    "async": dict(
        proto=lambda: AsyncProtocol(local_steps=2, batch_size=16),
        n=1, rounds=0, updates=3, secure=False,
    ),
    "secure": dict(
        proto=lambda: SyncProtocol(local_steps=2, batch_size=16),
        n=3, rounds=2, updates=0, secure=True,
    ),
    # secure + async: every community update is a per-epoch mask session
    # keyed by the model version (single learner keeps it deterministic)
    "secure_async": dict(
        proto=lambda: AsyncProtocol(local_steps=2, batch_size=16),
        n=1, rounds=0, updates=3, secure=True,
    ),
    # FedBuff with K == n and one community update: every buffered row has
    # staleness 0, so the staleness-damped buffered reduce degenerates to
    # example-weighted FedAvg over the whole fleet — an exact reference.
    "buffered_async": dict(
        proto=lambda: BufferedAsyncProtocol(buffer_k=3, local_steps=2,
                                            batch_size=16),
        n=3, rounds=0, updates=1, secure=False,
    ),
    # deadline far beyond any predicted finish (and wall-clock timers off):
    # every learner is predicted on-time, the policy degenerates to sync
    "deadline": dict(
        proto=lambda: DeadlineCohortProtocol(deadline_s=1e6, local_steps=2,
                                             batch_size=16,
                                             enforce_wall_clock=False),
        n=3, rounds=2, updates=0, secure=False,
    ),
    # fraction=1.0 keeps the whole fleet and the ranking sort is stable, so
    # equal default reputations select exactly sync's cohort in sync's order
    "reputation": dict(
        proto=lambda: ReputationProtocol(fraction=1.0, local_steps=2,
                                         batch_size=16),
        n=3, rounds=2, updates=0, secure=False,
    ),
}


def _reference(case, agg_mode):
    """Replay the federation's exact fit sequence learner-side.

    ``agg_mode="exact"`` aggregates with the controller's fused pipeline in
    selection order; ``"naive"`` with the f64 per-tensor Python baseline.
    Both share the real fedavg server optimizer, so the only difference from
    the federation is transport + aggregation order.
    """
    proto = case["proto"]()
    learners = [_make_learner(i) for i in range(case["n"])]
    manifest = packing.build_manifest(_INIT)
    gbuf = packing.pack_numeric(_INIT)
    params = packing.unpack_numeric(gbuf, manifest)
    server = make_server_optimizer("fedavg")
    state = server.init(gbuf)
    for r in range(case["rounds"] or case["updates"]):
        task = proto.make_task(r, {})
        ups = [l.fit(params, task) for l in learners]
        weights = [float(u.num_examples) for u in ups]
        bufs = [packing.pack_numeric(u.params) for u in ups]
        if agg_mode == "naive":
            new = packing.pack_numeric(
                naive.naive_aggregate([u.params for u in ups], weights)
            )
        elif case["secure"]:
            # Per-epoch mask session: round id (sync) / model version
            # (async) — both advance once per loop iteration here.
            new = secure_mod.secure_fedavg(
                bufs, weights, base_seed=secure_mod.MaskSession(0, r).seed
            )
        elif case["updates"] and case["n"] == 1:
            # async, single learner: the row IS the update
            new = bufs[0]
        else:
            # sync-shaped cohorts AND the K == n buffered reduce (all
            # staleness weights are (1+0)^-alpha): example-weighted FedAvg
            new = aggregation.weighted_average(
                jnp.stack(bufs), jnp.asarray(weights, jnp.float32)
            )
        state, gbuf = server.apply(state, gbuf, new)
        params = packing.unpack_numeric(gbuf, manifest)
    return np.asarray(params["w"])


def _federation(case, store_mode, codec, arena_dtype="f32"):
    ctrl = Controller(
        protocol=case["proto"](), secure=case["secure"],
        store_mode=store_mode, upload_codec=codec,
        arena_dtype=arena_dtype,
    )
    ctrl.set_initial_model(_INIT)
    for i in range(case["n"]):
        ctrl.register_learner(_make_learner(i))
    if case["updates"]:
        ctrl.engine.run(total_updates=case["updates"])
    else:
        ctrl.engine.run(rounds=case["rounds"])
    out = np.asarray(ctrl.global_params["w"])
    stats = ctrl.channel.stats
    # every learner uploads once per round AND once per community update
    # (the buffered arm dispatches the whole K == n cohort per update)
    expected_uploads = case["n"] * (case["rounds"] + case["updates"])
    ctrl.shutdown()
    return out, stats, expected_uploads


@pytest.mark.parametrize("codec", ["raw", "int8"])
@pytest.mark.parametrize("store_mode", ["arena", "stack"])
@pytest.mark.parametrize("proto", list(_CASES))
def test_conformance_matrix(proto, store_mode, codec):
    """Global model parity vs the replay references, per grid cell."""
    case = _CASES[proto]
    got, stats, expected_uploads = _federation(case, store_mode, codec)
    ref_exact = _reference(case, "exact")
    ref_naive = _reference(case, "naive")

    if codec == "raw":
        # arena row order follows upload arrival order (thread races), so
        # only the order-deterministic combos can demand bit-identity.
        deterministic = (
            case["secure"] or case["updates"] or store_mode == "stack"
        )
        if deterministic:
            np.testing.assert_array_equal(got, ref_exact)
        else:
            np.testing.assert_allclose(got, ref_exact, rtol=1e-6, atol=1e-7)
        if case["secure"]:
            # the naive reference aggregates in the clear: the secure arm
            # differs by its int32 fixed-point step (~N/(2·scale)/round)
            np.testing.assert_allclose(got, ref_naive, rtol=1e-3, atol=5e-4)
        else:
            np.testing.assert_allclose(got, ref_naive, rtol=2e-5, atol=1e-5)
    else:
        np.testing.assert_allclose(got, ref_exact, rtol=_INT8_RTOL, atol=_INT8_ATOL)
        np.testing.assert_allclose(got, ref_naive, rtol=_INT8_RTOL, atol=_INT8_ATOL)

    # full-duplex wire contract: both directions nonzero and reconciling
    assert stats.upload_messages == expected_uploads
    assert stats.upload_serializations == expected_uploads
    assert stats.upload_bytes > 0 and stats.bytes_moved > 0
    assert stats.upload_virtual_wire_s > 0 and stats.virtual_wire_s > 0
    per_upload = stats.upload_bytes / expected_uploads
    assert per_upload == int(per_upload)  # identical payload size per upload


def test_int8_uplink_actually_compresses():
    """The int8 arm must put ~4x fewer bytes on the uplink wire than raw —
    even at this tiny P=1024 arena row, thanks to the adaptive kernel tile
    (`effective_block_rows`)."""
    case = _CASES["sync"]
    _, raw_stats, n = _federation(case, "arena", "raw")
    _, int8_stats, _ = _federation(case, "arena", "int8")
    assert raw_stats.upload_messages == int8_stats.upload_messages == n
    from repro.kernels.quantize import wire_layout

    _, _, payload = wire_layout(1024)
    assert int8_stats.upload_bytes == n * payload
    assert raw_stats.upload_bytes == n * 4 * 1024
    assert raw_stats.upload_bytes / int8_stats.upload_bytes > 3.5


# ---------------------------------------------------------------------------
# quantized-resident arena (arena_dtype="int8")
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["raw", "int8"])
@pytest.mark.parametrize("proto", ["sync", "semi_sync", "async",
                                   "buffered_async"])
def test_int8_arena_conformance(proto, codec):
    """int8-resident arena × fedavg protocols × codecs vs the f64
    dequant-then-reduce replay references: the fused single-pass aggregate
    must land inside the quantization-error bound of both the exact and the
    naive reference — the resident quantization adds at most one extra
    per-group rounding on top of the int8 wire's."""
    case = _CASES[proto]
    got, stats, expected_uploads = _federation(case, "arena", codec,
                                               arena_dtype="int8")
    ref_exact = _reference(case, "exact")
    ref_naive = _reference(case, "naive")
    np.testing.assert_allclose(got, ref_exact, rtol=_INT8_RTOL, atol=_INT8_ATOL)
    np.testing.assert_allclose(got, ref_naive, rtol=_INT8_RTOL, atol=_INT8_ATOL)
    assert stats.upload_messages == expected_uploads
    assert stats.upload_bytes > 0 and stats.bytes_moved > 0


def test_int8_arena_direct_landing_bitexact_vs_dequant_store():
    """The tentpole's no-materialization proof: the SAME int8 wire
    envelopes, ingested in the SAME order, aggregate bit-identically
    whether they land directly in the quantized arena (fused reduce) or are
    dequantized to f32 rows first (f32 arena + masked reduce).  Any hidden
    f32 round-trip or requantization on the direct path would break
    bit-equality."""
    ctrls = {
        dt: Controller(
            protocol=SyncProtocol(local_steps=2, batch_size=16),
            store_mode="arena", upload_codec="int8", arena_dtype=dt,
        )
        for dt in ("int8", "f32")
    }
    from repro.core.learner import LocalUpdate

    for ctrl in ctrls.values():
        ctrl.set_initial_model(_INIT)
        for i in range(3):
            ctrl.register_learner(_make_learner(i))
    P = ctrls["int8"].arena.padded_params
    rng = np.random.default_rng(0)
    rows = [jnp.asarray(rng.normal(size=P), jnp.float32) for _ in range(3)]
    for dt, ctrl in ctrls.items():
        for i, row in enumerate(rows):
            env = ctrl.channel.upload(
                row, metadata={"learner_id": f"l{i}", "round_id": 0})
            ctrl.ingest(LocalUpdate(
                learner_id=f"l{i}", round_id=0, params=None, buffer=None,
                num_examples=10 * (i + 1), metrics={},
                seconds_per_step=0.01, upload=env,
            ))
        ctrl.aggregate_round([f"l{i}" for i in range(3)])
    got8 = np.asarray(ctrls["int8"].global_buffer)
    got32 = np.asarray(ctrls["f32"].global_buffer)
    for ctrl in ctrls.values():
        ctrl.shutdown()
    np.testing.assert_array_equal(got8, got32)
    assert ctrls["int8"].telemetry.value(
        "engine.uploads.quantized_direct", 0) == 3
    assert ctrls["int8"].telemetry.value(
        "controller.aggregations.fused_q8", 0) == 1


@pytest.mark.multidevice
def test_int8_arena_conformance_sharded():
    """The int8-resident grid on the mesh-sharded arena (8 forced host
    devices): sync and async × raw/int8 codec, the column-sharded fused
    reduce vs the f64 replay reference — and vs a single-device int8
    federation of the same workload."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    script = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (AsyncProtocol, Controller, Learner,
                                SyncProtocol, aggregation, packing)
        from repro.core.server_opt import make_server_optimizer
        from repro.launch.mesh import make_controller_mesh
        from repro.optim import sgd

        INIT = {"w": np.zeros((4, 1), np.float32)}

        def make_learner(i):
            def loss_fn(p, b):
                return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)
            rng = np.random.default_rng(i)
            X = rng.normal(size=(64, 4)).astype(np.float32)
            y = X @ np.ones((4, 1), np.float32)
            def data_fn(bs):
                j = rng.integers(0, 64, size=bs)
                return X[j], y[j]
            return Learner(
                f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
                data_fn, lambda: (X, y), sgd(0.05), 64,
            )

        CASES = {
            "sync": (lambda: SyncProtocol(local_steps=2, batch_size=16),
                     3, 2, 0),
            "async": (lambda: AsyncProtocol(local_steps=2, batch_size=16),
                      1, 0, 3),
        }

        def reference(name):
            proto_fn, n, rounds, updates = CASES[name]
            proto = proto_fn()
            learners = [make_learner(i) for i in range(n)]
            manifest = packing.build_manifest(INIT)
            gbuf = packing.pack_numeric(INIT)
            params = packing.unpack_numeric(gbuf, manifest)
            server = make_server_optimizer("fedavg")
            state = server.init(gbuf)
            for r in range(rounds or updates):
                task = proto.make_task(r, {})
                ups = [l.fit(params, task) for l in learners]
                ws = [float(u.num_examples) for u in ups]
                bufs = [packing.pack_numeric(u.params) for u in ups]
                if updates and n == 1:
                    new = bufs[0]
                else:
                    new = aggregation.weighted_average(
                        jnp.stack(bufs), jnp.asarray(ws, jnp.float32))
                state, gbuf = server.apply(state, gbuf, new)
                params = packing.unpack_numeric(gbuf, manifest)
            return np.asarray(params["w"])

        def federation(name, codec, mesh):
            proto_fn, n, rounds, updates = CASES[name]
            ctrl = Controller(protocol=proto_fn(), arena_mesh=mesh,
                              store_mode="arena", upload_codec=codec,
                              arena_dtype="int8")
            ctrl.set_initial_model(INIT)
            for i in range(n):
                ctrl.register_learner(make_learner(i))
            if updates:
                ctrl.engine.run(total_updates=updates)
            else:
                ctrl.engine.run(rounds=rounds)
            got = np.asarray(ctrl.global_params["w"])
            fused = ctrl.telemetry.value(
                "controller.aggregations.fused_q8", 0)
            ctrl.shutdown()
            return got, fused

        assert jax.device_count() == 8
        for name in CASES:
            ref = reference(name)
            for codec in ("raw", "int8"):
                got_sh, fused = federation(name, codec,
                                           make_controller_mesh())
                got_1d, _ = federation(name, codec, None)
                assert fused > 0, (name, codec)
                np.testing.assert_allclose(got_sh, ref, rtol=0.02, atol=0.02,
                                           err_msg=f"{name}/{codec}/ref")
                np.testing.assert_allclose(got_sh, got_1d, rtol=1e-5,
                                           atol=1e-6,
                                           err_msg=f"{name}/{codec}/1d")
        print("SHARDED-INT8-ARENA-OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "SHARDED-INT8-ARENA-OK" in out.stdout


# ---------------------------------------------------------------------------
# robust aggregation rules (median / trimmed_mean)
# ---------------------------------------------------------------------------

_ROBUST_N, _ROBUST_ROUNDS, _TRIM_K = 4, 2, 1


def _robust_reference(rule):
    """f64 numpy order-statistics replay reference for the robust rules.

    The robust rules are weight-blind, so the reference is plain
    ``np.median`` / sort-then-trimmed-mean over the replayed upload stack,
    pushed through the same fedavg server optimizer as the federation.
    """
    proto = SyncProtocol(local_steps=2, batch_size=16)
    learners = [_make_learner(i) for i in range(_ROBUST_N)]
    manifest = packing.build_manifest(_INIT)
    gbuf = packing.pack_numeric(_INIT)
    params = packing.unpack_numeric(gbuf, manifest)
    server = make_server_optimizer("fedavg")
    state = server.init(gbuf)
    for r in range(_ROBUST_ROUNDS):
        task = proto.make_task(r, {})
        ups = [l.fit(params, task) for l in learners]
        stack = np.stack([
            np.asarray(packing.pack_numeric(u.params), np.float64)
            for u in ups
        ])
        if rule == "median":
            new = np.median(stack, axis=0)
        else:
            s = np.sort(stack, axis=0)
            new = s[_TRIM_K:_ROBUST_N - _TRIM_K].mean(axis=0)
        state, gbuf = server.apply(state, gbuf, jnp.asarray(new, jnp.float32))
        params = packing.unpack_numeric(gbuf, manifest)
    return np.asarray(params["w"])


@pytest.mark.parametrize("codec", ["raw", "int8"])
@pytest.mark.parametrize("store_mode", ["arena", "stack"])
@pytest.mark.parametrize("rule", ["median", "trimmed_mean"])
def test_robust_rules_conformance(rule, store_mode, codec):
    """median / trimmed_mean × arena / stack × raw / int8 vs the f64 numpy
    replay reference.  Order statistics are row-permutation invariant, so
    even the arena arms (row order follows upload arrival order) get the
    tight tolerance the fedavg grid reserves for deterministic combos."""
    ctrl = Controller(
        protocol=SyncProtocol(local_steps=2, batch_size=16),
        store_mode=store_mode, upload_codec=codec,
        aggregation_rule=rule, trim_k=_TRIM_K,
    )
    ctrl.set_initial_model(_INIT)
    for i in range(_ROBUST_N):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=_ROBUST_ROUNDS)
    got = np.asarray(ctrl.global_params["w"])
    stats = ctrl.channel.stats
    rejected = ctrl.telemetry.value("engine.uploads.rejected.nonfinite")
    clipped = ctrl.telemetry.value("engine.uploads.clipped")
    ctrl.shutdown()

    ref = _robust_reference(rule)
    if codec == "raw":
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    else:
        np.testing.assert_allclose(got, ref, rtol=_INT8_RTOL, atol=_INT8_ATOL)
    # honest cohorts sail through the default-on admission screen untouched
    assert rejected == 0 and clipped == 0
    assert stats.upload_messages == _ROBUST_N * _ROBUST_ROUNDS


@pytest.mark.multidevice
def test_robust_rules_sharded_arena():
    """The robust rules on the mesh-sharded arena (8 forced host devices):
    median / trimmed_mean × raw / int8 must match the f64 replay reference
    — the column-sharded reduce may not change the order statistics."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    script = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Controller, Learner, SyncProtocol, packing
        from repro.core.server_opt import make_server_optimizer
        from repro.launch.mesh import make_controller_mesh
        from repro.optim import sgd

        INIT = {"w": np.zeros((4, 1), np.float32)}
        N, ROUNDS, TRIM_K = 4, 2, 1

        def make_learner(i):
            def loss_fn(p, b):
                return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)
            rng = np.random.default_rng(i)
            X = rng.normal(size=(64, 4)).astype(np.float32)
            y = X @ np.ones((4, 1), np.float32)
            def data_fn(bs):
                j = rng.integers(0, 64, size=bs)
                return X[j], y[j]
            return Learner(
                f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
                data_fn, lambda: (X, y), sgd(0.05), 64,
            )

        def reference(rule):
            proto = SyncProtocol(local_steps=2, batch_size=16)
            learners = [make_learner(i) for i in range(N)]
            manifest = packing.build_manifest(INIT)
            gbuf = packing.pack_numeric(INIT)
            params = packing.unpack_numeric(gbuf, manifest)
            server = make_server_optimizer("fedavg")
            state = server.init(gbuf)
            for r in range(ROUNDS):
                task = proto.make_task(r, {})
                ups = [l.fit(params, task) for l in learners]
                stack = np.stack([
                    np.asarray(packing.pack_numeric(u.params), np.float64)
                    for u in ups
                ])
                if rule == "median":
                    new = np.median(stack, axis=0)
                else:
                    s = np.sort(stack, axis=0)
                    new = s[TRIM_K:N - TRIM_K].mean(axis=0)
                state, gbuf = server.apply(
                    state, gbuf, jnp.asarray(new, jnp.float32))
                params = packing.unpack_numeric(gbuf, manifest)
            return np.asarray(params["w"])

        assert jax.device_count() == 8
        for rule in ("median", "trimmed_mean"):
            ref = reference(rule)
            for codec in ("raw", "int8"):
                ctrl = Controller(
                    protocol=SyncProtocol(local_steps=2, batch_size=16),
                    arena_mesh=make_controller_mesh(), upload_codec=codec,
                    aggregation_rule=rule, trim_k=TRIM_K,
                )
                ctrl.set_initial_model(INIT)
                for i in range(N):
                    ctrl.register_learner(make_learner(i))
                ctrl.engine.run(rounds=ROUNDS)
                got = np.asarray(ctrl.global_params["w"])
                ctrl.shutdown()
                if codec == "raw":
                    np.testing.assert_allclose(
                        got, ref, rtol=1e-5, atol=1e-6,
                        err_msg=f"{rule}/raw")
                else:
                    np.testing.assert_allclose(
                        got, ref, rtol=0.02, atol=0.02,
                        err_msg=f"{rule}/int8")
        print("SHARDED-ROBUST-OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "SHARDED-ROBUST-OK" in out.stdout


@pytest.mark.multidevice
def test_conformance_matrix_sharded_arena():
    """The same grid on the mesh-sharded arena (8 forced host devices):
    every protocol × codec must match the replay reference."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    script = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (AsyncProtocol, BufferedAsyncProtocol,
                                Controller, DeadlineCohortProtocol, Learner,
                                ReputationProtocol, SemiSyncProtocol,
                                SyncProtocol, aggregation, packing)
        from repro.core import secure as secure_mod
        from repro.core.server_opt import make_server_optimizer
        from repro.launch.mesh import make_controller_mesh
        from repro.optim import sgd

        INIT = {"w": np.zeros((4, 1), np.float32)}

        def make_learner(i):
            def loss_fn(p, b):
                return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)
            rng = np.random.default_rng(i)
            X = rng.normal(size=(64, 4)).astype(np.float32)
            y = X @ np.ones((4, 1), np.float32)
            def data_fn(bs):
                j = rng.integers(0, 64, size=bs)
                return X[j], y[j]
            return Learner(
                f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
                data_fn, lambda: (X, y), sgd(0.05), 64,
            )

        CASES = {
            "sync": (lambda: SyncProtocol(local_steps=2, batch_size=16),
                     3, 2, 0, False),
            "semi_sync": (lambda: SemiSyncProtocol(
                              hyperperiod_s=0.05, batch_size=16,
                              default_steps=2), 3, 1, 0, False),
            "async": (lambda: AsyncProtocol(local_steps=2, batch_size=16),
                      1, 0, 3, False),
            "secure": (lambda: SyncProtocol(local_steps=2, batch_size=16),
                       3, 2, 0, True),
            "secure_async": (lambda: AsyncProtocol(local_steps=2,
                                                   batch_size=16),
                             1, 0, 3, True),
            "buffered_async": (lambda: BufferedAsyncProtocol(
                                   buffer_k=3, local_steps=2,
                                   batch_size=16), 3, 0, 1, False),
            "deadline": (lambda: DeadlineCohortProtocol(
                             deadline_s=1e6, local_steps=2, batch_size=16,
                             enforce_wall_clock=False), 3, 2, 0, False),
            "reputation": (lambda: ReputationProtocol(
                               fraction=1.0, local_steps=2,
                               batch_size=16), 3, 2, 0, False),
        }

        def reference(name):
            proto_fn, n, rounds, updates, secure = CASES[name]
            proto = proto_fn()
            learners = [make_learner(i) for i in range(n)]
            manifest = packing.build_manifest(INIT)
            gbuf = packing.pack_numeric(INIT)
            params = packing.unpack_numeric(gbuf, manifest)
            server = make_server_optimizer("fedavg")
            state = server.init(gbuf)
            for r in range(rounds or updates):
                task = proto.make_task(r, {})
                ups = [l.fit(params, task) for l in learners]
                ws = [float(u.num_examples) for u in ups]
                bufs = [packing.pack_numeric(u.params) for u in ups]
                if secure:
                    new = secure_mod.secure_fedavg(
                        bufs, ws, base_seed=secure_mod.MaskSession(0, r).seed)
                elif updates and n == 1:
                    new = bufs[0]
                else:  # sync cohorts and the K == n zero-staleness buffer
                    new = aggregation.weighted_average(
                        jnp.stack(bufs), jnp.asarray(ws, jnp.float32))
                state, gbuf = server.apply(state, gbuf, new)
                params = packing.unpack_numeric(gbuf, manifest)
            return np.asarray(params["w"])

        assert jax.device_count() == 8
        for name in CASES:
            proto_fn, n, rounds, updates, secure = CASES[name]
            ref = reference(name)
            for codec in ("raw", "int8"):
                ctrl = Controller(protocol=proto_fn(), secure=secure,
                                  arena_mesh=make_controller_mesh(),
                                  upload_codec=codec)
                ctrl.set_initial_model(INIT)
                for i in range(n):
                    ctrl.register_learner(make_learner(i))
                if updates:
                    ctrl.engine.run(total_updates=updates)
                else:
                    ctrl.engine.run(rounds=rounds)
                got = np.asarray(ctrl.global_params["w"])
                stats = ctrl.channel.stats
                expected = n * (rounds + updates)
                assert stats.upload_messages == expected, (name, codec)
                assert stats.upload_bytes > 0 and stats.bytes_moved > 0
                ctrl.shutdown()
                if codec == "raw":
                    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-7,
                                               err_msg=f"{name}/raw")
                else:
                    np.testing.assert_allclose(got, ref, rtol=0.02, atol=0.02,
                                               err_msg=f"{name}/int8")
        print("SHARDED-CONFORMANCE-OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "SHARDED-CONFORMANCE-OK" in out.stdout


# ---------------------------------------------------------------------------
# sparse (top-k) uplink with error feedback
# ---------------------------------------------------------------------------

# The replay reference re-runs the learner-side error feedback with the SAME
# codec (the f32 top-k selection kernel and, for int8 values, the same
# grouped quantization) — an f64 re-selection could flip near-magnitude
# ties — then densifies and reduces the sent deltas in f64 and folds them
# onto the running global buffer, exactly the controller's delta-commit
# contract.


def _topk_reference(case, k, pad, value_dtype="f32"):
    from repro.core.transport import TopkUploadCodec

    codec = TopkUploadCodec(k=k, value_dtype=value_dtype)
    proto = case["proto"]()
    learners = [_make_learner(i) for i in range(case["n"])]
    manifest = packing.build_manifest(_INIT)
    gbuf = packing.pack_numeric(_INIT)
    num_params = int(gbuf.shape[0])
    params = packing.unpack_numeric(gbuf, manifest)
    server = make_server_optimizer("fedavg")
    state = server.init(gbuf)
    width = pad if pad is not None else num_params
    residuals = [np.zeros(width, np.float64) for _ in learners]
    for r in range(case["rounds"] or case["updates"]):
        task = proto.make_task(r, {})
        base = np.asarray(
            packing.pack_numeric(params, pad_to=pad), np.float64
        )
        ups = [l.fit(params, task) for l in learners]
        ws = [float(u.num_examples) for u in ups]
        sent = []
        for i, u in enumerate(ups):
            trained = np.asarray(
                packing.pack_numeric(u.params, pad_to=pad), np.float64
            )
            acc = residuals[i] + (trained - base)
            payload = codec.encode(jnp.asarray(acc, jnp.float32))
            idx, val = codec.unpack_coords(payload, width)
            idx, val = np.asarray(idx), np.asarray(val, np.float64)
            dense = np.zeros(width, np.float64)
            np.add.at(dense, idx, val)
            residuals[i] = acc - dense
            sent.append(dense)
        w = np.asarray(ws, np.float64)
        delta = (w[:, None] * np.stack(sent)).sum(0) / w.sum()
        new = np.asarray(gbuf, np.float64) + delta[:num_params]
        state, gbuf = server.apply(state, gbuf, jnp.asarray(new, jnp.float32))
        params = packing.unpack_numeric(gbuf, manifest)
    return np.asarray(params["w"])


def _topk_federation(case, sparse_mode, store_mode="arena", k=2,
                     value_dtype="f32"):
    from repro.core.transport import TopkUploadCodec

    ctrl = Controller(
        protocol=case["proto"](), secure=case["secure"],
        store_mode=store_mode,
        upload_codec=TopkUploadCodec(k=k, value_dtype=value_dtype),
        sparse_mode=sparse_mode,
    )
    ctrl.set_initial_model(_INIT)
    for i in range(case["n"]):
        ctrl.register_learner(_make_learner(i))
    if case["updates"]:
        ctrl.engine.run(total_updates=case["updates"])
    else:
        ctrl.engine.run(rounds=case["rounds"])
    out = np.asarray(ctrl.global_params["w"])
    pad = ctrl.arena.padded_params if ctrl.arena is not None else None
    stats = ctrl.channel.stats
    tele = ctrl.telemetry
    expected_uploads = case["n"] * (case["rounds"] + case["updates"])
    ctrl.shutdown()
    return out, pad, stats, tele, expected_uploads


@pytest.mark.parametrize("sparse_mode", ["direct", "densify"])
@pytest.mark.parametrize("proto", ["sync", "semi_sync", "async",
                                   "buffered_async"])
def test_topk_arena_conformance(proto, sparse_mode):
    """topk × fedavg protocols × sparse_mode vs the f64 EF replay: the
    scatter-accumulate (direct) and the densified rows (densify) must land
    within float-accumulation tolerance of the reference — and the direct
    arm must prove it never densified (sparse counters fired)."""
    case = _CASES[proto]
    got, pad, stats, tele, expected = _topk_federation(
        case, sparse_mode, "arena", k=2
    )
    ref = _topk_reference(case, k=2, pad=pad)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    assert stats.upload_messages == expected
    assert stats.upload_bytes > 0 and stats.upload_meta_bytes > 0
    if sparse_mode == "direct":
        assert tele.value("engine.uploads.sparse_direct", 0) == expected
        assert tele.value("controller.aggregations.sparse_scatter", 0) > 0
    else:
        assert tele.value("engine.uploads.sparse_direct", 0) == 0


@pytest.mark.parametrize("proto", ["sync", "async"])
def test_topk_stack_conformance(proto):
    """topk × stack store (densify is implied): dense decoded deltas flow
    the legacy path, aggregate, and fold onto the global buffer."""
    case = _CASES[proto]
    got, pad, stats, _, expected = _topk_federation(
        case, "densify", "stack", k=2
    )
    ref = _topk_reference(case, k=2, pad=pad)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    assert stats.upload_messages == expected


def test_topk_int8_values_conformance():
    """topk with int8-grouped values: selection and grouped quantization in
    the reference use the same codec, so parity stays tight — the EF carry
    absorbs the quantization error instead of compounding it."""
    case = _CASES["sync"]
    got, pad, _, _, _ = _topk_federation(
        case, "direct", "arena", k=2, value_dtype="int8"
    )
    ref = _topk_reference(case, k=2, pad=pad, value_dtype="int8")
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


def test_topk_direct_vs_densify_landing_parity():
    """The SAME topk wire envelopes, ingested in the SAME order, aggregate
    to the same model whether they land in the (n, k) sparse arena (masked
    scatter-accumulate) or are densified into f32 rows first."""
    from repro.core.learner import LocalUpdate
    from repro.core.transport import TopkUploadCodec

    ctrls = {
        mode: Controller(
            protocol=SyncProtocol(local_steps=2, batch_size=16),
            store_mode="arena", upload_codec=TopkUploadCodec(k=16),
            sparse_mode=mode,
        )
        for mode in ("direct", "densify")
    }
    for ctrl in ctrls.values():
        ctrl.set_initial_model(_INIT)
        for i in range(3):
            ctrl.register_learner(_make_learner(i))
    P = ctrls["direct"].arena.padded_params
    rng = np.random.default_rng(0)
    rows = [jnp.asarray(rng.normal(size=P), jnp.float32) for _ in range(3)]
    for mode, ctrl in ctrls.items():
        for i, row in enumerate(rows):
            env = ctrl.channel.upload(
                row, metadata={"learner_id": f"l{i}", "round_id": 0})
            ctrl.ingest(LocalUpdate(
                learner_id=f"l{i}", round_id=0, params=None, buffer=None,
                num_examples=10 * (i + 1), metrics={},
                seconds_per_step=0.01, upload=env,
            ))
        ctrl.aggregate_round([f"l{i}" for i in range(3)])
    got_direct = np.asarray(ctrls["direct"].global_buffer)
    got_densify = np.asarray(ctrls["densify"].global_buffer)
    for ctrl in ctrls.values():
        ctrl.shutdown()
    np.testing.assert_allclose(got_direct, got_densify, rtol=1e-6, atol=1e-7)
    assert ctrls["direct"].telemetry.value(
        "engine.uploads.sparse_direct", 0) == 3
    assert ctrls["direct"].telemetry.value(
        "controller.aggregations.sparse_scatter", 0) == 1
    # resident state: (n, k) values + indices, NOT n dense rows
    arena = ctrls["direct"].arena
    assert arena.buffer.shape == (arena.n_max, 16)
    assert arena.indices.shape == (arena.n_max, 16)


def test_topk_uplink_actually_compresses():
    """Acceptance ratios at k = P/64: the sparse wire must carry >= 8x
    fewer uplink bytes than raw and >= 2x fewer than int8 (P = 1024, the
    padded arena row)."""
    from repro.core.transport import TopkUploadCodec

    case = _CASES["sync"]
    _, raw_stats, n = _federation(case, "arena", "raw")
    _, int8_stats, _ = _federation(case, "arena", "int8")
    got, _, topk_stats, _, n_topk = _topk_federation(
        case, "direct", "arena", k=1024 // 64
    )
    assert raw_stats.upload_messages == topk_stats.upload_messages == n
    from repro.kernels.topk import wire_layout_topk

    _, _, payload = wire_layout_topk(1024, 1024 // 64, "f32", 64)
    assert topk_stats.upload_bytes == n * payload
    assert raw_stats.upload_bytes / topk_stats.upload_bytes >= 8.0
    assert int8_stats.upload_bytes / topk_stats.upload_bytes >= 2.0
    assert np.isfinite(got).all()


def test_topk_rejects_secure_and_robust_direct():
    """Construction-time refusals: secure × topk, and direct × robust."""
    from repro.core.transport import TopkUploadCodec

    with pytest.raises(ValueError, match="secure"):
        Controller(upload_codec=TopkUploadCodec(k=4), secure=True)
    with pytest.raises(ValueError, match="fedavg"):
        Controller(upload_codec=TopkUploadCodec(k=4), sparse_mode="direct",
                   aggregation_rule="median")
    with pytest.raises(ValueError, match="topk"):
        Controller(upload_codec="raw", sparse_mode="direct")


@pytest.mark.multidevice
def test_topk_arena_conformance_sharded():
    """The sparse grid on the mesh-sharded arena (8 forced host devices):
    sync and async × direct/densify, the column-sharded scatter-accumulate
    vs a single-device federation of the same workload."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    script = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (AsyncProtocol, Controller, Learner,
                                SyncProtocol)
        from repro.core.transport import TopkUploadCodec
        from repro.launch.mesh import make_controller_mesh
        from repro.optim import sgd

        INIT = {"w": np.zeros((4, 1), np.float32)}

        def make_learner(i):
            def loss_fn(p, b):
                return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)
            rng = np.random.default_rng(i)
            X = rng.normal(size=(64, 4)).astype(np.float32)
            y = X @ np.ones((4, 1), np.float32)
            def data_fn(bs):
                j = rng.integers(0, 64, size=bs)
                return X[j], y[j]
            return Learner(
                f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
                data_fn, lambda: (X, y), sgd(0.05), 64,
            )

        CASES = {
            "sync": (lambda: SyncProtocol(local_steps=2, batch_size=16),
                     3, 2, 0),
            "async": (lambda: AsyncProtocol(local_steps=2, batch_size=16),
                      1, 0, 3),
        }

        def federation(name, sparse_mode, mesh):
            proto_fn, n, rounds, updates = CASES[name]
            ctrl = Controller(protocol=proto_fn(), arena_mesh=mesh,
                              store_mode="arena",
                              upload_codec=TopkUploadCodec(k=2),
                              sparse_mode=sparse_mode)
            ctrl.set_initial_model(INIT)
            for i in range(n):
                ctrl.register_learner(make_learner(i))
            if updates:
                ctrl.engine.run(total_updates=updates)
            else:
                ctrl.engine.run(rounds=rounds)
            got = np.asarray(ctrl.global_params["w"])
            scat = ctrl.telemetry.value(
                "controller.aggregations.sparse_scatter", 0)
            ctrl.shutdown()
            return got, scat

        assert jax.device_count() == 8
        for name in CASES:
            for mode in ("direct", "densify"):
                got_sh, scat = federation(name, mode, make_controller_mesh())
                got_1d, _ = federation(name, mode, None)
                if mode == "direct":
                    assert scat > 0, (name, mode)
                np.testing.assert_allclose(got_sh, got_1d, rtol=1e-5,
                                           atol=1e-6,
                                           err_msg=f"{name}/{mode}")
        print("SHARDED-TOPK-ARENA-OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "SHARDED-TOPK-ARENA-OK" in out.stdout
