"""Property tests for the sparse (top-k) uplink and scatter aggregation.

Four invariants hold the sparse path together:

* the ``topk`` codec round-trips: ``unpack_coords(encode(row))`` returns the
  selected (index, value) stream, and ``decode`` densifies it losslessly for
  f32 values / inside the per-group quantization bound for int8 values;
* error feedback conserves mass — ``densify(sent) + residual == update``
  coordinate-exactly in f32 (the residual is ``update - sent``, computed
  against the *dequantized* wire values, so the carry sees exactly what the
  controller sees);
* top-k selection is permutation-equivariant: permuting the row permutes the
  selected coordinate set with it (no positional bias in the selection);
* the masked scatter-accumulate matches a float64 numpy densify-then-reduce
  reference under random masks, weights and (unique-per-row) index streams.

Runs under real hypothesis when installed, else the deterministic
``tests/hypothesis_compat.py`` mini-engine.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import aggregation
from repro.core.transport import Channel, TopkUploadCodec
from repro.kernels import sparse_agg
from repro.kernels import topk as topk_kernels


@st.composite
def _rows(draw):
    """A random f32 row with its codec k (sometimes clamped: k >= n)."""
    n = draw(st.integers(2, 257))
    k = draw(st.integers(1, 300))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    row = rng.normal(size=(n,)).astype(np.float32) * 3.0
    return row, k


@given(_rows(), st.sampled_from(("f32", "int8")))
@settings(max_examples=25, deadline=None)
def test_topk_codec_roundtrips(row_k, value_dtype):
    """encode -> unpack_coords/decode recovers the selected coordinates."""
    row, k = row_k
    n = row.shape[0]
    codec = TopkUploadCodec(k=k, value_dtype=value_dtype, group=32)
    payload = codec.encode(jnp.asarray(row))
    k_eff, n_scales, nbytes = topk_kernels.wire_layout_topk(
        n, k, value_dtype, 32
    )
    assert payload.nbytes == nbytes
    idx, val = codec.unpack_coords(payload, n)
    idx = np.asarray(idx)
    val = np.asarray(val)
    assert idx.shape == val.shape == (k_eff,)
    # Indices are unique and in range, and they are the k largest magnitudes.
    assert len(set(idx.tolist())) == k_eff
    assert idx.min() >= 0 and idx.max() < n
    order = np.argsort(-np.abs(row), kind="stable")
    assert set(idx.tolist()) == set(order[:k_eff].tolist())
    dense = np.asarray(codec.decode(payload, n))
    assert dense.shape == (n,)
    if value_dtype == "f32":
        np.testing.assert_array_equal(val, row[idx])
        np.testing.assert_array_equal(dense[idx], row[idx])
    else:
        # Blockwise int8: |dequant - x| <= scale/2 per value, scale = amax/127
        # over the value group the coordinate landed in.
        assert np.max(np.abs(val - row[idx])) <= np.abs(row).max() / 127.0
    off = np.ones(n, bool)
    off[idx] = False
    assert not dense[off].any()


@given(_rows(), st.sampled_from(("f32", "int8")))
@settings(max_examples=25, deadline=None)
def test_error_feedback_conserves_update_mass(row_k, value_dtype):
    """densify(sent) + residual == update, coordinate-exact in f32."""
    row, k = row_k
    n = row.shape[0]
    codec = TopkUploadCodec(k=k, value_dtype=value_dtype, group=32)
    acc = jnp.asarray(row)
    payload = codec.encode(acc)
    idx, val = codec.unpack_coords(payload, n)
    residual = topk_kernels.ef_residual(acc, idx, val)
    sent = topk_kernels.densify(idx, val, n)
    # Exact: residual is literally acc - sent at the selected coordinates
    # (and acc elsewhere), both computed in f32 from the same wire values.
    np.testing.assert_array_equal(
        np.asarray(sent + residual), np.asarray(acc)
    )
    if value_dtype == "f32":
        # f32 values: the carry is exactly zero where the wire sent mass.
        assert not np.asarray(residual)[np.asarray(idx)].any()


@given(_rows(), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_topk_selection_is_permutation_equivariant(row_k, seed):
    """Permuting the row permutes the selected coordinate set with it."""
    row, k = row_k
    n = row.shape[0]
    # Distinct magnitudes so the top-k *set* is unambiguous under ties.
    rng = np.random.default_rng(seed)
    mags = np.sort(rng.uniform(0.5, 100.0, size=n))[::-1]
    mags = mags + np.arange(n)[::-1]  # strictly distinct
    row = (np.sign(row) + (row == 0)) * mags.astype(np.float32)
    k_eff = topk_kernels.effective_k(n, k)
    perm = rng.permutation(n)
    idx, _ = topk_kernels.topk_select(jnp.asarray(row), k_eff)
    idx_p, _ = topk_kernels.topk_select(jnp.asarray(row[perm]), k_eff)
    want = {int(perm[j]) for j in np.asarray(idx_p)}
    assert {int(j) for j in np.asarray(idx)} == want


@st.composite
def _arenas(draw):
    """A random (N, k) sparse arena + weights + mask + output width."""
    n_rows = draw(st.integers(1, 9))
    width = draw(st.integers(4, 600))
    k = draw(st.integers(1, min(width, 48)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    indices = np.stack([
        rng.choice(width, size=k, replace=False).astype(np.int32)
        for _ in range(n_rows)
    ])
    values = rng.normal(size=(n_rows, k)).astype(np.float32)
    weights = rng.uniform(0.5, 20.0, size=n_rows).astype(np.float32)
    mask = (rng.uniform(size=n_rows) < 0.7).astype(np.float32)
    if not mask.any():
        mask[rng.integers(n_rows)] = 1.0
    # Masked-out rows may carry garbage — the reduce must ignore it.
    values[mask == 0.0] = np.nan
    return indices, values, weights, mask, width


@given(_arenas())
@settings(max_examples=25, deadline=None)
def test_scatter_accumulate_matches_f64_densify_reference(arena):
    """Masked scatter-add == densify rows in f64, weight, and sum."""
    indices, values, weights, mask, width = arena
    out = np.asarray(sparse_agg.scatter_accumulate(
        jnp.asarray(indices), jnp.asarray(values), jnp.asarray(weights),
        jnp.asarray(mask), width,
    ))
    ref = np.zeros(width, np.float64)
    for r in range(indices.shape[0]):
        if mask[r] == 0.0:
            continue
        dense = np.zeros(width, np.float64)
        np.add.at(dense, indices[r], values[r].astype(np.float64))
        ref += float(weights[r]) * dense
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@given(_arenas())
@settings(max_examples=25, deadline=None)
def test_masked_fedavg_topk_matches_dense_masked_average(arena):
    """Sparse-arena FedAvg == masked_weighted_average of densified rows."""
    indices, values, weights, mask, width = arena
    out = np.asarray(aggregation.masked_fedavg_topk(
        jnp.asarray(indices), jnp.asarray(values), jnp.asarray(weights),
        jnp.asarray(mask), width,
    ))
    dense = np.zeros((indices.shape[0], width), np.float32)
    for r in range(indices.shape[0]):
        if mask[r] == 0.0:
            continue
        np.add.at(dense[r], indices[r], values[r])
    ref = np.asarray(aggregation.masked_weighted_average(
        jnp.asarray(dense), jnp.asarray(weights), jnp.asarray(mask)
    ))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_sparse_norm_equals_dense_row_norm(k, seed):
    """recv_upload_sparse's fused norm == L2 norm of the densified row."""
    rng = np.random.default_rng(seed)
    n = 128
    row = rng.normal(size=(n,)).astype(np.float32)
    ch = Channel(upload_codec=TopkUploadCodec(k=k))
    env = ch.upload(jnp.asarray(row))
    idx, val, norm = ch.recv_upload_sparse(env)
    dense = topk_kernels.densify(idx, val, n)
    np.testing.assert_allclose(
        float(norm), float(jnp.linalg.norm(dense)), rtol=1e-6
    )
