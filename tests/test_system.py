"""End-to-end system tests: full federated workflows through the public API —
the paper's workflow (Fig. 1) with real training, aggregation, evaluation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Driver, FederationEnv, TerminationCriteria
from repro.launch.train import build_housing_learners, build_lm_learners
from repro.models import mlp as mlp_model
from repro.models import transformer
from repro.optim import sgd

# End-to-end federation runs: minutes each — nightly lane, not tier-1.
pytestmark = pytest.mark.slow


def test_housing_mlp_federation_converges():
    """The paper's exact stress-test workload at reduced scale: HousingMLP,
    FedAvg, vanilla SGD, 100 samples/learner - loss must decrease."""
    cfg, learners = build_housing_learners("100k", n_learners=4, seed=0)
    initial = mlp_model.init_params(jax.random.key(0), cfg)
    env = FederationEnv(
        protocol="sync", local_steps=8, batch_size=50, learning_rate=0.01,
        termination=TerminationCriteria(max_rounds=4),
    )
    drv = Driver(env)
    drv.initialize(initial, learners)
    hist = drv.run()
    losses = [h.metrics["eval_loss"] for h in hist]
    assert losses[-1] < losses[0], losses
    # the six per-op timings of Figs. 5-7 are all recorded
    assert all(h.federation_round_s > 0 for h in hist)


def test_transformer_federation_loss_decreases():
    """Federated LM training with a reduced assigned-arch config."""
    from repro.configs import get_reduced

    cfg = get_reduced("qwen3-14b")
    learners = build_lm_learners(cfg, n_learners=3, seed=0,
                                 n_seq_per_learner=32, seq_len=24,
                                 optimizer=sgd(0.5))
    initial = transformer.init_params(jax.random.key(0), cfg)
    env = FederationEnv(
        protocol="sync", local_steps=6, batch_size=16,
        termination=TerminationCriteria(max_rounds=3),
    )
    drv = Driver(env)
    drv.initialize(initial, learners)
    hist = drv.run()
    losses = [h.metrics["eval_loss"] for h in hist]
    assert losses[-1] < losses[0], losses


def test_quantized_transport_federation():
    """int8 transport codec end-to-end: converges despite lossy shipping."""
    from repro.kernels.ops import QuantCodec

    cfg, learners = build_housing_learners("100k", n_learners=3, seed=1)
    initial = mlp_model.init_params(jax.random.key(0), cfg)
    env = FederationEnv(
        protocol="sync", local_steps=8, batch_size=50, learning_rate=0.01,
        termination=TerminationCriteria(max_rounds=3),
    )
    drv = Driver(env)
    drv.controller.channel.codec = QuantCodec()
    drv.initialize(initial, learners)
    hist = drv.run()
    losses = [h.metrics["eval_loss"] for h in hist]
    assert losses[-1] < losses[0], losses
    assert drv.controller.channel.stats.bytes_moved > 0


def test_semi_sync_federation_runs():
    cfg, learners = build_housing_learners("100k", n_learners=3, seed=2)
    initial = mlp_model.init_params(jax.random.key(0), cfg)
    env = FederationEnv(
        protocol="semi_sync", hyperperiod_s=0.2, local_steps=2, batch_size=50,
        termination=TerminationCriteria(max_rounds=3),
    )
    drv = Driver(env)
    drv.initialize(initial, learners)
    hist = drv.run()
    assert len(hist) == 3
    prof = drv.controller._learner_profiles
    assert all("seconds_per_step" in p for p in prof.values())


def test_async_federation_converges():
    cfg, learners = build_housing_learners("100k", n_learners=3, seed=3)
    initial = mlp_model.init_params(jax.random.key(0), cfg)
    env = FederationEnv(
        protocol="async", local_steps=5, batch_size=50, learning_rate=0.01,
        staleness_alpha=0.5,
        termination=TerminationCriteria(max_rounds=9),  # = async updates
    )
    drv = Driver(env)
    drv.initialize(initial, learners)
    drv.run()
    data = learners[0]._eval_data_fn()
    final = float(mlp_model.mse_loss(drv.controller.global_params, data))
    init_loss = float(mlp_model.mse_loss(initial, data))
    assert final < init_loss
