"""Model-stack tests: per-arch smoke (reduced configs), decode/prefill
consistency, MoE expert-parallel vs dense oracle, SSD chunked vs sequential,
chunked vs naive attention."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_reduced
from repro.models import kvcache, layers, transformer
from repro.models.config import ModelConfig
from repro.models.sharding import make_policy


def _batch_for(cfg, B=2, S=16, seed=1):
    tokens = jax.random.randint(jax.random.key(seed), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.key(seed + 1), (B, cfg.num_prefix_tokens, cfg.frontend_dim),
            jnp.float32,
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            jax.random.key(seed + 1), (B, cfg.encoder_seq_len, cfg.frontend_dim),
            jnp.float32,
        )
    return batch


# ---------------------------------------------------------------------------
# per-arch smoke: REDUCED variant, one forward + one train step on CPU
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_arch_smoke_forward_and_train_step(arch):
    from repro.launch.steps import make_train_step
    from repro.optim import sgd

    cfg = get_reduced(arch)
    params = transformer.init_params(jax.random.key(0), cfg)
    batch = _batch_for(cfg)

    # forward: logits shape + finite
    logits, _, aux = transformer.forward(
        params, batch["tokens"], cfg,
        prefix_embeds=batch.get("prefix_embeds"), frames=batch.get("frames"),
    )
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one train step: loss finite, params change, no NaNs anywhere
    opt = sgd(0.1)
    step = jax.jit(make_train_step(cfg, opt, None))
    new_params, _, loss = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(loss)), arch
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, new_params,
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0, "params did not move"
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch",
    ["gemma3-4b", "mamba2-780m", "zamba2-1.2b", "deepseek-v3-671b",
     "qwen3-14b", "whisper-large-v3", "qwen2-moe-a2.7b"],
)
def test_decode_matches_prefill(arch):
    cfg = dataclasses.replace(get_reduced(arch), dtype=jnp.float32)
    params = transformer.init_params(jax.random.key(0), cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    memory = None
    kw = {}
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq_len, cfg.frontend_dim), jnp.float32
        )
        memory = transformer.encode(params, frames, cfg)
        kw["memory"] = memory
    logits_pre, _, _ = transformer.forward(params, tokens, cfg, **kw)
    cache = kvcache.init_cache(cfg, B, 16, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = transformer.decode_step(
            params, tokens[:, t : t + 1], cache, jnp.asarray(t, jnp.int32), cfg,
            memory=memory,
        )
        outs.append(lg)
    err = float(jnp.max(jnp.abs(logits_pre - jnp.concatenate(outs, axis=1))))
    assert err < 2e-3, (arch, err)


# ---------------------------------------------------------------------------
# layer-level equivalences
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["causal", "sliding", "full"])
def test_chunked_attention_matches_naive(mode):
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=100, sliding_window=48,
        attn_chunk_min_len=1, attn_k_chunk=37,
    )
    p = layers.init_attention(jax.random.key(0), cfg)
    B, S = 2, 100
    x = jax.random.normal(jax.random.key(1), (B, S, 64), jnp.float32)
    pos = jnp.arange(S)[None, :].repeat(B, 0)
    y_chunk, _ = layers.apply_attention(p, x, cfg, positions=pos, mode=mode)
    y_naive, _ = layers.apply_attention(
        p, x, dataclasses.replace(cfg, attn_naive=True), positions=pos, mode=mode
    )
    np.testing.assert_allclose(y_chunk, y_naive, atol=3e-5)


def test_mla_chunked_matches_naive():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=1, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=100, attn_impl="mla", q_lora_rank=24, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        attn_chunk_min_len=1, attn_k_chunk=33,
    )
    p = layers.init_mla(jax.random.key(2), cfg)
    x = jax.random.normal(jax.random.key(3), (2, 100, 64), jnp.float32)
    pos = jnp.arange(100)[None, :].repeat(2, 0)
    y_c, _ = layers.apply_mla(p, x, cfg, positions=pos, mode="causal")
    y_n, _ = layers.apply_mla(
        p, x, dataclasses.replace(cfg, attn_naive=True), positions=pos, mode="causal"
    )
    np.testing.assert_allclose(y_c, y_n, atol=3e-5)


def test_moe_ep_matches_dense_oracle():
    cfg = ModelConfig(
        name="t", arch_type="moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab_size=100, n_experts=4, top_k=2, moe_d_ff=48,
        n_shared_experts=1, shared_d_ff=48, capacity_factor=4.0,
    )
    p = layers.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32), jnp.float32)
    y_dense, aux_d = layers.apply_moe_dense(p, x, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = make_policy(cfg, mesh)
    y_ep, aux_e = jax.jit(lambda p_, x_: layers.apply_moe_ep(p_, x_, cfg, pol))(p, x)
    np.testing.assert_allclose(y_dense, y_ep, atol=1e-4)
    np.testing.assert_allclose(aux_d, aux_e, rtol=1e-5)


def test_moe_padded_experts_never_routed():
    cfg = ModelConfig(
        name="t", arch_type="moe", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=32, vocab_size=50, n_experts=3, expert_pad_to=4, top_k=2, moe_d_ff=24,
    )
    assert cfg.padded_n_experts == 4
    p = layers.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 32, 16), jnp.float32)
    probs, gates, idx = layers._router_probs(p, x.reshape(-1, 16), cfg)
    assert int(jnp.max(idx)) < 3  # pad expert (id 3) never selected


@pytest.mark.slow
def test_ssd_chunked_matches_sequential():
    cfg = ModelConfig(
        name="t", arch_type="ssm", n_layers=1, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=100, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    )
    p = layers.init_mamba(jax.random.key(0), cfg)
    B, S = 2, 37  # deliberately not a multiple of the chunk
    x = jax.random.normal(jax.random.key(1), (B, S, 64), jnp.float32)
    y_full, _ = layers.apply_mamba(p, x, cfg)
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cache = {
        "conv": jnp.zeros((B, cfg.conv_width - 1, di + 2 * N)),
        "ssm": jnp.zeros((B, H, Pd, N)),
    }
    ys = []
    for t in range(S):
        yt, cache = layers.apply_mamba(
            p, x[:, t : t + 1], cfg, cache=cache, decode_pos=jnp.asarray(t)
        )
        ys.append(yt)
    np.testing.assert_allclose(y_full, jnp.concatenate(ys, 1), atol=1e-3)


def test_segments_cover_all_layers():
    from repro.models.config import plan_segments

    for arch in ARCHITECTURES:
        cfg = get_reduced(arch)
        segs = plan_segments(cfg)
        assert sum(s.n_layers for s in segs) == cfg.n_layers, arch
        full = get_reduced(arch)  # full config pattern check
        from repro.configs import get_config

        cfg_full = get_config(arch)
        segs_full = plan_segments(cfg_full)
        assert sum(s.n_layers for s in segs_full) == cfg_full.n_layers, arch


def test_param_count_estimate_close():
    """Closed-form estimate used for MODEL_FLOPS must track actual params."""
    import numpy as np

    for arch in ARCHITECTURES:
        cfg = get_reduced(arch)
        params = transformer.init_params(jax.random.key(0), cfg)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
        est = cfg.param_count_estimate()
        assert abs(est - actual) / actual < 0.35, (arch, est, actual)
