"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels execute in interpret mode on CPU (the TPU lowering is proven
structurally by pl.pallas_call + BlockSpec; numerics validated here).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.fedavg import fedavg_pallas
from repro.kernels.quantize import dequantize_pallas, quantize_pallas


# ---------------------------------------------------------------------------
# fedavg kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 7, 64])
@pytest.mark.parametrize("p", [1024, 16384, 50_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_kernel_sweep(n, p, dtype):
    stack = (jax.random.normal(jax.random.key(n * p), (n, p)) * 3).astype(dtype)
    w = jax.random.uniform(jax.random.key(p), (n,)) + 0.05
    got = ops.fedavg(stack, w)
    want = ref.fedavg_ref(stack, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


def test_fedavg_kernel_block_shapes():
    stack = jax.random.normal(jax.random.key(0), (4, 8192), jnp.float32)
    w = jnp.ones((4,))
    want = ref.fedavg_ref(stack, w)
    for block_p in (1024, 2048, 8192):
        got = fedavg_pallas(stack, w, block_p=block_p, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 12), seed=st.integers(0, 99))
def test_fedavg_kernel_property_matches_oracle(n, seed):
    p = 2048
    stack = jax.random.normal(jax.random.key(seed), (n, p), jnp.float32)
    w = jax.random.uniform(jax.random.key(seed + 1), (n,)) + 0.01
    np.testing.assert_allclose(
        np.asarray(ops.fedavg(stack, w)), np.asarray(ref.fedavg_ref(stack, w)),
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# robust (trimmed-mean) kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,trim_k", [(3, 1), (8, 1), (8, 3), (16, 4)])
@pytest.mark.parametrize("p", [1024, 5000])
def test_trimmed_mean_kernel_sweep(n, trim_k, p):
    arena = jax.random.normal(jax.random.key(n * p + trim_k), (n, p)) * 3
    mask = (jax.random.uniform(jax.random.key(p + n), (n,)) > 0.3).astype(
        jnp.float32
    )
    w = jnp.ones((n,))
    got = ops.masked_trimmed_mean(arena, w, mask, trim_k=trim_k)
    want = ref.masked_trimmed_mean_ref(arena, mask, trim_k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_trimmed_mean_kernel_matches_core_rule():
    from repro.core import aggregation

    arena = jax.random.normal(jax.random.key(0), (12, 4096), jnp.float32)
    mask = jnp.ones((12,)).at[3].set(0.0).at[7].set(0.0)
    w = jnp.ones((12,))
    got = ops.masked_trimmed_mean(arena, w, mask, trim_k=2)
    want = aggregation.masked_trimmed_mean(arena, w, mask, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_trimmed_mean_kernel_ignores_dead_row_garbage():
    arena = np.ones((6, 2048), np.float32)
    arena[2] = np.nan
    arena[4] = 1e30
    mask = np.array([1, 1, 0, 1, 0, 1], np.float32)
    got = ops.masked_trimmed_mean(jnp.asarray(arena), jnp.ones((6,)),
                                  jnp.asarray(mask), trim_k=1)
    np.testing.assert_allclose(np.asarray(got), np.ones(2048), atol=1e-6)


def test_trimmed_mean_kernel_degenerate_cohort_falls_back():
    arena = jax.random.normal(jax.random.key(5), (8, 1024), jnp.float32)
    mask = jnp.zeros((8,)).at[0].set(1.0).at[5].set(1.0)
    got = ops.masked_trimmed_mean(arena, jnp.ones((8,)), mask, trim_k=2)
    want = (arena[0] + arena[5]) / 2.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_trimmed_mean_kernel_trim_k_trace_error():
    arena = jnp.ones((4, 1024), jnp.float32)
    with pytest.raises(ValueError, match="trim_k"):
        ops.masked_trimmed_mean(arena, jnp.ones((4,)), jnp.ones((4,)),
                                trim_k=2, block_p=1024)


# ---------------------------------------------------------------------------
# quantize kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("size", [16384, 65536, 100_000])
def test_quantize_kernel_matches_ref(size):
    x = jax.random.normal(jax.random.key(size), (size,), jnp.float32) * 5
    q, s = ops.quantize(x)
    pad = q.shape[0]
    qr, sr = ref.quantize_ref(jnp.pad(x, (0, pad - size)))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(1), (32768,), jnp.float32) * 10
    q, s = ops.quantize(x)
    back = ops.dequantize(q, s, 32768)
    # per-group bound: |err| <= scale/2 = max|x|_group / 254
    xg = np.asarray(x).reshape(-1, 256)
    bound = np.abs(xg).max(1, keepdims=True) / 254.0 + 1e-7
    err = np.abs(np.asarray(back).reshape(-1, 256) - xg)
    assert (err <= bound).all()


def test_quantize_zero_block_safe():
    x = jnp.zeros((16384,), jnp.float32)
    q, s = ops.quantize(x)
    assert bool(jnp.all(q == 0))
    back = ops.dequantize(q, s, 16384)
    assert bool(jnp.all(back == 0))


def test_quant_codec_roundtrip_mixed_tree():
    tree = {
        "w": jax.random.normal(jax.random.key(0), (33, 57), jnp.bfloat16),
        "b": jax.random.normal(jax.random.key(1), (129,), jnp.float32),
        "step": jnp.asarray(7, jnp.int32),
    }
    dec = ops.QuantCodec.decode(ops.QuantCodec.encode(tree))
    assert dec["w"].shape == (33, 57) and dec["w"].dtype == jnp.bfloat16
    assert dec["b"].dtype == jnp.float32
    assert int(dec["step"]) == 7
    rel = np.abs(np.asarray(dec["b"]) - np.asarray(tree["b"]))
    assert rel.max() < np.abs(np.asarray(tree["b"])).max() / 100


def test_choose_block_p_fits_vmem():
    from repro.kernels.fedavg import VMEM_BUDGET_BYTES, choose_block_p

    for n in (2, 8, 50, 200, 1000):
        bp = choose_block_p(n)
        working = 2 * n * bp * 4 + bp * 4 + n * 4
        assert working <= VMEM_BUDGET_BYTES, (n, bp, working)
        assert bp % 1024 == 0 or bp == 1024
        got = ops.fedavg(
            jax.random.normal(jax.random.key(n), (n, 4096), jnp.float32),
            jnp.ones((n,)),
        )
        want = ref.fedavg_ref(
            jax.random.normal(jax.random.key(n), (n, 4096), jnp.float32),
            jnp.ones((n,)),
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# fused dequant-into-aggregate kernel
# ---------------------------------------------------------------------------


def _quantized_arena(n, p, seed=0, group=256, scale_spread=5.0):
    """A synthetic quantized arena: random int8 groups + spread-out scales."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-127, 128, size=(n, p), dtype=np.int8)
    s = rng.uniform(0.01, scale_spread, size=(n, p // group)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(s)


@pytest.mark.parametrize("n,p", [(3, 4096), (8, 16384), (33, 8192)])
def test_fused_q8_kernel_matches_oracle(n, p):
    q, s = _quantized_arena(n, p, seed=n)
    w = jnp.asarray(np.random.default_rng(n + 1).uniform(1, 50, n), jnp.float32)
    mask = jnp.asarray((np.arange(n) % 3 != 1).astype(np.float32))
    got = ops.masked_fedavg_q8(q, s, w, mask)
    want = ref.masked_fedavg_q8_ref(q, s, w, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_q8_kernel_block_sweep():
    q, s = _quantized_arena(5, 8192, seed=7)
    w = jnp.ones((5,), jnp.float32)
    mask = jnp.asarray([1, 0, 1, 1, 0], jnp.float32)
    want = np.asarray(ref.masked_fedavg_q8_ref(q, s, w, mask))
    for block_p in (1024, 2048, 4096, 8192):
        got = ops.masked_fedavg_q8(q, s, w, mask, block_p=block_p)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=2e-5, atol=2e-5, err_msg=str(block_p))


def test_fused_q8_kernel_nondefault_group():
    q, s = _quantized_arena(4, 4096, seed=3, group=512)
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    mask = jnp.ones((4,), jnp.float32)
    got = ops.masked_fedavg_q8(q, s, w, mask, group=512)
    want = ref.masked_fedavg_q8_ref(q, s, w, mask, group=512)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_q8_kernel_all_invalid_mask_is_zero():
    q, s = _quantized_arena(4, 2048, seed=9)
    out = ops.masked_fedavg_q8(q, s, jnp.ones((4,)), jnp.zeros((4,)))
    assert bool(jnp.all(out == 0.0))


def test_fused_q8_kernel_dead_row_garbage_ignored():
    q, s = _quantized_arena(4, 2048, seed=11)
    # poison a masked-out row with extreme values and scales
    q = q.at[2].set(127)
    s = s.at[2].set(1e30)
    mask = jnp.asarray([1, 1, 0, 1], jnp.float32)
    got = ops.masked_fedavg_q8(q, s, jnp.ones((4,)), mask)
    want = ref.masked_fedavg_q8_ref(q, s, jnp.ones((4,)), mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_fused_q8_kernel_shape_errors():
    from repro.kernels.fused_agg import masked_fedavg_q8_pallas

    q, s = _quantized_arena(3, 2048, seed=1)
    w, m = jnp.ones((3,)), jnp.ones((3,))
    with pytest.raises(ValueError, match="block_p"):
        masked_fedavg_q8_pallas(q, s, w, m, block_p=1536, interpret=True)
    with pytest.raises(ValueError, match="scales"):
        masked_fedavg_q8_pallas(q, s[:, :-1], w, m, block_p=2048,
                                interpret=True)


def test_fused_q8_kernel_pads_non_aligned_width():
    # 2048 + one group: not a multiple of any legal block — ops must pad.
    q, s = _quantized_arena(3, 2048 + 256, seed=5)
    got = ops.masked_fedavg_q8(q, s, jnp.ones((3,)), jnp.ones((3,)),
                               block_p=1024)
    want = ref.masked_fedavg_q8_ref(q, s, jnp.ones((3,)), jnp.ones((3,)))
    assert got.shape == (2048 + 256,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_choose_block_p_q8_fits_vmem_and_divides():
    from repro.kernels.fused_agg import (
        VMEM_BUDGET_BYTES, choose_block_p_q8, choose_block_p_q8_dividing,
    )

    for n in (2, 8, 50, 200, 1000):
        bp = choose_block_p_q8(n)
        # int8 values + f32 out-tile accum + scales + weights/mask vectors
        working = n * bp + 4 * n * bp + 4 * n * (bp // 256) + 4 * bp + 8 * n
        assert working <= VMEM_BUDGET_BYTES, (n, bp, working)
        assert bp % 1024 == 0
    bp = choose_block_p_q8_dividing(16 * 1024, 8, 256)
    assert (16 * 1024) % bp == 0


def test_dequantize_scale_count_error():
    q = jnp.zeros((16384,), jnp.int8)
    s = jnp.zeros((3,), jnp.float32)  # wrong: needs 64 scales
    with pytest.raises(ValueError, match="scales"):
        ops.dequantize(q, s, 16384)
