"""Crash-consistent checkpoint/resume: kill-and-resume bit-identity.

The tentpole's acceptance contract: a federation killed at a checkpointed
round boundary and resumed on a freshly constructed controller must produce
a **bit-identical** global model to the uninterrupted run — across the full
protocol × store grid (sync / semi-sync / async / buffered-async FedBuff /
deadline cohorts / reputation × arena / stack).  The FedBuff rows resume
through a *partially filled* arrival buffer: the checkpoint carries
``pending_buffer`` (drained in-flight arrivals) and ``pending_dispatch``
(learners to re-dispatch), which the fresh engine replays.

Determinism preconditions the harness supplies (and the docs document):

* learners feed a *constant* data batch (call-count-independent — the
  resumed run constructs fresh learners, so any data schedule keyed on call
  counts would diverge);
* learners report a fixed seconds-per-step (semi-sync sizes tasks from the
  EWMA profile; measured wall-clock would make sizing nondeterministic);
* async runs n=1 (multi-learner async arrival order is scheduler-dependent
  by design);
* arena rows follow registration order (``ArenaStore.ensure_row`` at
  registration), so aggregation order is reproducible across processes.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsyncProtocol,
    BufferedAsyncProtocol,
    Controller,
    DeadlineCohortProtocol,
    Learner,
    ReputationProtocol,
    SemiSyncProtocol,
    SyncProtocol,
)
from repro.optim import sgd


def _make_learner(i):
    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(16, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)

    class _Fixed(Learner):
        def fit(self, params, task):
            update = super().fit(params, task)
            update.seconds_per_step = 1e-3
            return update

    return _Fixed(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        lambda bs: (X, y), lambda: (X, y), sgd(0.05), 16,
    )


def _protocol(name):
    if name == "sync":
        return SyncProtocol(local_steps=2, batch_size=8)
    if name == "semi_sync":
        return SemiSyncProtocol(hyperperiod_s=0.05, batch_size=8,
                                default_steps=2)
    if name == "buffered_async":
        return BufferedAsyncProtocol(buffer_k=2, local_steps=2, batch_size=8)
    if name == "deadline":
        # wall-clock deadline timers off: predicted cohorts only, so the
        # resumed run sees the same cohorts as the golden run
        return DeadlineCohortProtocol(deadline_s=1e6, local_steps=2,
                                      batch_size=8, enforce_wall_clock=False)
    if name == "reputation":
        return ReputationProtocol(fraction=1.0, local_steps=2, batch_size=8)
    return AsyncProtocol(local_steps=2, batch_size=8)


# FedBuff interleaving (which K arrivals fill the buffer) is arrival-order
# dependent: pin one dispatch worker so golden and resumed runs interleave
# identically.
_CONTINUOUS = ("async", "buffered_async")


def _extra(proto_name):
    if proto_name == "buffered_async":
        return {"max_dispatch_workers": 1}
    return {}


def _build(proto_name, store_mode, n, secure=False, **kwargs):
    ctrl = Controller(protocol=_protocol(proto_name), store_mode=store_mode,
                      secure=secure, **kwargs)
    ctrl.set_initial_model({"w": jnp.zeros((4, 1), jnp.float32)})
    for i in range(n):
        ctrl.register_learner(_make_learner(i))
    return ctrl


def _run(ctrl, proto_name, k):
    if proto_name in _CONTINUOUS:
        return ctrl.engine.run(total_updates=k)
    return ctrl.engine.run(rounds=k)


GRID = [
    ("sync", "arena", 3),
    ("sync", "stack", 3),
    ("semi_sync", "arena", 2),
    ("semi_sync", "stack", 2),
    ("async", "arena", 1),
    ("async", "stack", 1),
    ("buffered_async", "arena", 3),
    ("buffered_async", "stack", 3),
    ("deadline", "arena", 3),
    ("deadline", "stack", 3),
    ("reputation", "arena", 3),
]


@pytest.mark.parametrize("proto,store_mode,n", GRID,
                         ids=[f"{p}-{s}" for p, s, _ in GRID])
def test_kill_and_resume_bit_identical(proto, store_mode, n, tmp_path):
    # golden: 4 uninterrupted rounds / community updates
    golden = _build(proto, store_mode, n, **_extra(proto))
    _run(golden, proto, 4)
    want = np.asarray(golden.global_buffer)
    want_version = golden._model_version
    golden.shutdown()

    # interrupted: checkpoint at round 2, then "kill" the process
    ckpt = str(tmp_path / "ckpt")
    first = _build(proto, store_mode, n,
                   checkpoint_dir=ckpt, checkpoint_every=2, **_extra(proto))
    _run(first, proto, 2)
    first.shutdown()

    # resume on a *fresh* controller (new stores, new learners, new engine)
    resumed = _build(proto, store_mode, n, **_extra(proto))
    meta = resumed.restore(ckpt)
    assert meta["round_id"] == 2
    assert resumed.round_id == 2
    _run(resumed, proto, 2)
    got = np.asarray(resumed.global_buffer)
    resumed.shutdown()

    np.testing.assert_array_equal(got, want)  # bit-identical, not allclose
    assert resumed._model_version == want_version


@pytest.mark.parametrize("rule", ["median", "trimmed_mean"])
@pytest.mark.parametrize("store_mode", ["arena", "stack"])
def test_robust_rule_kill_and_resume_bit_identical(rule, store_mode,
                                                   tmp_path):
    """The byzantine-robust rows of the kill-and-resume grid: a federation
    aggregating with a robust rule resumes bit-identically, and the
    checkpoint pins the rule — resuming under a different one is refused
    rather than silently switching reductions mid-workflow."""
    kw = dict(aggregation_rule=rule, trim_k=1)
    golden = _build("sync", store_mode, 4, **kw)
    _run(golden, "sync", 4)
    want = np.asarray(golden.global_buffer)
    golden.shutdown()

    ckpt = str(tmp_path / "ckpt")
    first = _build("sync", store_mode, 4, checkpoint_dir=ckpt,
                   checkpoint_every=2, **kw)
    _run(first, "sync", 2)
    first.shutdown()

    wrong_rule = _build("sync", store_mode, 4)  # a fedavg controller
    with pytest.raises(ValueError, match="aggregation_rule"):
        wrong_rule.restore(ckpt)
    wrong_rule.shutdown()

    resumed = _build("sync", store_mode, 4, **kw)
    meta = resumed.restore(ckpt)
    assert meta["aggregation_rule"] == rule
    _run(resumed, "sync", 2)
    got = np.asarray(resumed.global_buffer)
    resumed.shutdown()
    np.testing.assert_array_equal(got, want)  # bit-identical, not allclose


def test_resume_restores_admission_and_quarantine_state(tmp_path):
    """Admission EWMA, offense scores and the quarantine set survive a
    kill: the resumed controller clips at the same norm limit and keeps
    the same learners benched — an adversary cannot launder its history
    through a controller restart."""
    ckpt = str(tmp_path / "ckpt")
    first = _build("sync", "arena", 3, aggregation_rule="trimmed_mean")
    _run(first, "sync", 2)
    # warm the admission EWMA past warmup, the way arriving uploads would
    for i in range(10):
        first._screen_upload("l0", jnp.full((4,), jnp.float32(1.0 + 0.1 * i)))
    # two offenses push l0 over the threshold; one leaves l1 clean
    assert first.note_offense("l0") is False
    assert first.note_offense("l0") is True
    first.note_offense("l1")
    assert first.is_quarantined("l0") and not first.is_quarantined("l1")
    want = (first._adm_ewma, first._adm_accepted,
            dict(first._offenses), set(first._quarantined))
    first.save_checkpoint(ckpt)
    first.shutdown()

    resumed = _build("sync", "arena", 3, aggregation_rule="trimmed_mean")
    meta = resumed.restore(ckpt)
    assert resumed._adm_ewma == want[0]  # floats round-trip exactly
    assert resumed._adm_accepted == want[1]
    assert resumed._offenses == want[2]
    assert resumed._quarantined == want[3]
    assert resumed.is_quarantined("l0") and not resumed.is_quarantined("l1")
    assert meta["admission"]["accepted"] == want[1]
    assert resumed.telemetry.value("engine.quarantine.active") == 1
    resumed.shutdown()


def test_secure_sync_resume_bit_identical(tmp_path):
    """Secure aggregation composes: mask sessions are keyed by round id /
    model version (both checkpointed), so the resumed fixed-point sums are
    the golden run's sums exactly."""
    golden = _build("sync", "arena", 2, secure=True)
    _run(golden, "sync", 4)
    want = np.asarray(golden.global_buffer)
    golden.shutdown()

    ckpt = str(tmp_path / "ckpt")
    first = _build("sync", "arena", 2, secure=True,
                   checkpoint_dir=ckpt, checkpoint_every=2)
    _run(first, "sync", 2)
    first.shutdown()

    resumed = _build("sync", "arena", 2, secure=True)
    resumed.restore(ckpt)
    _run(resumed, "sync", 2)
    got = np.asarray(resumed.global_buffer)
    resumed.shutdown()
    np.testing.assert_array_equal(got, want)


def test_fedbuff_mid_buffer_kill_and_resume(tmp_path):
    """Kill with a partially filled FedBuff buffer; resume must replay it.

    n=3, K=2, one dispatch worker: community update #1 aggregates the first
    two arrivals while the third learner's upload is still in flight and
    the first two have been re-dispatched.  A checkpoint taken there must
    carry that exact intermediate state — the drained in-flight arrival in
    ``pending_buffer`` and the re-dispatched learners in
    ``pending_dispatch`` — and a fresh controller resuming from it must
    finish bit-identically to the uninterrupted run.
    """
    proto, store_mode, n = "buffered_async", "arena", 3

    golden = _build(proto, store_mode, n, max_dispatch_workers=1)
    _run(golden, proto, 4)
    want = np.asarray(golden.global_buffer)
    golden.shutdown()

    ckpt = str(tmp_path / "ckpt")
    first = _build(proto, store_mode, n, checkpoint_dir=ckpt,
                   checkpoint_every=1, max_dispatch_workers=1)
    _run(first, proto, 1)
    first.shutdown()

    resumed = _build(proto, store_mode, n, max_dispatch_workers=1)
    meta = resumed.restore(ckpt)
    # the kill point: agg #1 took (l0, l1); l2's arrival was drained into
    # the buffer and l0, l1 were already re-dispatched
    assert meta["pending_buffer"] == ["l2"]
    assert meta["pending_dispatch"] == ["l0", "l1"]
    _run(resumed, proto, 3)
    got = np.asarray(resumed.global_buffer)
    resumed.shutdown()
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# checkpoint mechanics
# ---------------------------------------------------------------------------


def test_checkpoint_cadence_writes_round_boundary_files(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    ctrl = _build("sync", "arena", 2)
    ctrl.engine.run(rounds=4, checkpoint_every=2, checkpoint_dir=ckpt)
    ctrl.shutdown()
    assert sorted(os.listdir(ckpt)) == ["ckpt_00000002.npz",
                                        "ckpt_00000004.npz"]


def test_restore_state_carries_counters_profiles_and_journal(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    first = _build("sync", "arena", 2, checkpoint_dir=ckpt, checkpoint_every=2)
    first.engine.run(rounds=2)
    saved_cursor = first.journal.cursor
    saved_profile = dict(first._learner_profiles["l0"])
    first.shutdown()

    resumed = _build("sync", "arena", 2)
    meta = resumed.restore(ckpt)
    assert meta["journal_cursor"] <= saved_cursor  # flushed pre-EngineStopped
    assert resumed.journal.cursor == meta["journal_cursor"]
    assert resumed._model_version == 2
    assert resumed.engine.aggregates_fired == 2
    assert resumed._learner_versions == {"l0": 1, "l1": 1}
    prof = resumed._learner_profiles["l0"]
    assert dict(prof) == saved_profile
    assert prof.observations == 2 and prof.decay == first.profile_decay
    # journal records resume the sequence numbering where the save left off
    resumed.engine.run(rounds=1)
    first_new = resumed.journal.records()[0]
    assert first_new["seq"] == meta["journal_cursor"]
    # the checkpoint carried a telemetry snapshot for offline inspection
    assert meta["telemetry"]["channel.upload_messages"] == 4
    resumed.shutdown()


def test_restore_validates_configuration(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    ctrl = _build("sync", "arena", 2)
    ctrl.engine.run(rounds=2)
    ctrl.save_checkpoint(ckpt)
    ctrl.shutdown()

    wrong_proto = _build("async", "arena", 1)
    with pytest.raises(ValueError, match="protocol"):
        wrong_proto.restore(ckpt)
    wrong_proto.shutdown()

    wrong_store = _build("sync", "stack", 2)
    with pytest.raises(ValueError, match="store_mode"):
        wrong_store.restore(ckpt)
    wrong_store.shutdown()

    wrong_secure = _build("sync", "arena", 2, secure=True)
    with pytest.raises(ValueError, match="secure"):
        wrong_secure.restore(ckpt)
    wrong_secure.shutdown()


def test_checkpoint_requires_directory_and_model():
    ctrl = Controller(protocol=SyncProtocol())
    with pytest.raises(ValueError, match="directory"):
        ctrl.save_checkpoint()
    with pytest.raises(ValueError, match="directory"):
        ctrl.restore()
    ctrl.shutdown()

    bare = Controller(protocol=SyncProtocol())
    with pytest.raises(RuntimeError, match="set_initial_model"):
        bare.save_checkpoint("/tmp/never-written")
    bare.shutdown()


def test_save_restore_roundtrip_preserves_arena_bitwise(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    ctrl = _build("sync", "arena", 3)
    ctrl.engine.run(rounds=1)
    buf = np.asarray(ctrl.arena.export_state()["buffer"])
    rows = dict(ctrl.arena._rows)
    ctrl.save_checkpoint(ckpt)
    ctrl.shutdown()

    resumed = _build("sync", "arena", 3)
    resumed.restore(ckpt)
    st = resumed.arena.export_state()
    np.testing.assert_array_equal(np.asarray(st["buffer"]), buf)
    assert st["rows"] == rows
    np.testing.assert_array_equal(
        np.asarray(resumed.global_buffer), np.asarray(ctrl.global_buffer)
    )
    resumed.shutdown()


def test_stack_restore_preserves_records_without_counter_bumps(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    ctrl = _build("sync", "stack", 2)
    ctrl.engine.run(rounds=1)
    inserts = ctrl.store.total_inserts
    ctrl.save_checkpoint(ckpt)
    ctrl.shutdown()
    assert inserts == 2

    resumed = _build("sync", "stack", 2)
    resumed.restore(ckpt)
    recs = resumed.store.export_records()
    assert [r.learner_id for r in recs] == [
        r.learner_id for r in ctrl.store.export_records()
    ]
    assert resumed.store.num_records() == 2
    # restore is not new wire traffic: ingest counters stay untouched
    assert resumed.store.total_inserts == 0
    assert recs[0].metadata["model_version"] == 0
    resumed.shutdown()

@pytest.mark.parametrize("codec", ["raw", "int8"])
def test_int8_arena_kill_and_resume_bit_identical(codec, tmp_path):
    """The quantized-resident rows of the kill-and-resume grid: the int8
    arena checkpoints its scales alongside the values, the resumed fused
    reduce is bit-identical to the uninterrupted run, and the checkpoint
    pins arena_dtype — resuming on an f32 controller is refused."""
    kw = dict(arena_dtype="int8", upload_codec=codec)
    golden = _build("sync", "arena", 3, **kw)
    _run(golden, "sync", 4)
    want = np.asarray(golden.global_buffer)
    golden.shutdown()

    ckpt = str(tmp_path / "ckpt")
    first = _build("sync", "arena", 3, checkpoint_dir=ckpt,
                   checkpoint_every=2, **kw)
    _run(first, "sync", 2)
    saved_q = np.asarray(first.arena.buffer)
    saved_s = np.asarray(first.arena.scales)
    first.shutdown()

    wrong_dtype = _build("sync", "arena", 3, upload_codec=codec)
    with pytest.raises(ValueError, match="arena_dtype"):
        wrong_dtype.restore(ckpt)
    wrong_dtype.shutdown()

    resumed = _build("sync", "arena", 3, **kw)
    meta = resumed.restore(ckpt)
    assert meta["arena_dtype"] == "int8"
    # the resident rows round-trip bit-exactly: int8 values AND f32 scales
    np.testing.assert_array_equal(np.asarray(resumed.arena.buffer), saved_q)
    np.testing.assert_array_equal(np.asarray(resumed.arena.scales), saved_s)
    assert resumed.arena.buffer.dtype == jnp.int8
    _run(resumed, "sync", 2)
    got = np.asarray(resumed.global_buffer)
    resumed.shutdown()
    np.testing.assert_array_equal(got, want)  # bit-identical, not allclose


_TOPK_GRID = [
    ("sync", "direct", 3),
    ("sync", "densify", 3),
    ("async", "direct", 1),
    ("buffered_async", "direct", 3),
]


@pytest.mark.parametrize("proto,sparse_mode,n", _TOPK_GRID,
                         ids=[f"{p}-{m}" for p, m, _ in _TOPK_GRID])
def test_topk_kill_and_resume_bit_identical(proto, sparse_mode, n, tmp_path):
    """The sparse-uplink rows of the kill-and-resume grid: the learner-side
    error-feedback residuals ride the checkpoint bit-identically (dropping
    them would re-send carried mass and diverge round 3), the sparse arena
    checkpoints its indices alongside the values, and the resumed run is
    bit-identical to the uninterrupted one."""
    from repro.core.transport import TopkUploadCodec

    kw = dict(upload_codec=TopkUploadCodec(k=2), sparse_mode=sparse_mode,
              **_extra(proto))
    golden = _build(proto, "arena", n, **kw)
    _run(golden, proto, 4)
    want = np.asarray(golden.global_buffer)
    golden.shutdown()

    ckpt = str(tmp_path / "ckpt")
    first = _build(proto, "arena", n, checkpoint_dir=ckpt,
                   checkpoint_every=2, **kw)
    _run(first, proto, 2)
    res_saved = {lid: l.export_residual()
                 for lid, l in first._learners.items()}
    assert any(r is not None for r in res_saved.values())
    if sparse_mode == "direct":
        saved_idx = np.asarray(first.arena.indices)
        saved_val = np.asarray(first.arena.buffer)
    first.shutdown()

    resumed = _build(proto, "arena", n, **kw)
    meta = resumed.restore(ckpt)
    assert meta["sparse_mode"] == sparse_mode
    # the error-feedback carries round-trip bit-exactly into fresh learners
    for lid, learner in resumed._learners.items():
        saved = res_saved[lid]
        got = learner.export_residual()
        assert (saved is None) == (got is None)
        if saved is not None:
            np.testing.assert_array_equal(got, saved)
    if sparse_mode == "direct":
        np.testing.assert_array_equal(
            np.asarray(resumed.arena.indices), saved_idx)
        np.testing.assert_array_equal(
            np.asarray(resumed.arena.buffer), saved_val)
        assert resumed.arena.indices.dtype == jnp.int32
    _run(resumed, proto, 2)
    got = np.asarray(resumed.global_buffer)
    resumed.shutdown()
    np.testing.assert_array_equal(got, want)  # bit-identical, not allclose


def test_topk_restore_refuses_sparse_mode_mismatch(tmp_path):
    """A direct-mode checkpoint resumed on a densify controller (or vice
    versa) is a different resident layout — refused, not coerced."""
    from repro.core.transport import TopkUploadCodec

    ckpt = str(tmp_path / "ckpt")
    first = _build("sync", "arena", 3, checkpoint_dir=ckpt,
                   checkpoint_every=2, upload_codec=TopkUploadCodec(k=2),
                   sparse_mode="direct")
    _run(first, "sync", 2)
    first.shutdown()

    wrong = _build("sync", "arena", 3, upload_codec=TopkUploadCodec(k=2),
                   sparse_mode="densify")
    with pytest.raises(ValueError, match="sparse_mode"):
        wrong.restore(ckpt)
    wrong.shutdown()
