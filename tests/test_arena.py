"""Device-resident aggregation arena tests.

Covers the acceptance surface of the arena store: numerical parity with the
legacy stack path on every protocol (plain FedAvg, staleness-weighted async,
secure sum), row reuse on re-upload, mask correctness when only a subset of
registered learners reported, and geometric growth past ``n_max``.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ArenaStore, AsyncProtocol, Controller, Driver, FederationEnv, Learner,
    SyncProtocol, aggregation, packing,
)
from repro.core.secure import secure_fedavg, secure_fedavg_arena
from repro.kernels import ops, ref
from repro.optim import sgd


def _fill(arena, n, p, seed=0, weights=None):
    """Write n random updates; returns (buffers, weights)."""
    bufs, ws = [], []
    for i in range(n):
        buf = jax.random.normal(jax.random.key(seed + i), (p,), jnp.float32)
        w = float(weights[i]) if weights is not None else float(10 * (i + 1))
        arena.write(f"l{i}", buf, weight=w, version=float(i))
        bufs.append(buf)
        ws.append(w)
    return bufs, ws


# ---------------------------------------------------------------------------
# masked aggregation rules vs the stack path
# ---------------------------------------------------------------------------


def test_masked_weighted_average_matches_stack_fedavg():
    arena = ArenaStore(num_params=3000, n_max=6, row_align=1024)
    bufs, ws = _fill(arena, 4, 3000)
    got = aggregation.masked_weighted_average(
        arena.buffer, arena.weights, arena.mask
    )[: arena.num_params]
    want = aggregation.fedavg(jnp.stack(bufs), jnp.asarray(ws))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_masked_kernel_matches_ref_and_stack():
    arena = ArenaStore(num_params=5000, n_max=8, row_align=1024)
    bufs, ws = _fill(arena, 5, 5000)
    got = ops.masked_fedavg(arena.buffer, arena.weights, arena.mask)[: arena.num_params]
    want_ref = ref.masked_fedavg_ref(arena.buffer, arena.weights, arena.mask)[:5000]
    want_stack = aggregation.fedavg(jnp.stack(bufs), jnp.asarray(ws))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_stack), rtol=1e-4, atol=1e-5)


def test_masked_staleness_average_matches_stack():
    arena = ArenaStore(num_params=2000, n_max=4, row_align=1024)
    bufs, ws = _fill(arena, 4, 2000)  # versions 0..3
    current = 5.0
    alpha = 0.5
    got = aggregation.masked_staleness_average(
        arena.buffer, arena.weights, arena.versions,
        jnp.float32(current), arena.mask, alpha,
    )[: arena.num_params]
    stal = jnp.asarray([current - v for v in range(4)], jnp.float32)
    w = aggregation.staleness_weights(jnp.asarray(ws), stal, alpha)
    want = aggregation.fedavg(jnp.stack(bufs), w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_secure_arena_bitexact_with_stack_secure():
    arena = ArenaStore(num_params=512, n_max=4, row_align=128)
    bufs, ws = _fill(arena, 3, 512)
    rows = [arena.row_of(f"l{i}") for i in range(3)]
    got = secure_fedavg_arena(
        arena.buffer, rows, ws, num_params=512, base_seed=7
    )
    want = secure_fedavg(bufs, ws, base_seed=7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_masked_kernel_block_divides_arena_rows():
    """The arena hot path must never re-pad the (N, P) arena: the default
    block size divides any lane-aligned row width within the VMEM cap."""
    from repro.kernels.fedavg import DEFAULT_BLOCK_P, choose_block_p, choose_block_p_dividing

    for n in (2, 8, 64, 200):
        cap = choose_block_p(n)
        for p in (1024, 5120, 1 << 20, 1024 * 977, 1024 * 3 * 7 * 11):
            bp = choose_block_p_dividing(p, n)
            assert p % bp == 0, (n, p, bp)
            assert bp <= cap, (n, p, bp)  # working set stays within VMEM
    # non-lane-aligned ad-hoc P falls back to the padding path
    assert choose_block_p_dividing(5000, 4) == choose_block_p(4)
    assert DEFAULT_BLOCK_P % 1024 == 0


def test_choose_block_p_for_shard_divides_shard_width():
    """The sharded-arena block (used by ops.masked_fedavg_sharded) must
    divide the per-device shard width, not the global row."""
    from repro.kernels.fedavg import (
        choose_block_p, choose_block_p_dividing, choose_block_p_for_shard,
    )

    for n in (2, 8, 64):
        for shards in (1, 2, 8):
            for p in (1024 * shards, 8192 * shards, (1 << 20)):
                if p % shards:
                    continue
                bp = choose_block_p_for_shard(p, n, shards)
                assert (p // shards) % bp == 0, (n, shards, p, bp)
                # equivalent to sizing directly from the local shard width
                assert bp == choose_block_p_dividing(p // shards, n)
    # non-divisible global width falls back to the padding path
    assert choose_block_p_for_shard(5000, 4, 8) == choose_block_p(4)


def test_masked_average_ignores_poisoned_invalid_row():
    """A dead row full of NaN must not leak into the aggregate."""
    arena = ArenaStore(num_params=100, n_max=4, row_align=128)
    _fill(arena, 3, 100)
    arena.write("poison", jnp.full((100,), jnp.nan), weight=100.0)
    arena.invalidate("poison")
    out = aggregation.masked_weighted_average(
        arena.buffer, arena.weights, arena.mask
    )[:100]
    assert np.isfinite(np.asarray(out)).all()
    out_k = ops.masked_fedavg(arena.buffer, arena.weights, arena.mask)[:100]
    assert np.isfinite(np.asarray(out_k)).all()


# ---------------------------------------------------------------------------
# store mechanics: row reuse, subset masks, growth
# ---------------------------------------------------------------------------


def test_row_reuse_after_reupload():
    arena = ArenaStore(num_params=256, n_max=4, row_align=128)
    r0 = arena.write("a", jnp.zeros((256,)), weight=1.0)
    r1 = arena.write("b", jnp.ones((256,)), weight=1.0)
    # re-upload: same row, new contents, no growth
    r0b = arena.write("a", jnp.full((256,), 7.0), weight=3.0, version=2.0)
    assert r0b == r0 and r0 != r1
    assert arena.n_max == 4 and arena.grow_events == 0
    assert arena.total_writes == 3
    row = np.asarray(arena.row_view("a"))
    np.testing.assert_array_equal(row, np.full((256,), 7.0, np.float32))
    assert arena.weight_of("a") == 3.0
    assert float(arena.versions[r0]) == 2.0


def test_round_mask_subset_of_registered():
    """Only the cohort that actually reported contributes to the round."""
    arena = ArenaStore(num_params=128, n_max=8, row_align=128)
    bufs, ws = _fill(arena, 5, 128)
    cohort = ["l0", "l2", "l4", "never-uploaded"]
    mask = np.asarray(arena.round_mask(cohort))
    assert mask.sum() == 3
    got = aggregation.masked_weighted_average(
        arena.buffer, arena.weights, jnp.asarray(mask)
    )[:128]
    want = aggregation.fedavg(
        jnp.stack([bufs[0], bufs[2], bufs[4]]),
        jnp.asarray([ws[0], ws[2], ws[4]]),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_arena_grows_past_n_max():
    arena = ArenaStore(num_params=64, n_max=2, row_align=64)
    bufs, ws = _fill(arena, 7, 64)
    assert arena.n_max >= 7
    assert arena.grow_events >= 2  # 2 -> 4 -> 8
    assert len(arena) == 7
    # all seven rows survive the copies intact
    got = aggregation.masked_weighted_average(
        arena.buffer, arena.weights, arena.mask
    )[:64]
    want = aggregation.fedavg(jnp.stack(bufs), jnp.asarray(ws))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_arena_rejects_wrong_size_and_empty_mask_falls_back():
    arena = ArenaStore(num_params=128, n_max=2, row_align=128)
    with pytest.raises(ValueError):
        arena.write("a", jnp.zeros((64,)), weight=1.0)
    # nothing written: mask all-zero -> masked average returns zeros
    out = aggregation.masked_weighted_average(
        arena.buffer, arena.weights, arena.mask
    )
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_n_max_exactly_at_capacity_never_grows():
    """Filling every row of an exactly-sized arena must not trigger growth;
    the first learner past capacity must."""
    arena = ArenaStore(num_params=64, n_max=4, row_align=64)
    bufs, ws = _fill(arena, 4, 64)
    assert arena.n_max == 4 and arena.grow_events == 0 and len(arena) == 4
    got = aggregation.masked_weighted_average(
        arena.buffer, arena.weights, arena.mask
    )[:64]
    want = aggregation.fedavg(jnp.stack(bufs), jnp.asarray(ws))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
    arena.write("l4", jnp.ones((64,)), weight=1.0)  # one past capacity
    assert arena.grow_events == 1 and arena.n_max == 8


def test_learner_joins_after_growth():
    """A learner registering after a growth event gets a fresh row in the
    grown buffer; pre-growth rows keep their identity and contents."""
    arena = ArenaStore(num_params=64, n_max=2, row_align=64)
    bufs, ws = _fill(arena, 3, 64)  # third write grows 2 -> 4
    assert arena.grow_events == 1
    pre_rows = {f"l{i}": arena.row_of(f"l{i}") for i in range(3)}

    late = jnp.full((64,), 9.0)
    row = arena.write("late-joiner", late, weight=5.0)
    assert row == 3  # next free row of the grown arena
    assert {f"l{i}": arena.row_of(f"l{i}") for i in range(3)} == pre_rows
    np.testing.assert_array_equal(
        np.asarray(arena.row_view("late-joiner")), np.asarray(late)
    )
    # re-upload of a pre-growth learner still lands in its original row
    arena.write("l0", jnp.zeros((64,)), weight=1.0)
    assert arena.row_of("l0") == pre_rows["l0"]

    got = aggregation.masked_weighted_average(
        arena.buffer, arena.weights, arena.mask
    )[:64]
    want = aggregation.fedavg(
        jnp.stack([jnp.zeros((64,)), bufs[1], bufs[2], late]),
        jnp.asarray([1.0, ws[1], ws[2], 5.0]),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize(
    "env_kwargs,expected",
    [
        ({}, "arena"),
        ({"lineage_length": 2}, "stack"),
        ({"store_capacity_bytes": 1 << 20}, "stack"),
        ({"lineage_length": 3, "store_capacity_bytes": 1 << 20}, "stack"),
        ({"store_mode": "arena"}, "arena"),
        ({"store_mode": "stack"}, "stack"),
    ],
)
def test_driver_auto_picks_store_mode(env_kwargs, expected):
    """The Driver auto-pick documented in README/docs/ARENA.md: lineage or
    byte-capacity eviction forces the legacy hash-map store, everything else
    gets the arena."""
    driver = Driver(FederationEnv(**env_kwargs))
    try:
        assert driver.controller.store_mode == expected
    finally:
        driver.controller.shutdown()


def test_driver_rejects_sharding_an_explicit_stack_store():
    """arena_shards contradicts an explicitly requested stack store (the
    auto-pick fallback ignores the knob; an explicit ask must raise)."""
    with pytest.raises(ValueError):
        Driver(FederationEnv(store_mode="stack", arena_shards=2))
    # auto-pick falling back to stack drops the knob silently (documented)
    driver = Driver(FederationEnv(lineage_length=2, arena_shards=2))
    try:
        assert driver.controller.store_mode == "stack"
        assert driver.controller.arena_mesh is None
    finally:
        driver.controller.shutdown()


def test_concurrent_writes_are_serialized():
    arena = ArenaStore(num_params=1024, n_max=4, row_align=1024)
    errs = []

    def upload(i):
        try:
            for _ in range(10):
                arena.write(f"l{i}", jnp.full((1024,), float(i)), weight=1.0)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=upload, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert arena.total_writes == 80 and len(arena) == 8
    for i in range(8):
        np.testing.assert_array_equal(
            np.asarray(arena.row_view(f"l{i}")), np.full((1024,), float(i), np.float32)
        )


# ---------------------------------------------------------------------------
# controller-level parity: arena vs stack on all protocols
# ---------------------------------------------------------------------------


def _make_learner(i):
    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(64, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)

    def data_fn(bs):
        j = rng.integers(0, 64, size=bs)
        return X[j], y[j]

    return Learner(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        data_fn, lambda: (X, y), sgd(0.05), 64,
    )


def _run_sync(store_mode, secure=False, rounds=2):
    ctrl = Controller(
        protocol=SyncProtocol(local_steps=2, batch_size=16),
        secure=secure, store_mode=store_mode,
    )
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(3):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=rounds)
    out = np.asarray(ctrl.global_params["w"])
    ctrl.shutdown()
    return out, ctrl


@pytest.mark.parametrize("secure", [False, True])
def test_controller_sync_parity_arena_vs_stack(secure):
    arena_out, actrl = _run_sync("arena", secure=secure)
    stack_out, _ = _run_sync("stack", secure=secure)
    tol = 1e-3 if secure else 1e-5  # secure: fixed-point quantization
    np.testing.assert_allclose(arena_out, stack_out, atol=tol)
    assert actrl.arena is not None and actrl.arena.total_writes >= 6
    assert actrl.store.total_inserts == 0  # arena mode bypasses the hash map


def test_controller_async_staleness_arena_matches_manual():
    """One deterministic arrival: arena async community update == hand-built
    staleness-weighted stack aggregation over the same state."""
    ctrl = Controller(
        protocol=AsyncProtocol(local_steps=1, batch_size=8), store_mode="arena"
    )
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(2):
        ctrl.register_learner(_make_learner(i))
    hist = ctrl.engine.run(total_updates=4)
    out = np.asarray(ctrl.global_params["w"])
    ctrl.shutdown()
    assert len(hist) >= 4
    assert ctrl._model_version >= 4
    assert np.isfinite(out).all()
    # every arrival wrote in place; no stack was ever built
    assert ctrl.arena.total_writes >= 4
    assert ctrl.store.total_inserts == 0


def test_controller_arena_round_uses_padded_rows():
    """P=4 model pads to one 1024-lane row; aggregation slices back to P."""
    _, ctrl = _run_sync("arena", rounds=1)
    assert ctrl.arena.num_params == 4
    assert ctrl.arena.padded_params == 1024
    assert ctrl.global_buffer.shape == (4,)


# ---------------------------------------------------------------------------
# quantized-resident arena (arena_dtype="int8")
# ---------------------------------------------------------------------------


def test_int8_arena_write_dequant_bound():
    """f32 writes requantize on device; row_view obeys the per-group bound."""
    arena = ArenaStore(num_params=3000, n_max=4, arena_dtype="int8")
    assert arena.buffer.dtype == jnp.int8
    assert arena.scales.shape == (4, arena.padded_params // arena.qgroup)
    rng = np.random.default_rng(0)
    x = rng.normal(size=3000).astype(np.float32) * 3
    arena.write("a", jnp.asarray(x), weight=5.0)
    back = np.asarray(arena.row_view("a"))
    assert back.shape == (3000,)
    pad = (-3000) % arena.qgroup
    xg = np.pad(x, (0, pad)).reshape(-1, arena.qgroup)
    bound = np.abs(xg).max(1, keepdims=True) / 254.0 + 1e-7
    err = np.abs(np.pad(back, (0, pad)).reshape(-1, arena.qgroup) - xg)
    assert (err <= bound).all()


def test_int8_arena_write_quantized_bit_exact():
    """An already-quantized row lands with no re-encoding loss."""
    arena = ArenaStore(num_params=2048, n_max=2, arena_dtype="int8")
    g = arena.qgroup
    rng = np.random.default_rng(1)
    q = rng.integers(-127, 128, size=arena.padded_params, dtype=np.int8)
    s = rng.uniform(0.1, 2.0, size=arena.padded_params // g).astype(np.float32)
    row = arena.write_quantized("a", jnp.asarray(q), jnp.asarray(s), weight=1.0)
    np.testing.assert_array_equal(np.asarray(arena.buffer)[row], q)
    np.testing.assert_array_equal(np.asarray(arena.scales)[row], s)


def test_int8_arena_resident_bytes_shrink():
    """The resident gauge shows the ~4x shrink over an f32 arena."""
    from repro.core.metrics import Telemetry

    t8, t32 = Telemetry(), Telemetry()
    a8 = ArenaStore(num_params=100_000, n_max=8, arena_dtype="int8",
                    telemetry=t8)
    a32 = ArenaStore(num_params=100_000, n_max=8, telemetry=t32)
    b8 = t8.value("store.arena.bytes_resident", 0)
    b32 = t32.value("store.arena.bytes_resident", 0)
    assert b8 == a8.resident_bytes() and b32 == a32.resident_bytes()
    assert b8 >= a8.buffer.nbytes + a8.scales.nbytes
    assert b32 >= a32.buffer.nbytes
    # int8 values + f32 per-group scales = (1 + 4/group) bytes/param vs 4
    assert b32 / b8 > 3.5


def test_int8_arena_grow_preserves_rows_and_scales():
    arena = ArenaStore(num_params=1024, n_max=2, arena_dtype="int8")
    rng = np.random.default_rng(2)
    rows = {}
    for i in range(5):  # forces growth past n_max=2
        x = rng.normal(size=1024).astype(np.float32)
        arena.write(f"l{i}", jnp.asarray(x), weight=1.0)
        rows[f"l{i}"] = x
    assert arena.n_max >= 5
    for lid, x in rows.items():
        back = np.asarray(arena.row_view(lid))
        g = arena.qgroup
        bound = np.abs(x.reshape(-1, g)).max(1, keepdims=True) / 254.0 + 1e-7
        assert (np.abs(back.reshape(-1, g) - x.reshape(-1, g)) <= bound).all()


def test_int8_arena_export_restore_roundtrip():
    arena = ArenaStore(num_params=2048, n_max=3, arena_dtype="int8")
    rng = np.random.default_rng(3)
    for i in range(3):
        arena.write(f"l{i}", jnp.asarray(rng.normal(size=2048), jnp.float32),
                    weight=float(i + 1), version=float(i))
    st = arena.export_state()
    fresh = ArenaStore(num_params=2048, n_max=3, arena_dtype="int8")
    fresh.restore_state(buffer=st["buffer"], weights=st["weights"],
                        versions=st["versions"], valid=st["valid"],
                        rows=st["rows"], scales=st["scales"])
    np.testing.assert_array_equal(np.asarray(fresh.buffer),
                                  np.asarray(arena.buffer))
    np.testing.assert_array_equal(np.asarray(fresh.scales),
                                  np.asarray(arena.scales))
    out_a = ops.masked_fedavg_q8(arena.buffer, arena.scales, arena.weights,
                                 arena.mask, group=arena.qgroup)
    out_f = ops.masked_fedavg_q8(fresh.buffer, fresh.scales, fresh.weights,
                                 fresh.mask, group=fresh.qgroup)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_f))


def test_int8_arena_restore_requires_scales():
    arena = ArenaStore(num_params=1024, n_max=2, arena_dtype="int8")
    st = arena.export_state()
    fresh = ArenaStore(num_params=1024, n_max=2, arena_dtype="int8")
    with pytest.raises(ValueError, match="scales"):
        fresh.restore_state(buffer=st["buffer"], weights=st["weights"],
                            versions=st["versions"], valid=st["valid"],
                            rows=st["rows"])


def test_write_quantized_rejects_f32_arena_and_bad_shapes():
    f32 = ArenaStore(num_params=1024, n_max=2)
    q = jnp.zeros((f32.padded_params,), jnp.int8)
    s = jnp.ones((f32.padded_params // 256,), jnp.float32)
    with pytest.raises(ValueError, match="int8"):
        f32.write_quantized("a", q, s, weight=1.0)
    a8 = ArenaStore(num_params=1024, n_max=2, arena_dtype="int8")
    with pytest.raises(ValueError, match="int8"):
        a8.write_quantized("a", q.astype(jnp.float32), s, weight=1.0)
    with pytest.raises(ValueError, match="scales"):
        a8.write_quantized("a", q, s[:-1], weight=1.0)


def _run_sync_dtype(arena_dtype, codec="int8", rounds=2):
    from repro.core import Channel

    ctrl = Controller(
        protocol=SyncProtocol(local_steps=2, batch_size=16),
        store_mode="arena", arena_dtype=arena_dtype,
        channel=Channel(upload_codec=codec),
    )
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(3):
        ctrl.register_learner(_make_learner(i))
    ctrl.engine.run(rounds=rounds)
    out = np.asarray(ctrl.global_params["w"])
    ctrl.shutdown()
    return out, ctrl


def test_controller_int8_arena_matches_f32_arena_with_int8_codec():
    """Same quantized wire payloads -> the direct landing and the f32
    dequant-then-store arena agree to float-accumulation tolerance (arena
    row order follows arrival order, so engine-driven runs may reduce in a
    different order; the bit-exact proof with pinned ingest order lives in
    test_conformance.test_int8_arena_direct_landing_bitexact...)."""
    out8, ctrl8 = _run_sync_dtype("int8", codec="int8")
    out32, _ = _run_sync_dtype("f32", codec="int8")
    np.testing.assert_allclose(out8, out32, rtol=1e-5, atol=1e-6)
    assert ctrl8.telemetry.value("engine.uploads.quantized_direct", 0) >= 6
    assert ctrl8.telemetry.value("controller.aggregations.fused_q8", 0) >= 2


def test_controller_int8_arena_raw_codec_requantizes():
    """Raw f32 uploads into an int8 arena: fallback path requantizes on
    write (no direct landings) and stays within quantization error."""
    out8, ctrl8 = _run_sync_dtype("int8", codec="raw")
    out32, _ = _run_sync_dtype("f32", codec="raw")
    assert ctrl8.telemetry.value("engine.uploads.quantized_direct", 0) == 0
    assert ctrl8.telemetry.value("controller.aggregations.fused_q8", 0) >= 2
    np.testing.assert_allclose(out8, out32, atol=0.05)


@pytest.mark.parametrize("kwargs,frag", [
    (dict(store_mode="stack"), "arena"),
    (dict(store_mode="arena", secure=True), "secure"),
    (dict(store_mode="arena", aggregation_rule="median"), "f32-only"),
    (dict(store_mode="arena", aggregation_rule="trimmed_mean"), "f32-only"),
])
def test_controller_rejects_unsupported_int8_combinations(kwargs, frag):
    with pytest.raises(ValueError, match=frag):
        Controller(protocol=SyncProtocol(local_steps=1, batch_size=8),
                   arena_dtype="int8", **kwargs)


def test_config_rejects_unsupported_int8_combinations():
    from repro.core.config import FederationConfig

    with pytest.raises(ValueError, match="arena_dtype"):
        FederationConfig(arena_dtype="fp16")
    with pytest.raises(ValueError, match="arena"):
        FederationConfig(arena_dtype="int8", store_mode="stack")
    with pytest.raises(ValueError, match="fedavg"):
        FederationConfig(arena_dtype="int8", aggregation_rule="median")
