"""Controller / scheduler / store / selection / driver behaviour tests."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsyncProtocol, Channel, Controller, Driver, FederationEnv, Learner,
    ModelRecord, ModelStore, SelectionPolicy, SemiSyncProtocol, SyncProtocol,
    TerminationCriteria, select_learners,
)
from repro.optim import sgd


# ---------------------------------------------------------------------------
# model store
# ---------------------------------------------------------------------------


def _rec(lid, rid, nbytes=64):
    return ModelRecord(
        learner_id=lid, round_id=rid,
        buffer=np.zeros(nbytes // 4, np.float32), num_examples=10,
    )


def test_store_lineage_bounded():
    store = ModelStore(lineage_length=2)
    for r in range(5):
        store.insert(_rec("a", r))
    lin = store.lineage("a")
    assert [x.round_id for x in lin] == [3, 4]
    assert store.latest("a").round_id == 4


def test_store_eviction_never_drops_latest():
    store = ModelStore(lineage_length=3, capacity_bytes=400)
    for lid in ("a", "b"):
        for r in range(3):
            store.insert(_rec(lid, r, nbytes=100))
    # capacity forces eviction of old records but each learner keeps latest
    assert "a" in store and "b" in store
    assert store.latest("a").round_id == 2
    assert store.latest("b").round_id == 2
    assert store.resident_bytes() <= 400


def test_store_select_latest_subset():
    store = ModelStore()
    for lid in ("a", "b", "c"):
        store.insert(_rec(lid, 0))
    recs = store.select_latest(["a", "c", "missing"])
    assert [r.learner_id for r in recs] == ["a", "c"]


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


def test_selection_all():
    ids = [f"l{i}" for i in range(10)]
    assert select_learners(SelectionPolicy("all"), ids, 0) == ids


def test_selection_random_deterministic_per_round():
    ids = [f"l{i}" for i in range(10)]
    pol = SelectionPolicy("random", fraction=0.5, seed=1)
    a = select_learners(pol, ids, 3)
    b = select_learners(pol, ids, 3)
    c = select_learners(pol, ids, 4)
    assert a == b and len(a) == 5
    assert a != c  # new round, new cohort (w.h.p.)


def test_selection_stratified_prefers_large():
    ids = ["small", "big"]
    n_ex = {"small": 1, "big": 10_000}
    pol = SelectionPolicy("stratified", fraction=0.5, seed=0)
    picks = [select_learners(pol, ids, r, n_ex)[0] for r in range(50)]
    assert picks.count("big") > 40


# ---------------------------------------------------------------------------
# protocols
# ---------------------------------------------------------------------------


def test_semi_sync_adapts_steps_to_speed():
    proto = SemiSyncProtocol(hyperperiod_s=1.0, default_steps=2)
    fast = proto.make_task(0, {"seconds_per_step": 0.01})
    slow = proto.make_task(0, {"seconds_per_step": 0.5})
    new = proto.make_task(0, {})
    assert fast.local_steps == 100
    assert slow.local_steps == 2
    assert new.local_steps == 2  # no profile yet -> default


def _make_learner(i, delay=0.0):
    W = jnp.ones((4, 1))

    def loss_fn(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2)

    rng = np.random.default_rng(i)
    X = rng.normal(size=(64, 4)).astype(np.float32)
    y = X @ np.ones((4, 1), np.float32)

    def data_fn(bs):
        if delay:
            time.sleep(delay)
        j = rng.integers(0, 64, size=bs)
        return X[j], y[j]

    return Learner(
        f"l{i}", loss_fn, lambda p, b: {"eval_loss": loss_fn(p, b)},
        data_fn, lambda: (X, y), sgd(0.05), 64,
    )


def test_sync_round_reports_all_six_timings():
    ctrl = Controller(protocol=SyncProtocol(local_steps=2, batch_size=16))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(3):
        ctrl.register_learner(_make_learner(i))
    t = ctrl.engine.run(rounds=1)[0]
    ctrl.shutdown()
    row = t.as_row()
    for key in ("train_dispatch_s", "train_round_s", "aggregation_s",
                "eval_dispatch_s", "eval_round_s", "federation_round_s"):
        assert row[key] > 0, key
    # dispatch must be cheaper than the full round (async fire-and-forget)
    assert row["train_dispatch_s"] < row["train_round_s"]
    assert "eval_loss" in t.metrics


def test_async_protocol_produces_updates_and_uses_staleness():
    ctrl = Controller(protocol=AsyncProtocol(local_steps=1, batch_size=8))
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(3):
        ctrl.register_learner(_make_learner(i, delay=0.002 * i))
    hist = ctrl.engine.run(total_updates=9)
    ctrl.shutdown()
    assert len(hist) >= 9
    assert ctrl._model_version >= 9


def test_secure_controller_round_matches_plain():
    def build(secure):
        ctrl = Controller(
            protocol=SyncProtocol(local_steps=3, batch_size=16), secure=secure
        )
        ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
        for i in range(3):
            ctrl.register_learner(_make_learner(i))
        ctrl.engine.run(rounds=1)
        out = np.asarray(ctrl.global_params["w"])
        ctrl.shutdown()
        return out

    plain, sec = build(False), build(True)
    np.testing.assert_allclose(plain, sec, atol=1e-3)


def test_driver_lifecycle_and_termination():
    env = FederationEnv(
        protocol="sync", local_steps=2, batch_size=16,
        termination=TerminationCriteria(max_rounds=3),
    )
    drv = Driver(env)
    learners = [_make_learner(i) for i in range(2)]
    drv.initialize({"w": jnp.zeros((4, 1))}, learners)
    hist = drv.run()
    assert len(hist) == 3
    assert all(not l.alive for l in learners)  # shutdown reached learners


def test_driver_rejects_dead_learner_at_init():
    env = FederationEnv(termination=TerminationCriteria(max_rounds=1))
    drv = Driver(env)
    dead = _make_learner(0)
    dead.shutdown()
    with pytest.raises(RuntimeError):
        drv.initialize({"w": jnp.zeros((4, 1))}, [dead])


def test_channel_counts_bytes_and_virtual_time():
    ch = Channel(bandwidth_gbps=1.0, latency_ms=1.0)
    params = {"w": jnp.ones((1000,), jnp.float32)}
    env = ch.send(params)
    back = ch.recv(env)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.ones(1000, np.float32))
    assert ch.stats.bytes_moved == 4000
    assert ch.stats.messages == 1
    expected_wire = 1e-3 + 4000 * 8 / 1e9
    assert abs(ch.stats.virtual_wire_s - expected_wire) < 1e-9


# ---------------------------------------------------------------------------
# admission screen: one host sync per upload (fused-norm regression)
# ---------------------------------------------------------------------------


class _CountingScalar:
    """A device-scalar proxy that counts host readbacks (`float()` calls)."""

    def __init__(self, value, counter):
        self._value, self._counter = value, counter

    def __float__(self):
        self._counter["readbacks"] += 1
        return float(self._value)


@pytest.mark.parametrize("codec,arena_dtype", [
    ("raw", "f32"), ("int8", "f32"), ("int8", "int8"),
])
def test_admission_screen_single_host_sync_per_upload(codec, arena_dtype):
    """The screen reads back ONE already-fused scalar per upload.

    Regression for the per-upload blocking device sync: the old screen
    launched a fresh full-row `jnp.linalg.norm` and blocked on it for every
    arrival.  Now the norm rides along inside the jitted upload decode
    (`recv_upload(..., with_norm=True)` / `recv_upload_quantized`), so the
    only host sync is one `float()` on a scalar the decode already
    scheduled — asserted here by (a) counting scalar readbacks through a
    proxy and (b) poisoning the separate-norm fallback so any extra norm
    launch fails the test.
    """
    from repro.core import transport
    from repro.core.learner import LocalUpdate

    ctrl = Controller(
        protocol=SyncProtocol(local_steps=1, batch_size=8),
        channel=Channel(upload_codec=codec),
        store_mode="arena", arena_dtype=arena_dtype,
        admission_control=True,
    )
    ctrl.set_initial_model({"w": jnp.zeros((4, 1))})
    for i in range(2):
        ctrl.register_learner(_make_learner(i))
    counter = {"readbacks": 0}
    real_recv = ctrl.channel.recv_upload
    real_recv_q = ctrl.channel.recv_upload_quantized

    def spy_recv(envelope, with_norm=False):
        assert with_norm, "admission ingest must fuse the norm into decode"
        row, norm = real_recv(envelope, with_norm=True)
        return row, _CountingScalar(norm, counter)

    def spy_recv_q(envelope, out_params):
        q, s, norm = real_recv_q(envelope, out_params)
        return q, s, _CountingScalar(norm, counter)

    ctrl.channel.recv_upload = spy_recv
    ctrl.channel.recv_upload_quantized = spy_recv_q
    poison = transport._row_norm
    transport._row_norm = lambda *_: (_ for _ in ()).throw(
        AssertionError("separate per-upload norm launch")
    )
    try:
        rng = np.random.default_rng(0)
        P = ctrl.arena.padded_params
        for k in range(4):
            row = jnp.asarray(rng.normal(size=P), jnp.float32)
            env = ctrl.channel.upload(
                row, metadata={"learner_id": f"l{k % 2}", "round_id": 0})
            before = counter["readbacks"]
            ctrl.ingest(LocalUpdate(
                learner_id=f"l{k % 2}", round_id=0, params=None, buffer=None,
                num_examples=10, metrics={}, seconds_per_step=0.01,
                upload=env,
            ))
            assert counter["readbacks"] - before == 1, \
                "expected exactly one scalar readback per upload"
    finally:
        transport._row_norm = poison
        ctrl.shutdown()
    if arena_dtype == "int8" and codec == "int8":
        assert ctrl.telemetry.value("engine.uploads.quantized_direct", 0) == 4
