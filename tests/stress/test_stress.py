"""Stress-harness fast lane: small fleets, churn on, determinism pinned.

``pytest -m stress_smoke`` runs these in seconds; the 1000-learner sweep
is the nightly ``bench_round.py --stress`` arm.  The determinism test is
the seeding contract's acceptance pin: two runs with the same fault seed
emit **byte-identical** journal JSONL.
"""

import dataclasses

import pytest

from repro.core import FaultSpec

from stress.harness import STRESS_PROTOCOLS, run_stress

CHAOS = FaultSpec(
    seed=7,
    dropout_rate=0.1,
    rejoin_rate=0.5,
    upload_loss_rate=0.05,
    upload_dup_rate=0.05,
    straggler_rate=0.2,
    bandwidth_min_gbps=0.05,
    bandwidth_max_gbps=10.0,
)


@pytest.mark.stress_smoke
@pytest.mark.parametrize("protocol", STRESS_PROTOCOLS)
def test_smoke_fleet_survives_churn(protocol, tmp_path):
    row = run_stress(
        protocol=protocol, learners=48, rounds=3, spec=CHAOS,
        journal_path=str(tmp_path / "journal.jsonl"),
    )
    assert row["protocol"] == protocol
    assert row["uploads"] > 0 and row["uploads_per_s"] > 0
    assert row["aggregates"] > 0 and row["rounds_per_s"] > 0
    assert row["staleness_hist"], "upload records must carry staleness"
    faults = row["faults"]
    assert faults["dropouts"] > 0, "churn was configured on"
    assert faults["uploads_lost"] + faults["uploads_duplicated"] > 0
    assert len(row["journal_sha256"]) == 64


@pytest.mark.stress_smoke
@pytest.mark.parametrize("protocol", ["sync", "async", "buffered_async"])
def test_same_fault_seed_is_byte_identical(protocol, tmp_path):
    a_path = str(tmp_path / "a.jsonl")
    b_path = str(tmp_path / "b.jsonl")
    a = run_stress(protocol=protocol, learners=24, rounds=3, spec=CHAOS,
                   journal_path=a_path)
    b = run_stress(protocol=protocol, learners=24, rounds=3, spec=CHAOS,
                   journal_path=b_path)
    with open(a_path, "rb") as fa, open(b_path, "rb") as fb:
        assert fa.read() == fb.read()
    assert a["journal_sha256"] == b["journal_sha256"]
    assert a["uploads"] == b["uploads"]
    assert a["staleness_hist"] == b["staleness_hist"]


@pytest.mark.stress_smoke
def test_different_fault_seeds_diverge(tmp_path):
    a = run_stress(protocol="sync", learners=24, rounds=3, spec=CHAOS,
                   journal_path=str(tmp_path / "a.jsonl"))
    other = dataclasses.replace(CHAOS, seed=8)
    b = run_stress(protocol="sync", learners=24, rounds=3, spec=other,
                   journal_path=str(tmp_path / "b.jsonl"))
    assert a["journal_sha256"] != b["journal_sha256"]


@pytest.mark.stress_smoke
def test_faultless_spec_runs_clean(tmp_path):
    row = run_stress(protocol="sync", learners=16, rounds=2,
                     spec=FaultSpec(seed=0),
                     journal_path=str(tmp_path / "journal.jsonl"))
    assert row["uploads"] == 32  # every learner, every round, no faults
    assert all(v == 0 for v in row["faults"].values())
    assert all(v == 0 for v in row["adversarial"].values())
    assert all(v == 0 for v in row["admission"].values())


# -- byzantine arms ----------------------------------------------------------

ADVERSARIAL = FaultSpec(
    seed=7, adversarial_fraction=0.15,
    adversarial_fates=("scale", "sign_flip"),
)


@pytest.mark.stress_smoke
def test_adversarial_run_is_byte_identical(tmp_path):
    """The byzantine arm honours the same --fault-seed determinism contract:
    corruption draws, admission clips and quarantine entries are all
    decision-keyed, so two runs emit byte-identical journal JSONL."""
    a_path, b_path = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    kw = dict(protocol="sync", learners=24, rounds=4, spec=ADVERSARIAL,
              value_mode="target", aggregation_rule="trimmed_mean", trim_k=6)
    a = run_stress(journal_path=a_path, **kw)
    b = run_stress(journal_path=b_path, **kw)
    with open(a_path, "rb") as fa, open(b_path, "rb") as fb:
        assert fa.read() == fb.read()
    assert a["journal_sha256"] == b["journal_sha256"]
    assert a["adversarial"] == b["adversarial"]
    assert a["admission"] == b["admission"]
    assert a["final_eval_loss"] == b["final_eval_loss"]


@pytest.mark.stress_smoke
def test_adversarial_counters_clipping_and_quarantine(tmp_path):
    """Scale blow-ups get clipped, repeat offenders get quarantined, and the
    per-fate adversarial counters land in the summary row."""
    row = run_stress(protocol="sync", learners=64, rounds=5, spec=ADVERSARIAL,
                     value_mode="target", aggregation_rule="trimmed_mean",
                     trim_k=16, journal_path=str(tmp_path / "journal.jsonl"))
    assert row["adversarial"]["scale"] > 0
    assert row["adversarial"]["sign_flip"] > 0
    assert row["adversarial"]["nan"] == row["adversarial"]["garbage"] == 0
    # every scale fate hit the clip screen (sign flips are norm-invariant)
    assert row["admission"]["clipped"] == row["adversarial"]["scale"]
    assert row["admission"]["quarantine_entered"] > 0
    # quarantine shrinks later cohorts: fewer uploads than learners * rounds
    assert row["uploads"] < 64 * 5


@pytest.mark.stress_smoke
def test_nan_fates_reconcile_with_rejections(tmp_path):
    """No NaN ever reaches the global model: every injected nan fate is
    rejected at admission (exact counter reconciliation) and the journal
    replay names each excluded row."""
    from repro.core import EventJournal

    spec = FaultSpec(seed=11, adversarial_fraction=0.2,
                     adversarial_fates=("nan",))
    path = str(tmp_path / "journal.jsonl")
    row = run_stress(protocol="sync", learners=32, rounds=3, spec=spec,
                     value_mode="target", aggregation_rule="median",
                     journal_path=path)
    n_nan = row["adversarial"]["nan"]
    assert n_nan > 0
    assert row["admission"]["rejected_nonfinite"] == n_nan
    # the surviving global model is finite and still on target
    assert row["final_eval_loss"] < 1e-9
    # replay() surfaces why each row was excluded
    records = EventJournal.read_jsonl(path)
    rejected_recs = [r for r in records if r.get("kind") == "upload_rejected"]
    assert len(rejected_recs) == n_nan
    assert all(r["reason"] == "nonfinite" for r in rejected_recs)
    summaries = EventJournal().replay(records)
    replayed = [rej for s in summaries for rej in s.rejected]
    assert len(replayed) == n_nan
    assert all(r["reason"] == "nonfinite" for r in replayed)


@pytest.mark.slow
def test_thousand_learner_byzantine_demo():
    """The headline: at N=1000 with ~15% scale/sign-flip adversaries,
    trimmed_mean tracks the faultless baseline while FedAvg degrades."""
    kw = dict(protocol="sync", learners=1000, rounds=3, value_mode="target")
    base = run_stress(aggregation_rule="fedavg", **kw)
    fed = run_stress(spec=ADVERSARIAL, aggregation_rule="fedavg", **kw)
    tm = run_stress(spec=ADVERSARIAL, aggregation_rule="trimmed_mean",
                    trim_k=250, **kw)
    # the faultless baseline sits at f32-accumulation epsilon
    assert base["final_eval_loss"] < 1e-9
    # trimmed_mean stays within 10% of the baseline (absolute floor guards
    # the 0-vs-0 comparison against eps-level flakiness)
    assert tm["final_eval_loss"] <= max(1.1 * base["final_eval_loss"], 1e-9)
    # FedAvg degrades >= 2x (in practice ~10^8 x: sign flips are invisible
    # to the norm screen and pull the mean off target)
    assert fed["final_eval_loss"] >= 2 * max(base["final_eval_loss"], 1e-12)
    assert fed["final_eval_loss"] > 1e-4
