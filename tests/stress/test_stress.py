"""Stress-harness fast lane: small fleets, churn on, determinism pinned.

``pytest -m stress_smoke`` runs these in seconds; the 1000-learner sweep
is the nightly ``bench_round.py --stress`` arm.  The determinism test is
the seeding contract's acceptance pin: two runs with the same fault seed
emit **byte-identical** journal JSONL.
"""

import dataclasses

import pytest

from repro.core import FaultSpec

from stress.harness import STRESS_PROTOCOLS, run_stress

CHAOS = FaultSpec(
    seed=7,
    dropout_rate=0.1,
    rejoin_rate=0.5,
    upload_loss_rate=0.05,
    upload_dup_rate=0.05,
    straggler_rate=0.2,
    bandwidth_min_gbps=0.05,
    bandwidth_max_gbps=10.0,
)


@pytest.mark.stress_smoke
@pytest.mark.parametrize("protocol", STRESS_PROTOCOLS)
def test_smoke_fleet_survives_churn(protocol, tmp_path):
    row = run_stress(
        protocol=protocol, learners=48, rounds=3, spec=CHAOS,
        journal_path=str(tmp_path / "journal.jsonl"),
    )
    assert row["protocol"] == protocol
    assert row["uploads"] > 0 and row["uploads_per_s"] > 0
    assert row["aggregates"] > 0 and row["rounds_per_s"] > 0
    assert row["staleness_hist"], "upload records must carry staleness"
    faults = row["faults"]
    assert faults["dropouts"] > 0, "churn was configured on"
    assert faults["uploads_lost"] + faults["uploads_duplicated"] > 0
    assert len(row["journal_sha256"]) == 64


@pytest.mark.stress_smoke
@pytest.mark.parametrize("protocol", ["sync", "async", "buffered_async"])
def test_same_fault_seed_is_byte_identical(protocol, tmp_path):
    a_path = str(tmp_path / "a.jsonl")
    b_path = str(tmp_path / "b.jsonl")
    a = run_stress(protocol=protocol, learners=24, rounds=3, spec=CHAOS,
                   journal_path=a_path)
    b = run_stress(protocol=protocol, learners=24, rounds=3, spec=CHAOS,
                   journal_path=b_path)
    with open(a_path, "rb") as fa, open(b_path, "rb") as fb:
        assert fa.read() == fb.read()
    assert a["journal_sha256"] == b["journal_sha256"]
    assert a["uploads"] == b["uploads"]
    assert a["staleness_hist"] == b["staleness_hist"]


@pytest.mark.stress_smoke
def test_different_fault_seeds_diverge(tmp_path):
    a = run_stress(protocol="sync", learners=24, rounds=3, spec=CHAOS,
                   journal_path=str(tmp_path / "a.jsonl"))
    other = dataclasses.replace(CHAOS, seed=8)
    b = run_stress(protocol="sync", learners=24, rounds=3, spec=other,
                   journal_path=str(tmp_path / "b.jsonl"))
    assert a["journal_sha256"] != b["journal_sha256"]


@pytest.mark.stress_smoke
def test_faultless_spec_runs_clean(tmp_path):
    row = run_stress(protocol="sync", learners=16, rounds=2,
                     spec=FaultSpec(seed=0),
                     journal_path=str(tmp_path / "journal.jsonl"))
    assert row["uploads"] == 32  # every learner, every round, no faults
    assert all(v == 0 for v in row["faults"].values())
