"""Scale-out stress harness package (``from stress.harness import ...``)."""
