"""Thousand-learner stress harness: a SimLearner fleet under injected faults.

``run_stress`` drives one real ``RoundEngine.run`` federation — real
controller, real measured transport, real journal — with simulated
learners that never train: ``SimLearner.fit`` fabricates a deterministic
update row and a fault-injected step time instead of running an optimizer,
so a single process pushes 1000+ learners through churn, upload loss /
duplication, stragglers, and per-learner bandwidth caps in seconds.

Determinism contract (``--fault-seed``): every stochastic choice comes
from ``core/faults.FaultInjector`` (seeded per decision), the engine runs
one dispatch worker, and the journal gets a counter clock — so two runs
with the same spec emit **byte-identical** journal JSONL
(``tests/stress/test_stress.py`` pins this; ``docs/STRESS.md`` documents
the knobs and the emitted JSON row).
"""

from __future__ import annotations

import hashlib
import itertools
import math
import time
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ADVERSARIAL_FATES,
    AsyncProtocol,
    BufferedAsyncProtocol,
    Controller,
    DeadlineCohortProtocol,
    EvalReport,
    EventJournal,
    FaultInjector,
    FaultSpec,
    FaultyChannel,
    Learner,
    LocalUpdate,
    ReputationProtocol,
    SemiSyncProtocol,
    SyncProtocol,
    Telemetry,
)

__all__ = ["SimLearner", "run_stress", "target_value", "STRESS_PROTOCOLS"]

# The protocols the nightly --stress arm sweeps.
STRESS_PROTOCOLS = (
    "sync", "semi_sync", "async", "buffered_async", "deadline", "reputation",
)

_FAULT_COUNTERS = (
    "orphaned", "uploads_lost", "uploads_duplicated", "uploads_late",
    "deadline_fires", "dropouts", "rejoins", "stragglers",
)


def target_value(round_id: int) -> float:
    """The per-round consensus value honest ``value_mode="target"`` rows carry.

    Deterministic, bounded away from 0 so a sign-flipped row is a *distinct*
    wrong answer and ``final_eval_loss`` ratios are well-conditioned.
    """
    return 0.25 + ((int(round_id) * 37) % 50) / 100.0


class SimLearner(Learner):
    """A learner that fabricates updates instead of training.

    ``fit`` ignores the received params entirely: it reports a
    fault-injected seconds-per-step (virtual — it never sleeps), builds a
    deterministic update row keyed on ``(learner_id, round_id)``, and
    ships it through the measured channel uplink like a real learner —
    so transport accounting, ingest, aggregation, and the journal all see
    authentic traffic at zero training cost.
    """

    def __init__(self, learner_id: str, injector: FaultInjector,
                 num_examples: int = 16, value_mode: str = "crc"):
        """A simulated learner bound to one fault injector.

        ``value_mode="crc"`` (default) fills each row with a per-(learner,
        round) pseudo-random value — wide norm spread, good for transport
        stress.  ``"target"`` makes every honest row *exactly*
        ``target_value(round_id)``: the faultless global model then equals
        the target bit-for-bit, so byzantine-robustness demos compare
        ``final_eval_loss`` against a deterministic zero baseline instead
        of a flaky noise floor.
        """
        super().__init__(
            learner_id, loss_fn=None, eval_fn=None, data_fn=None,
            eval_data_fn=None, optimizer=None, num_examples=num_examples,
        )
        if value_mode not in ("crc", "target"):
            raise ValueError(f"value_mode must be 'crc' or 'target', "
                             f"got {value_mode!r}")
        self._injector = injector
        self._value_mode = value_mode

    def fit(self, params, task) -> LocalUpdate:
        """Fabricate one deterministic update for this (learner, round)."""
        rid = int(task.round_id)
        sps = self._injector.step_time(self.learner_id, rid)
        if self._value_mode == "target":
            value = target_value(rid)
        else:
            value = (
                zlib.crc32(f"{self.learner_id}:{rid}".encode()) % 100_000
            ) / 100_000.0
        width = self._upload_pad
        row = np.full((width,), np.float32(value), dtype=np.float32)
        upload = self._channel.upload(
            row,
            metadata={"learner_id": self.learner_id, "round_id": rid},
        )
        return LocalUpdate(
            learner_id=self.learner_id,
            round_id=rid,
            params=None,
            num_examples=self.num_examples,
            metrics={"train_loss": value, "local_steps": task.local_steps},
            seconds_per_step=sps,
            upload=upload,
        )

    def evaluate(self, params, round_id: int) -> EvalReport:
        """A constant eval report (evaluation cost is not under test)."""
        return EvalReport(
            learner_id=self.learner_id, round_id=int(round_id),
            metrics={"eval_loss": 0.0}, num_examples=self.num_examples,
        )


def _make_protocol(name: str, learners: int, buffer_k: int | None,
                   deadline_s: float):
    """The policy instance one stress arm runs (deterministic variants)."""
    if name == "sync":
        return SyncProtocol(local_steps=1, batch_size=8)
    if name == "semi_sync":
        return SemiSyncProtocol(hyperperiod_s=0.05, batch_size=8,
                                default_steps=1)
    if name == "async":
        return AsyncProtocol(local_steps=1, batch_size=8)
    if name == "buffered_async":
        # Default K stays strictly below the fleet: upload fates are
        # per-(learner, round), so a buffer that needs *every* learner can
        # never fill once one upload is deterministically lost that round.
        k = buffer_k if buffer_k is not None else max(1, min(16, learners - 1))
        return BufferedAsyncProtocol(buffer_k=k, local_steps=1, batch_size=8)
    if name == "deadline":
        # Wall-clock timers are real time — the one nondeterminism the
        # byte-identity contract cannot absorb — so the stress arm runs
        # the deadline policy on predicted cohorts only.
        return DeadlineCohortProtocol(deadline_s=deadline_s, local_steps=1,
                                      batch_size=8, enforce_wall_clock=False)
    if name == "reputation":
        return ReputationProtocol(fraction=0.5, local_steps=1, batch_size=8)
    raise ValueError(f"unknown stress protocol {name!r}")


def run_stress(
    protocol: str = "sync",
    learners: int = 64,
    rounds: int = 3,
    spec: FaultSpec | None = None,
    journal_path: str | None = None,
    model_params: int = 64,
    buffer_k: int | None = None,
    deadline_s: float = 0.05,
    aggregation_rule: str = "fedavg",
    trim_k: int = 1,
    value_mode: str = "crc",
    admission_control: bool | None = None,
) -> dict:
    """One deterministic stress run; returns the bench JSON row.

    Builds a ``learners``-sized SimLearner fleet on a fault-stamping
    channel, applies per-round churn from ``spec`` between engine runs,
    and drives ``rounds`` federation rounds (round-based policies) or the
    equivalent number of community-update batches (continuous policies).
    The returned row carries uploads/sec, rounds/sec, the staleness
    histogram, every ``engine.faults.*`` counter (including the per-fate
    ``adversarial`` and admission/quarantine blocks), the host-computed
    ``final_eval_loss`` against the ``value_mode="target"`` consensus
    value, and — when ``journal_path`` is given — the journal JSONL's
    sha256.

    ``aggregation_rule``/``trim_k`` select the community reduction
    (byzantine arms run ``"median"``/``"trimmed_mean"``).
    ``admission_control=None`` enables the ingest screen exactly when the
    spec configures adversaries: the crc value mode fabricates legitimate
    rows whose norms swing 1000x between learners, which the clip screen
    would (correctly, but unhelpfully) mangle in faultless runs.
    """
    spec = spec if spec is not None else FaultSpec()
    if admission_control is None:
        admission_control = spec.adversarial_fraction > 0
    if journal_path is not None:
        # The journal sink appends (flight-recorder semantics); a stress
        # row's JSONL must cover exactly this run, so start clean.
        open(journal_path, "w", encoding="utf-8").close()
    telemetry = Telemetry()
    injector = FaultInjector(spec, telemetry=telemetry)
    channel = FaultyChannel(injector, telemetry=telemetry)
    counter = itertools.count()
    journal = EventJournal(
        capacity=1 << 17, sink=journal_path,
        clock=lambda: float(next(counter)),
    )
    proto = _make_protocol(protocol, learners, buffer_k, deadline_s)
    ctrl = Controller(
        protocol=proto, channel=channel, store_mode="arena",
        arena_n_max=learners, max_dispatch_workers=1, journal=journal,
        aggregation_rule=aggregation_rule, trim_k=trim_k,
        admission_control=admission_control,
    )
    ctrl.set_initial_model(
        {"w": jnp.zeros((model_params,), jnp.float32)}
    )
    fleet = {
        f"l{i:04d}": SimLearner(f"l{i:04d}", injector, value_mode=value_mode)
        for i in range(learners)
    }
    for lid, learner in fleet.items():
        cap = injector.bandwidth_cap(lid)
        if cap is not None:
            channel.set_learner_bandwidth(lid, cap)
        ctrl.register_learner(learner)

    continuous = bool(getattr(proto, "continuous", False))
    k = getattr(proto, "buffer_k", 1)
    updates_per_round = max(1, math.ceil(learners / max(1, k)))
    t0 = time.perf_counter()
    for r in range(rounds):
        if r > 0:
            leave, rejoin = injector.churn(r, sorted(ctrl._learners))
            for lid in leave:
                ctrl.deregister_learner(lid)
            for lid in rejoin:
                ctrl.register_learner(fleet[lid])
        if continuous:
            ctrl.engine.run(total_updates=updates_per_round)
        else:
            ctrl.engine.run(rounds=1)
    wall_s = time.perf_counter() - t0
    # Host-side eval: squared distance between the final global model and
    # the last aggregated round's consensus target.  Exactly 0 for a
    # faultless value_mode="target" run (honest rows ARE the target);
    # byzantine arms compare against that zero baseline.
    final_target = target_value(max(int(ctrl.round_id) - 1, 0))
    gbuf = np.asarray(ctrl.global_buffer)[:model_params]
    final_eval_loss = float(np.mean((gbuf - np.float32(final_target)) ** 2))
    ctrl.shutdown()

    staleness_hist: dict[str, int] = {}
    for rec in journal.records():
        if rec.get("kind") == "upload" and "staleness" in rec:
            key = str(int(rec["staleness"]))
            staleness_hist[key] = staleness_hist.get(key, 0) + 1
    uploads = int(telemetry.value("channel.upload_messages"))
    aggregates = int(ctrl.engine.aggregates_fired)
    row = {
        "protocol": protocol,
        "learners": learners,
        "rounds": rounds,
        "fault_seed": spec.seed,
        "aggregation_rule": aggregation_rule,
        "wall_s": wall_s,
        "uploads": uploads,
        "uploads_per_s": uploads / wall_s if wall_s > 0 else 0.0,
        "aggregates": aggregates,
        "rounds_per_s": aggregates / wall_s if wall_s > 0 else 0.0,
        "final_eval_loss": final_eval_loss,
        "staleness_hist": dict(sorted(staleness_hist.items())),
        "faults": {
            name: int(telemetry.value(f"engine.faults.{name}"))
            if name != "orphaned"
            else int(telemetry.value("engine.uploads.orphaned"))
            for name in _FAULT_COUNTERS
        },
        "adversarial": {
            fate: int(telemetry.value(f"engine.faults.adversarial.{fate}"))
            for fate in ADVERSARIAL_FATES
        },
        "admission": {
            "rejected_nonfinite": int(
                telemetry.value("engine.uploads.rejected.nonfinite")
            ),
            "clipped": int(telemetry.value("engine.uploads.clipped")),
            "quarantine_entered": int(
                telemetry.value("engine.quarantine.entered")
            ),
        },
    }
    if journal_path is not None:
        with open(journal_path, "rb") as fh:
            row["journal_sha256"] = hashlib.sha256(fh.read()).hexdigest()
    return row
