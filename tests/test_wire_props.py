"""Property tests for the wire layer, both directions.

Downlink: ``pack_bytes_from_numeric``/``unpack_bytes`` must round-trip any
pytree — random leaf counts, shapes, dtypes and padded buffer widths —
bit-identically to the canonical ``pack_bytes`` of the numeric-decoded tree.

Uplink: the upload codecs must round-trip random flat ``(P,)`` rows — ``raw``
bit-exactly at 4 bytes/param, ``int8`` inside the per-group quantization
bound with the payload size pinned to ``kernels/quantize.wire_layout``.

Runs under real hypothesis when installed, else the deterministic
``tests/hypothesis_compat.py`` mini-engine (so tier-1 still collects bare).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import packing
from repro.core.transport import Channel, Int8UploadCodec
from repro.kernels.quantize import (
    effective_block_rows, scales_padding, wire_layout,
)

_DTYPES = ("float32", "bfloat16", "float16", "int32", "int8")


@st.composite
def _trees(draw):
    """A pytree of 1-4 leaves with random shapes/dtypes, f32-survivable values."""
    n_leaves = draw(st.integers(1, 4))
    tree = {}
    for i in range(n_leaves):
        ndim = draw(st.integers(0, 2))
        shape = tuple(draw(st.integers(1, 7)) for _ in range(ndim))
        dtype = draw(st.sampled_from(_DTYPES))
        size = int(np.prod(shape)) if shape else 1
        # small integers / 4: exactly representable in every listed dtype and
        # in the f32 accumulation buffer, so numeric round-trips are lossless
        vals = [draw(st.integers(-40, 40)) for _ in range(size)]
        arr = (np.asarray(vals, np.float32) / 4.0).reshape(shape)
        tree[f"leaf{i}"] = jnp.asarray(arr).astype(jnp.dtype(dtype))
    return tree


@st.composite
def _pads(draw):
    """A pack_numeric pad_to value (None = unpadded)."""
    return draw(st.sampled_from((None, 8, 128, 1000)))


@given(_trees(), _pads())
@settings(max_examples=25, deadline=None)
def test_pack_bytes_from_numeric_roundtrips_any_tree(tree, pad_to):
    """Numeric-buffer wire bytes == canonical pack_bytes, pad-oblivious."""
    manifest = packing.build_manifest(tree)
    numeric = packing.pack_numeric(tree, pad_to=pad_to)
    want, _ = packing.pack_bytes(
        packing.unpack_numeric(numeric, manifest)
    )
    got = packing.pack_bytes_from_numeric(numeric, manifest)
    assert got.dtype == np.uint8
    assert want.tobytes() == got.tobytes()

    # and the receiver reconstructs every leaf bit-exactly
    out = packing.unpack_bytes(got, manifest)
    for k in tree:
        assert out[k].dtype == tree[k].dtype and out[k].shape == tree[k].shape
        want_leaf = np.asarray(
            packing.unpack_numeric(numeric, manifest)[k]
        )
        assert np.asarray(out[k]).tobytes() == want_leaf.tobytes()


@st.composite
def _rows(draw):
    """A flat f32 row of random length (crossing pad boundaries) and scale."""
    n = draw(st.integers(1, 3000))
    scale = draw(st.floats(0.01, 100.0))
    vals = [draw(st.floats(-1.0, 1.0)) for _ in range(min(n, 16))]
    rng = np.random.default_rng(n)
    row = rng.normal(size=(n,)).astype(np.float32) * np.float32(scale)
    row[: len(vals)] = np.asarray(vals, np.float32) * np.float32(scale)
    return jnp.asarray(row)


@given(_rows())
@settings(max_examples=25, deadline=None)
def test_raw_upload_codec_roundtrips_bit_exact(row):
    """raw: 4 bytes/param on the wire, decode bit-identical to the buffer."""
    ch = Channel(upload_codec="raw")
    env = ch.upload(row)
    assert env.codec == "raw"
    assert env.payload.nbytes == 4 * row.shape[0]
    got = ch.recv_upload(env)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(row))


@given(_rows())
@settings(max_examples=15, deadline=None)
def test_int8_upload_codec_bounded_and_layout_pinned(row):
    """int8: payload size == wire_layout, error inside the per-group bound."""
    codec = Int8UploadCodec(group=128, block_rows=8)  # small tiles: fast CI
    ch = Channel(upload_codec=codec)
    env = ch.upload(row)
    n = int(row.shape[0])
    n_pad, n_scales, payload_bytes = wire_layout(n, 128, 8)
    assert env.codec == "int8"
    assert env.payload.nbytes == payload_bytes
    got = np.asarray(ch.recv_upload(env))
    assert got.shape == (n,) and got.dtype == np.float32
    amax = float(np.max(np.abs(np.asarray(row))))
    assert float(np.max(np.abs(got - np.asarray(row)))) <= amax / 127 + 1e-7
    # the envelope is self-describing: a channel with a *different* default
    # codec reconstructs this one from codec_params and decodes identically
    foreign = np.asarray(Channel(upload_codec="raw").recv_upload(env))
    np.testing.assert_array_equal(foreign, got)


@given(st.integers(1, 40000))
@settings(max_examples=25, deadline=None)
def test_wire_layout_invariants(n):
    """Layout algebra: padded to the *adaptive* kernel tile, trimmed scales
    (only the ceil(n/group) groups that hold real data ship — pure-padding
    groups quantize to exactly q=0/scale=1 and are re-synthesized on decode),
    byte total — and compression never inverts once P reaches one group."""
    group, block_rows = 256, 64
    eff = effective_block_rows(n, group, block_rows)
    tile = group * eff
    n_pad, n_scales, payload = wire_layout(n, group, block_rows)
    assert 1 <= eff <= block_rows
    assert n_pad >= n and n_pad % tile == 0 and n_pad - n < tile
    assert n_scales == -(-n // group)  # ceil: data groups only
    assert scales_padding(n, group, block_rows) == n_pad // group - n_scales
    assert payload == n_pad + 4 * n_scales
    if n >= group:
        assert payload < 4 * n  # int8 wire never exceeds the raw wire
    if n > group * block_rows:
        # above one tile the adaptive block bounds pad waste to ~6.25% of
        # rows, so compression never collapses at tile-boundary bands
        assert 4 * n / payload > 3.5


def test_wire_layout_no_compression_cliff_at_tile_boundaries():
    """Row counts just past a block multiple (the old 2.0x cliff) compress."""
    group, block_rows = 256, 64
    tile = group * block_rows
    for n in (tile + group, tile + 1, 4 * tile + group, 123 * group + 17):
        n_pad, _, payload = wire_layout(n, group, block_rows)
        assert 4 * n / payload > 3.5, n
        # and the layout still matches what the kernel path emits
        eff = effective_block_rows(n, group, block_rows)
        assert (n_pad // group) % eff == 0


def test_uplink_byte_accounting_reconciles_envelope_exact():
    """channel stats == the sum over kept envelopes, payload and metadata.

    The regression this pins: ``upload_bytes`` must equal the sum of
    ``payload.nbytes`` over every envelope the channel produced, and the
    ``upload_meta_bytes`` ledger must equal the sum of each envelope's
    canonical-JSON metadata block (``meta_nbytes``) — across all three
    registry codecs, including clamped-k tiny buffers where the topk codec
    ships fewer than ``k`` coordinates.  No hidden bytes, no double counts.
    """
    from repro.core.transport import TopkUploadCodec

    rng = np.random.default_rng(7)
    for codec in ("raw",
                  Int8UploadCodec(group=64, block_rows=4),
                  TopkUploadCodec(k=16),
                  TopkUploadCodec(k=16, value_dtype="int8", group=32)):
        ch = Channel(upload_codec=codec)
        envs = []
        for n in (3, 16, 1000, 4096):  # 3 < k: the clamped-k envelope
            row = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
            envs.append(ch.upload(
                row, metadata={"learner_id": f"l{n}", "round_id": n}
            ))
        assert ch.stats.upload_bytes == sum(e.payload.nbytes for e in envs)
        assert ch.stats.upload_meta_bytes == sum(e.meta_nbytes for e in envs)
        assert all(e.wire_nbytes == e.payload.nbytes + e.meta_nbytes
                   for e in envs)
        assert ch.stats.upload_meta_bytes > 0  # metadata is never free
