"""Benchmark harness entry point — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

| benchmark       | paper artifact                  |
|-----------------|---------------------------------|
| bench_agg       | §4.2 OpenMP-vs-none 10x claim   |
| bench_ops       | Figs. 5/6/7 per-op comparison   |
| bench_round     | Table 2 federation round times  |
| bench_transport | dispatch/serialization share    |
| roofline_table  | §Roofline (from dry-run jsonl)  |

Prints ``name,...`` CSV lines; writes experiments/bench_results.json.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import bench_agg, bench_ops, bench_round, bench_transport, roofline_table


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweep (slow)")
    args = ap.parse_args()

    results = {}
    print("# bench_agg (paper §4.2 parallel-aggregation claim)")
    results["agg"] = bench_agg.run(
        sizes=("100k", "1m", "10m"),
        learner_counts=(10, 25, 50, 100, 200) if args.full else (10, 25, 50),
        iters=3,
    )
    print("\n# bench_transport (flat-tensor wire format)")
    results["transport"] = bench_transport.run()
    print("\n# bench_ops (Figs. 5/6/7)")
    results["ops"] = bench_ops.run(
        sizes=("100k", "1m", "10m") if args.full else ("100k", "1m"),
        learner_counts=(10, 25, 50, 100, 200) if args.full else (10, 25),
    )
    print("\n# bench_round (Table 2)")
    results["round"] = bench_round.run(
        learner_counts=(10, 25, 50, 100, 200) if args.full else (10, 25),
        size="10m",
    )
    print("\n# roofline (from dry-run records, if present)")
    print(roofline_table.summarize())

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.json", "w") as f:
        json.dump(results, f, indent=1)
    print("\nwrote experiments/bench_results.json")


if __name__ == "__main__":
    main()
