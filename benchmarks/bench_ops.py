"""Figs. 5/6/7 reproduction: per-operation wall-clock across model sizes and
federation sizes, MetisFL-style controller vs the naive (old-Python) one.

Measured operations per federation round (paper Fig. 1 / Figs. 5-7 panels):
  train_dispatch, train_round, aggregation, eval_dispatch, eval_round,
  federation_round.

Arms:
  metis — this repo's controller: flat-buffer transport, async dispatch,
          fused packed aggregation.
  naive — sequential blocking dispatch with per-tensor pickle transport and
          per-tensor Python-loop aggregation (the paper's comparison point).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Controller, SyncProtocol, naive, packing
from repro.launch.train import build_housing_learners
from repro.models import mlp as mlp_model


def _metis_round(size: str, n_learners: int, local_steps=1) -> dict:
    cfg, learners = build_housing_learners(size, n_learners, seed=0)
    ctrl = Controller(protocol=SyncProtocol(local_steps=local_steps, batch_size=100))
    ctrl.set_initial_model(mlp_model.init_params(jax.random.key(0), cfg))
    for l in learners:
        ctrl.register_learner(l)
    ctrl.engine.run(rounds=1)  # warmup (jit compilation of learner steps)
    t = ctrl.engine.run(rounds=1)[0]
    ctrl.shutdown()
    return t.as_row()


def _naive_round(size: str, n_learners: int, local_steps=1) -> dict:
    """Sequential controller: blocking dispatch, per-tensor transport+agg."""
    cfg, learners = build_housing_learners(size, n_learners, seed=0)
    params = mlp_model.init_params(jax.random.key(0), cfg)
    treedef = jax.tree_util.tree_structure(params)
    from repro.core.scheduler import TrainTask

    task = TrainTask(round_id=0, local_steps=local_steps, batch_size=100,
                     learning_rate=0.01)
    # warmup jits
    learners[0].fit(params, task)

    row = {}
    t_round = time.perf_counter()
    # train: serialize per-tensor, run learner, wait; strictly sequential
    updates = []
    t0 = time.perf_counter()
    dispatch_s = 0.0
    for l in learners:
        td = time.perf_counter()
        blobs = naive.naive_serialize(params)
        received = naive.naive_deserialize(blobs, treedef)
        dispatch_s += time.perf_counter() - td
        updates.append(l.fit(received, task))
    row["train_dispatch_s"] = dispatch_s
    row["train_round_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    agg = naive.naive_aggregate(
        [u.params for u in updates], [float(u.num_examples) for u in updates]
    )
    row["aggregation_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    dispatch_s = 0.0
    for l in learners:
        td = time.perf_counter()
        blobs = naive.naive_serialize(agg)
        received = naive.naive_deserialize(blobs, treedef)
        dispatch_s += time.perf_counter() - td
        l.evaluate(received, 0)
    row["eval_dispatch_s"] = dispatch_s
    row["eval_round_s"] = time.perf_counter() - t0
    row["federation_round_s"] = time.perf_counter() - t_round
    return row


OPS = ("train_dispatch_s", "train_round_s", "aggregation_s",
       "eval_dispatch_s", "eval_round_s", "federation_round_s")


def run(sizes=("100k", "1m"), learner_counts=(10, 25), include_naive=True):
    rows = []
    for size in sizes:
        for n in learner_counts:
            m = _metis_round(size, n)
            rec = {"bench": "ops", "size": size, "learners": n, "arm": "metis",
                   **{k: m[k] for k in OPS}}
            rows.append(rec)
            line = ",".join(f"{k}={m[k]*1e3:.2f}ms" for k in OPS)
            print(f"ops,metis,{size},{n},{line}", flush=True)
            if include_naive:
                nv = _naive_round(size, n)
                rows.append({"bench": "ops", "size": size, "learners": n,
                             "arm": "naive", **{k: nv[k] for k in OPS}})
                line = ",".join(f"{k}={nv[k]*1e3:.2f}ms" for k in OPS)
                print(f"ops,naive,{size},{n},{line}", flush=True)
                print(
                    f"ops,speedup,{size},{n},"
                    f"agg={nv['aggregation_s']/max(m['aggregation_s'],1e-9):.1f}x,"
                    f"round={nv['federation_round_s']/max(m['federation_round_s'],1e-9):.1f}x",
                    flush=True,
                )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default=None, choices=["100k", "1m", "10m"])
    ap.add_argument("--learners", type=int, nargs="*", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep: sizes x {10,25,50,100,200}")
    args = ap.parse_args()
    if args.full:
        run(sizes=("100k", "1m", "10m"), learner_counts=(10, 25, 50, 100, 200))
    else:
        run(
            sizes=(args.size,) if args.size else ("100k", "1m"),
            learner_counts=tuple(args.learners) if args.learners else (10, 25),
        )
