"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from the
experiments/dryrun*/*.jsonl records produced by launch/dryrun.py."""

from __future__ import annotations

import json
import os

HW_NOTE = "197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI, 16 GiB HBM per chip"

HBM_GBPS = 819.0

_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"])] = r  # last write wins
    return list(recs.values())


def fmt_table(recs: list[dict]) -> str:
    head = (
        "| arch | shape | kind | peak GiB/chip | compute ms | memory ms | "
        "collective ms | dominant | useful-FLOPs |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in sorted(recs, key=lambda r: (r["arch"], _ORDER.get(r["shape"], 9))):
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                f"skip (full-attn @500k) | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        ratio = r.get("useful_flops_ratio")
        ratio_s = f"{ratio:.2f}" if ratio is not None else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['peak_bytes_per_chip']/2**30:.2f} | "
            f"{max(r['compute_s'],0)*1e3:.2f} | "
            f"{max(r['memory_s'],0)*1e3:.2f} | "
            f"{max(r['collective_s'],0)*1e3:.2f} | "
            f"{r['dominant'].replace('_s','')} | {ratio_s} |"
        )
    return head + "\n".join(lines) + "\n"


def fmt_agg_table(recs: list[dict]) -> str:
    head = (
        "| workload | P (params) | memory ms | collective ms | collectives | "
        "bytes-efficiency |\n|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        ncoll = sum(r.get("collective_counts_full_hlo", {}).values())
        eff = r.get("model_bytes_per_chip", 0) / max(r.get("bytes_per_chip", 1), 1)
        lines.append(
            f"| {r['arch']}{' (hier.)' if r.get('hierarchical') else ''} | "
            f"{r['n_params']/1e9:.1f}B | {r['memory_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.3f} | {ncoll} | {eff:.2f} |"
        )
    return head + "\n".join(lines) + "\n"


def fmt_fused_q8_table(
    shapes=((1 << 22, 8), (1 << 22, 32), (1 << 22, 64), (1 << 24, 32)),
    group: int = 256,
) -> str:
    """Analytic bytes-moved roofline for the int8-arena aggregation paths.

    The fused dequant-into-aggregate pass (``kernels/fused_agg``) reads the
    int8 rows once plus their f32 group scales and writes the f32 output:
    ``~N·P·(1 + 4/group) + 4·P`` bytes.  Dequantize-then-reduce reads the
    same int8 + scales, *writes* the f32 ``(N, P)`` stack, then re-reads it
    for the reduction: ``~9·N·P`` bytes.  HBM-bound times assume the
    ``HW_NOTE`` chip's 819 GB/s; the bytes ratio is the memory-roofline
    speedup ceiling ``benchmarks/bench_agg.py --fused`` measures against.
    """
    head = (
        "| P (params) | N | fused MiB | dequant+reduce MiB | "
        "fused HBM-bound ms | dequant+reduce ms | bytes ratio |\n"
        "|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for p, n in shapes:
        fused = n * p * (1 + 4 / group) + 4 * p
        dq = 9 * n * p
        lines.append(
            f"| 2^{p.bit_length() - 1} | {n} | {fused / 2**20:.1f} | "
            f"{dq / 2**20:.1f} | {fused / (HBM_GBPS * 1e9) * 1e3:.3f} | "
            f"{dq / (HBM_GBPS * 1e9) * 1e3:.3f} | {dq / fused:.2f}x |"
        )
    return head + "\n".join(lines) + "\n"


def fmt_sparse_topk_table(
    shapes=((1 << 22, 8), (1 << 22, 32), (1 << 22, 64), (1 << 24, 32)),
    k_divisor: int = 64,
) -> str:
    """Analytic bytes-moved roofline for the sparse top-k aggregation paths.

    The masked scatter-accumulate (``kernels/sparse_agg``) reads the
    ``(N, k)`` int32 index and f32 value streams once and writes the f32
    output row: ``~8·N·k + 4·P`` bytes.  Densify-then-reduce writes the f32
    ``(N, P)`` stack from those same streams, then re-reads it for the
    reduction: ``~8·N·P`` bytes.  At ``k = P/64`` the stack never being
    built is a ~57x bytes gap — the memory-roofline ceiling
    ``benchmarks/bench_agg.py --sparse`` measures against.  HBM-bound times
    assume the ``HW_NOTE`` chip's 819 GB/s.
    """
    head = (
        "| P (params) | N | k | scatter MiB | densify+reduce MiB | "
        "scatter HBM-bound ms | densify+reduce ms | bytes ratio |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for p, n in shapes:
        k = max(1, p // k_divisor)
        scatter = 8 * n * k + 4 * p
        dense = 8 * n * p
        lines.append(
            f"| 2^{p.bit_length() - 1} | {n} | P/{k_divisor} | "
            f"{scatter / 2**20:.1f} | {dense / 2**20:.1f} | "
            f"{scatter / (HBM_GBPS * 1e9) * 1e3:.3f} | "
            f"{dense / (HBM_GBPS * 1e9) * 1e3:.3f} | {dense / scatter:.2f}x |"
        )
    return head + "\n".join(lines) + "\n"


def summarize(
    sections=(
        ("Baseline 16×16 (pre-§Perf substrate; old collective parser)",
         "experiments/dryrun/16x16.jsonl"),
        ("Baseline 2×16×16 multi-pod (old collective parser)",
         "experiments/dryrun/2x16x16.jsonl"),
        ("Optimized 16×16 (post-§Perf cycles 1-7; fixed parser)",
         "experiments/dryrun_opt/16x16.jsonl"),
        ("Optimized 2×16×16 multi-pod (fixed parser)",
         "experiments/dryrun_opt/2x16x16.jsonl"),
    ),
) -> str:
    out = []
    for title, path in sections:
        recs = load(path)
        if not recs:
            continue
        ok = sum(1 for r in recs if r["status"] == "ok")
        sk = sum(1 for r in recs if r["status"] == "skipped")
        er = len(recs) - ok - sk
        out.append(f"### {title}  ({ok} ok / {sk} skipped / {er} error)\n")
        out.append(fmt_table(recs))
    for title, path in (
        ("Controller aggregation, paper-faithful (N=8, 16×16)",
         "experiments/dryrun/agg_16x16.jsonl"),
        ("Controller aggregation, hierarchical pod-axis (2×16×16)",
         "experiments/dryrun/agg_2x16x16.jsonl"),
    ):
        recs = load(path)
        if recs:
            out.append(f"### {title}\n")
            out.append(fmt_agg_table(recs))
    return "\n".join(out)


if __name__ == "__main__":
    print(f"Hardware: {HW_NOTE}\n")
    print(summarize())
    print("### Int8 arena: fused dequant-into-aggregate bytes moved "
          "(analytic)\n")
    print(fmt_fused_q8_table())
    print("### Sparse top-k arena: scatter-accumulate bytes moved "
          "(analytic)\n")
    print(fmt_sparse_topk_table())
