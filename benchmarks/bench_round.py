"""Table 2 reproduction: federation round time (secs) for the 10M-param model
across federation sizes, MetisFL-arm vs naive-arm.

Paper Table 2 (10M params): MetisFL 4.58/6.10/14.13/21.28/45.61 s for
10/25/50/100/200 learners vs e.g. IBM FL 175->1915 s.  Our two arms
reproduce the *shape* of that comparison on this host; EXPERIMENTS.md
compares the scaling exponents.
"""

from __future__ import annotations

from benchmarks.bench_ops import _metis_round, _naive_round


def run(learner_counts=(10, 25, 50), size="10m", include_naive=True):
    rows = []
    for n in learner_counts:
        m = _metis_round(size, n)
        rows.append({"bench": "round", "size": size, "learners": n,
                     "arm": "metis", "federation_round_s": m["federation_round_s"]})
        print(f"round,metis,{size},{n},{m['federation_round_s']:.3f}s", flush=True)
        if include_naive:
            nv = _naive_round(size, n)
            rows.append({"bench": "round", "size": size, "learners": n,
                         "arm": "naive",
                         "federation_round_s": nv["federation_round_s"]})
            print(f"round,naive,{size},{n},{nv['federation_round_s']:.3f}s",
                  flush=True)
    return rows


if __name__ == "__main__":
    run()
