"""Table 2 reproduction: federation round time (secs) for the 10M-param model
across federation sizes, MetisFL-arm vs naive-arm — plus the dispatch-scaling
arm (``--dispatch``).

Paper Table 2 (10M params): MetisFL 4.58/6.10/14.13/21.28/45.61 s for
10/25/50/100/200 learners vs e.g. IBM FL 175->1915 s.  Our two arms
reproduce the *shape* of that comparison on this host; EXPERIMENTS.md
compares the scaling exponents.

``--dispatch`` measures the serialize-once broadcast claim: per-round train
*dispatch* wall time must stay ~flat in federation size N (the global model
is serialized once per round and fanned out as shared envelopes — O(P + N)),
against the legacy per-send arm that re-serializes per learner (O(N·P)).
Defaults follow the acceptance shape: N ∈ {8, 32, 128} at P = 2^23 (≥ 2^22).
"""

from __future__ import annotations

import argparse
import json
import time


def run(learner_counts=(10, 25, 50), size="10m", include_naive=True):
    from benchmarks.bench_ops import _metis_round, _naive_round

    rows = []
    for n in learner_counts:
        m = _metis_round(size, n)
        rows.append({"bench": "round", "size": size, "learners": n,
                     "arm": "metis", "federation_round_s": m["federation_round_s"]})
        print(f"round,metis,{size},{n},{m['federation_round_s']:.3f}s", flush=True)
        if include_naive:
            nv = _naive_round(size, n)
            rows.append({"bench": "round", "size": size, "learners": n,
                         "arm": "naive",
                         "federation_round_s": nv["federation_round_s"]})
            print(f"round,naive,{size},{n},{nv['federation_round_s']:.3f}s",
                  flush=True)
    return rows


# ---------------------------------------------------------------------------
# dispatch-scaling arm
# ---------------------------------------------------------------------------


def _make_null_learner(lid, upload_buffer):
    """A learner that trains instantly and uploads a pre-packed flat buffer.

    Isolates the *dispatch* path: the round still runs the full controller
    machinery (broadcast, recv, MarkTaskCompleted arena write, aggregation,
    eval fan-out) but no local SGD, so ``train_dispatch_s`` is measured under
    realistic envelope traffic without minutes of training per round.
    """
    from repro.core import EvalReport, Learner, LocalUpdate
    from repro.optim import sgd

    class _NullLearner(Learner):
        def fit(self, params, task):
            return LocalUpdate(
                learner_id=self.learner_id, round_id=task.round_id,
                params=None, num_examples=1, metrics={}, seconds_per_step=0.0,
                buffer=upload_buffer,
            )

        def evaluate(self, params, round_id):
            return EvalReport(self.learner_id, round_id,
                              {"eval_loss": 0.0}, 1)

    dummy = lambda *a, **k: None  # noqa: E731 - never called by _NullLearner
    return _NullLearner(lid, dummy, dummy, dummy, dummy, sgd(0.1), 1)


def run_dispatch(learner_counts=(8, 32, 128), p=1 << 23, rounds=3,
                 include_persend=True):
    """Per-round train-dispatch wall time vs federation size N.

    The wire cache is invalidated before every measured dispatch (as if the
    model had just been re-published), so each dispatch pays its one
    serialization inside the timed region — the worst case; in steady state
    that single serialization is shared with the previous round's eval
    fan-out.  Median over ``rounds`` repeats: the completion side (N recvs +
    N arena writes) runs concurrently with the next measurement's setup and
    adds noise on small hosts.  The ``persend`` arm is the legacy cost: one
    full serialization per learner.
    """
    from concurrent.futures import wait as wait_futures

    import jax.numpy as jnp

    from repro.core import Channel, Controller, SyncProtocol

    rows = []
    base = None
    for n in learner_counts:
        ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=1),
                          arena_n_max=n)
        params = {"w": jnp.zeros((p,), jnp.float32)}
        ctrl.set_initial_model(params)
        upload = jnp.zeros((ctrl.arena.padded_params,), jnp.float32)
        for i in range(n):
            ctrl.register_learner(_make_null_learner(f"l{i}", upload))
        ids = ctrl.learner_ids

        def one_dispatch():
            with ctrl._wire_lock:
                ctrl._wire_cache = None  # model re-published: cold cache
            futures, dispatch_s = ctrl._dispatch_train(ids)
            wait_futures(futures)
            for f in futures:
                f.result()
            return dispatch_s

        one_dispatch()  # warmup: compiles recv/arena-write programs
        dispatch = sorted(one_dispatch() for _ in range(rounds))
        dispatch_s = dispatch[len(dispatch) // 2]
        serialized = ctrl.channel.stats.serializations
        assert ctrl.upload_fallback_packs == 0, "flat upload path not engaged"
        ctrl.shutdown()

        persend_s = None
        if include_persend:
            ch = Channel()
            t0 = time.perf_counter()
            for _ in range(n):
                ch.send(params)
            persend_s = time.perf_counter() - t0

        row = {"bench": "dispatch", "params": p, "learners": n,
               "dispatch_s": dispatch_s, "persend_s": persend_s,
               "serializations_total": serialized}
        if base is None:
            base = dispatch_s
        row["ratio_vs_smallest_n"] = dispatch_s / base
        rows.append(row)
        persend_txt = f",persend={persend_s*1e3:.1f}ms" if persend_s else ""
        print(f"dispatch,P={p},N={n},dispatch={dispatch_s*1e3:.2f}ms"
              f"{persend_txt},ratio={row['ratio_vs_smallest_n']:.2f}x",
              flush=True)
    flat = rows[-1]["dispatch_s"] / rows[0]["dispatch_s"]
    note = ("<=1.5x expected at this payload: serialize-once"
            if p >= 1 << 22 else
            "smoke payload: fan-out overhead dominates; the <=1.5x "
            "flatness claim holds at P>=2^22")
    print(f"dispatch flatness: {flat:.2f}x from N={learner_counts[0]} to "
          f"N={learner_counts[-1]} ({note})", flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dispatch", action="store_true",
                    help="train-dispatch scaling vs N (serialize-once claim)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump result rows as JSON")
    args = ap.parse_args(argv)

    if args.dispatch:
        if args.smoke:
            rows = run_dispatch(learner_counts=(4, 8, 16), p=1 << 16, rounds=1)
        else:
            rows = run_dispatch()
    else:
        rows = run(learner_counts=(10, 25) if args.smoke else (10, 25, 50))

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    return rows


if __name__ == "__main__":
    main()
